"""Thin facade over the sketch lowering engine.

One import surface for "how will this sketch launch, and what will it
cost":

    from repro import engine

    plan = make_plan(65_536, 1024)
    lw = engine.lower(plan, engine.LaunchSpec(n=512, dtype="bfloat16"))
    print(lw.describe())                  # the frozen launch record
    print(engine.explain(plan, n=512))    # the full decision trace
    engine.cost_of(lw).modeled_us         # modeled from the SAME record

The engine proper lives in ``repro.kernels.lowering`` (resolution +
execution) and ``repro.roofline.sketch_model.cost_of`` (the modeled cost
of a record); this module only re-exports, so high-level callers do not
need to know the split.
"""
from repro.kernels.lowering import (  # noqa: F401
    GATHER_OPS,
    IMPLS,
    OPS,
    SHARDS,
    LaunchSpec,
    Lowering,
    clear_lowering_cache,
    execute,
    explain,
    lower,
    lowering_cache_size,
    partial_fits_vmem,
    partial_vmem_bytes,
    v1_working_set_bytes,
)
from repro.roofline.sketch_model import cost_of  # noqa: F401
