"""Train / serve step builders with full sharding wiring.

``build_train_step`` returns (step_fn, state_specs...) ready for
``jax.jit(..., in_shardings=..., out_shardings=..., donate_argnums=...)``
under a mesh context.  Used by both the real trainer and the dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import mesh as mesh_lib
from repro.models.factory import (
    build_model, decode_token_specs, train_batch_specs,
)
from repro.optim import adamw
from repro.optim import grad_compress as gc
from repro.sharding import partition as pt


def sharding_ctx_for(mesh, cfg: ModelConfig) -> pt.ShardingContext:
    batch_axes = mesh_lib.batch_axes_of(mesh)
    data_size = 1
    for a in batch_axes:
        data_size *= mesh.shape[a]
    return pt.ShardingContext(
        batch_axes=batch_axes,
        model_axis="model",
        zero3=cfg.zero3,
        model_size=mesh.shape.get("model", 1),
        data_size=data_size,
    )


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                     compress: Optional[gc.CompressConfig] = None):
    """Returns (train_step, model).  train_step(params, opt, err, batch) ->
    (params, opt, err, metrics).  ``err`` is the EF state (None-free pytree
    of zeros when compression is off — keeps one signature)."""
    model = build_model(cfg)

    def train_step(params, opt_state, err_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        if compress is not None:
            grads, err_state = gc.compress_gradients(
                compress, grads, err_state, step=opt_state["step"])
        new_params, new_opt, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, err_state, metrics

    return train_step, model


def train_state_specs(cfg: ModelConfig, mesh, model,
                      compress: Optional[gc.CompressConfig] = None):
    """Abstract (ShapeDtypeStruct) state + PartitionSpec trees, no allocation."""
    ctx = sharding_ctx_for(mesh, cfg)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = pt.param_pspecs(params_shape, ctx)
    opt_shape = jax.eval_shape(
        functools.partial(adamw.init_state,
                          cfg=adamw.AdamWConfig(state_dtype=cfg.optstate_dtype)),
        params_shape)
    opt_specs = {
        "m": pspecs, "v": pspecs,
        "step": jax.sharding.PartitionSpec(),
    }
    if compress is not None:
        err_shape = jax.eval_shape(gc.init_error_state, params_shape)
        err_specs = pspecs
    else:
        err_shape, err_specs = None, None
    return ctx, params_shape, pspecs, opt_shape, opt_specs, err_shape, err_specs


# ---------------------------------------------------------------------------
# serving (decode)
# ---------------------------------------------------------------------------

def build_serve_step(cfg: ModelConfig):
    """serve_step(params, state, tokens, pos) -> (logits, new_state)."""
    model = build_model(cfg)

    def serve_step(params, state, tokens, pos):
        logits, new_state = model.decode_step(params, state, tokens, pos)
        return logits, new_state

    return serve_step, model


def decode_state_specs(cfg: ModelConfig, mesh, model, shape: ShapeConfig):
    """Abstract decode state (KV caches / SSM states) + specs."""
    ctx = sharding_ctx_for(mesh, cfg)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = pt.param_pspecs(params_shape, ctx)
    B = shape.global_batch
    extra = {}
    if cfg.family == "encdec":
        extra["encoder_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        extra["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.image_tokens, cfg.d_model), jnp.float32)
    state_shape = jax.eval_shape(
        lambda p, e: model.init_decode_state(p, B, shape.seq_len, e),
        params_shape, extra)
    state_specs = decode_state_pspecs(cfg, ctx, state_shape, mesh)
    return ctx, params_shape, pspecs, state_shape, state_specs, extra


def _divides(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def decode_state_pspecs(cfg: ModelConfig, ctx: pt.ShardingContext,
                        state_shape, mesh):
    """Shard decode caches: batch over data axes when divisible, else
    sequence over model (sequence-parallel KV for long_500k / batch=1)."""
    P = jax.sharding.PartitionSpec
    model_size = mesh.shape["model"]
    data_size = 1
    for a in ctx.batch_axes:
        data_size *= mesh.shape[a]

    def spec_for(leaf):
        shp = leaf.shape
        nd = len(shp)
        if nd >= 4:
            # (..., B, H, S, hd) KV-style or (..., B, H, P, N) state-style
            b_dim = nd - 4
            spec = [None] * nd
            if _divides(shp[b_dim], data_size):
                spec[b_dim] = ctx.batch_axes
            # try model axis on heads, else on seq (sequence-parallel cache)
            if _divides(shp[b_dim + 1], model_size):
                spec[b_dim + 1] = "model"
            elif _divides(shp[b_dim + 2], model_size):
                spec[b_dim + 2] = "model"
            return P(*spec)
        if nd >= 2:
            spec = [None] * nd
            b_dim = nd - 2
            if _divides(shp[b_dim], data_size):
                spec[b_dim] = ctx.batch_axes
            if _divides(shp[b_dim + 1], model_size):
                spec[b_dim + 1] = "model"
            return P(*spec)
        return P()

    return jax.tree.map(spec_for, state_shape)
