"""Trainer: the glue loop — data pipeline → sharded train step → metrics,
with periodic async checkpointing, restart-from-latest, and optional sketched
gradient compression.  Runs identically on 1 CPU device (smoke/examples) and
on a production mesh (launch/train.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import pipeline as dp
from repro.optim import adamw
from repro.optim import grad_compress as gc
from repro.train import checkpoint as ckpt
from repro.train import train_step as ts


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                 tcfg: TrainerConfig,
                 data_cfg: dp.DataConfig,
                 compress: Optional[gc.CompressConfig] = None,
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.data_cfg = data_cfg
        self.compress = compress
        self.log = log_fn
        self.step_fn, self.model = ts.build_train_step(cfg, opt_cfg, compress)
        self._jitted = jax.jit(self.step_fn)
        self.async_ckpt = ckpt.AsyncCheckpointer()

    # ------------------------------------------------------------------
    def init_state(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(self.tcfg.seed)
        params = self.model.init(key)
        opt_state = adamw.init_state(params, self.opt_cfg)
        err = gc.init_error_state(params) if self.compress else {}
        return params, opt_state, err

    def maybe_restore(self, params, opt_state, err):
        d = self.tcfg.ckpt_dir
        if not d:
            return params, opt_state, err, 0
        step = ckpt.latest_step(d)
        if step is None:
            return params, opt_state, err, 0
        tree = {"params": params, "opt": opt_state, "err": err}
        restored, step = ckpt.restore(d, step, tree)
        self.log(f"[trainer] restored checkpoint step={step}")
        return restored["params"], restored["opt"], restored["err"], step

    # ------------------------------------------------------------------
    def fit(self, start_key=None) -> Dict[str, Any]:
        params, opt_state, err = self.init_state(start_key)
        params, opt_state, err, start = self.maybe_restore(params, opt_state, err)
        losses = []
        t0 = time.time()
        for step in range(start, self.tcfg.total_steps):
            batch_np = dp.make_batch(self.data_cfg, step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt_state, err, metrics = self._jitted(
                params, opt_state, err, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % self.tcfg.log_every == 0:
                self.log(f"[trainer] step={step} loss={loss:.4f} "
                         f"gnorm={float(metrics['grad_norm']):.3f} "
                         f"lr={float(metrics['lr']):.2e}")
            if self.tcfg.ckpt_dir and (step + 1) % self.tcfg.ckpt_every == 0:
                self.async_ckpt.save_async(
                    self.tcfg.ckpt_dir, step + 1,
                    {"params": params, "opt": opt_state, "err": err})
                ckpt.prune_old(self.tcfg.ckpt_dir, self.tcfg.ckpt_keep)
        self.async_ckpt.wait()
        return {
            "losses": losses,
            "final_params": params,
            "steps": self.tcfg.total_steps - start,
            "wall_s": time.time() - t0,
        }
