"""Fault tolerance: failure detection, straggler mitigation, elastic re-mesh.

On a real 1000-node fleet these hooks bind to the cluster runtime (GKE / Borg
health signals, ICI link monitors).  Here the *policies* are implemented and
unit-tested against a simulated cluster so the control logic — which is what
actually pages people at 3am — is exercised:

  * HeartbeatMonitor      — per-host deadline tracking, failure detection
  * StragglerDetector     — per-step time EWMA + k·σ outlier rule
  * ElasticPlanner        — given surviving hosts, choose the largest valid
                            (data, model) mesh and a checkpoint-restore plan
  * TrainSupervisor       — retry loop: run steps, on failure shrink mesh,
                            restore latest checkpoint, continue
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# failure detection
# ---------------------------------------------------------------------------

class HeartbeatMonitor:
    def __init__(self, hosts: Sequence[str], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        now = clock()
        self._last: Dict[str, float] = {h: now for h in hosts}

    def beat(self, host: str, at: Optional[float] = None):
        self._last[host] = self._clock() if at is None else at

    def dead_hosts(self) -> List[str]:
        now = self._clock()
        return [h for h, t in self._last.items() if now - t > self.timeout_s]

    def alive_hosts(self) -> List[str]:
        dead = set(self.dead_hosts())
        return [h for h in self._last if h not in dead]


class StragglerDetector:
    """EWMA of step times; flags hosts persistently k·σ above the fleet."""

    def __init__(self, alpha: float = 0.2, k_sigma: float = 3.0,
                 patience: int = 3):
        self.alpha = alpha
        self.k = k_sigma
        self.patience = patience
        self._ewma: Dict[str, float] = {}
        self._strikes: Dict[str, int] = {}

    def record(self, host: str, step_time: float):
        prev = self._ewma.get(host, step_time)
        self._ewma[host] = (1 - self.alpha) * prev + self.alpha * step_time

    def stragglers(self) -> List[str]:
        if len(self._ewma) < 3:
            return []
        vals = list(self._ewma.values())
        mean = sum(vals) / len(vals)
        var = sum((v - mean) ** 2 for v in vals) / len(vals)
        sd = math.sqrt(var)
        out = []
        for h, v in self._ewma.items():
            if v > mean + self.k * max(sd, 1e-9):
                self._strikes[h] = self._strikes.get(h, 0) + 1
                if self._strikes[h] >= self.patience:
                    out.append(h)
            else:
                self._strikes[h] = 0
        return out


# ---------------------------------------------------------------------------
# elastic re-mesh
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int
    hosts_used: int
    note: str = ""

    @property
    def chips(self) -> int:
        return self.data * self.model


class ElasticPlanner:
    """Choose the largest (data, model) mesh from surviving chips.

    The model axis is pinned (TP degree is a property of the model layout —
    changing it would re-partition every weight); elasticity comes from the
    data axis: drop to the largest data degree that divides the global batch
    and fits the surviving chip count.
    """

    def __init__(self, model_parallel: int, chips_per_host: int,
                 global_batch: int):
        self.model_parallel = model_parallel
        self.chips_per_host = chips_per_host
        self.global_batch = global_batch

    def plan(self, alive_hosts: int) -> Optional[MeshPlan]:
        chips = alive_hosts * self.chips_per_host
        max_data = chips // self.model_parallel
        data = 1
        while data * 2 <= max_data and self.global_batch % (data * 2) == 0:
            data *= 2
        if max_data < 1:
            return None
        return MeshPlan(
            data=data, model=self.model_parallel,
            hosts_used=(data * self.model_parallel + self.chips_per_host - 1)
            // self.chips_per_host,
            note=f"elastic: {alive_hosts} hosts alive -> data={data}")


# ---------------------------------------------------------------------------
# supervision loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SupervisorReport:
    steps_done: int
    restarts: int
    mesh_history: List[MeshPlan]


class TrainSupervisor:
    """Retry loop: run → on failure, shrink mesh via planner, restore latest
    checkpoint, continue.  ``run_segment(plan, start_step)`` must return the
    step reached, raising on simulated failure."""

    def __init__(self, planner: ElasticPlanner, monitor: HeartbeatMonitor,
                 restore_latest: Callable[[], int],
                 run_segment: Callable[[MeshPlan, int], int],
                 max_restarts: int = 10):
        self.planner = planner
        self.monitor = monitor
        self.restore_latest = restore_latest
        self.run_segment = run_segment
        self.max_restarts = max_restarts

    def run(self, total_steps: int) -> SupervisorReport:
        restarts = 0
        history: List[MeshPlan] = []
        step = self.restore_latest()
        while step < total_steps:
            plan = self.planner.plan(len(self.monitor.alive_hosts()))
            if plan is None:
                raise RuntimeError("not enough healthy hosts to form a mesh")
            history.append(plan)
            try:
                step = self.run_segment(plan, step)
            except Exception:   # noqa: BLE001 — simulated node failure
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                step = self.restore_latest()
        return SupervisorReport(steps_done=step, restarts=restarts,
                                mesh_history=history)
