"""Checkpointing: atomic, sharded, async-capable, elastic-restorable.

Layout (one directory per step):

    <dir>/step_000100/
        meta.json            — step, config digest, pytree structure
        shard_<k>.npz        — flat arrays, chunked into ~512MB files

Design points for the 1000-node setting (simulated here on one host):
  * atomic publish: write to ``step_X.tmp`` then ``os.rename`` (crash-safe);
  * per-shard files keyed by flat-leaf index ranges — on a real cluster each
    host writes only leaves it owns (``local_leaf_filter``);
  * async: ``save_async`` snapshots arrays to host memory synchronously
    (cheap) and writes to disk on a worker thread — training continues;
  * elastic restore: ``restore`` only needs the files, not the mesh shape —
    re-sharding onto a smaller/larger mesh happens via the normal
    ``jax.device_put`` with new shardings after load.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_MAX_SHARD_BYTES = 512 * 2**20

# numpy can't serialize extension dtypes (bfloat16, fp8): store a bit-view.
_EXT_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
               "float8_e5m2": np.uint8, "float16": None}


def _to_savable(arr: np.ndarray) -> np.ndarray:
    name = arr.dtype.name
    if name in _EXT_DTYPES and _EXT_DTYPES[name] is not None:
        return arr.view(_EXT_DTYPES[name])
    return arr


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES and _EXT_DTYPES[dtype_name] is not None:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_names(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out.append((name, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree,
         local_leaf_filter: Optional[Callable[[int], bool]] = None) -> str:
    """Synchronous atomic checkpoint save. Returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    named, _ = _flatten_with_names(tree)
    meta = {"step": step, "leaves": []}
    shard: Dict[str, np.ndarray] = {}
    shard_bytes = 0
    shard_idx = 0

    def flush():
        nonlocal shard, shard_bytes, shard_idx
        if shard:
            np.savez(os.path.join(tmp, f"shard_{shard_idx:05d}.npz"), **shard)
            shard_idx += 1
            shard = {}
            shard_bytes = 0

    for i, (name, leaf) in enumerate(named):
        if local_leaf_filter is not None and not local_leaf_filter(i):
            continue
        arr = np.asarray(jax.device_get(leaf))
        key = f"leaf_{i:06d}"
        meta["leaves"].append({"i": i, "name": name, "shard": None,
                               "dtype": str(arr.dtype), "shape": list(arr.shape)})
        shard[key] = _to_savable(arr)
        shard_bytes += arr.nbytes
        meta["leaves"][-1]["shard"] = shard_idx
        if shard_bytes >= _MAX_SHARD_BYTES:
            flush()
    flush()
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)   # atomic publish
    return final


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk on a daemon thread."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def save_async(self, ckpt_dir: str, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(ckpt_dir, step, host_tree)
            except BaseException as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "meta.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_template,
            shardings=None):
    """Restore into the structure of ``tree_template``.

    ``shardings``: optional pytree of Sharding — enables *elastic* restore
    onto a different mesh than the one that saved (device_put reshards).
    """
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "meta.json")) as f:
        meta = json.load(f)
    by_idx = {l["i"]: l for l in meta["leaves"]}
    shards: Dict[int, Any] = {}

    flat, treedef = jax.tree_util.tree_flatten(tree_template)
    out = []
    for i, leaf in enumerate(flat):
        info = by_idx.get(i)
        if info is None:
            raise KeyError(f"checkpoint missing leaf {i}")
        sid = info["shard"]
        if sid not in shards:
            shards[sid] = np.load(os.path.join(final, f"shard_{sid:05d}.npz"))
        arr = _from_savable(shards[sid][f"leaf_{i:06d}"], info["dtype"])
        out.append(arr)
    restored = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored, meta["step"]


def prune_old(ckpt_dir: str, keep: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(m.group(1)) for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
