"""Training substrate: step builders, trainer loop, checkpointing, fault tolerance."""
