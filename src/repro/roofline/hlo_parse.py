"""Mini HLO-text cost walker.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
undercounts scan-over-layers models by a factor of n_layers (verified
empirically — see EXPERIMENTS.md §Dry-run notes).  This walker parses the
optimized HLO text (shapes are post-SPMD, i.e. per-device) and computes:

  * flops           — dot/conv MACs×2, loop bodies × trip count
  * hbm_bytes       — Σ over (post-fusion) ops of operand+output bytes
                      (the standard XLA bytes-accessed model)
  * collective wire bytes per kind, with ring-factor (n-1)/n scaling

Trip counts are recovered from each while condition's compare-with-constant.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_instr_line(line: str):
    """Structural parse of '%name = TYPE opcode(OPERANDS), attrs'.

    Handles tuple types (nested parens) and /*index=N*/ comments, which
    defeat naive regexes on real XLA dumps.
    Returns (name, type_str, opcode, rest) or None.
    """
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rhs = _COMMENT_RE.sub("", s[eq + 3:]).strip()
    if rhs.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        type_str = rhs[:end + 1]
        rest0 = rhs[end + 1:].strip()
    else:
        m = re.match(r"^(\S+)", rhs)
        if not m:
            return None
        type_str = m.group(1)
        rest0 = rhs[m.end():].strip()
    m2 = re.match(r"^([\w\-]+)\((.*)$", rest0)
    if not m2:
        return None
    return name, type_str, m2.group(1), m2.group(2)


def _shape_bytes(type_str: str) -> float:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "f32", []
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str          # everything after the '(' of the operand list
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]          # var -> type string


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_wire_bytes: float = 0.0

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.hbm_bytes += other.hbm_bytes * times
        self.coll_wire_bytes += other.coll_wire_bytes * times
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * times


_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "domain",
    "opt-barrier", "custom-call",
}


def _split_operands(rest: str) -> List[str]:
    """Operand names from 'op(%a, %b), attr=...' (first paren level).

    Modern XLA dumps inline each operand's type — ``dot(f32[64,64]{1,0}
    %lhs, f32[64,64]{1,0} %rhs)`` — so commas inside ``[]``/``{}``/nested
    ``()`` must not split, and the operand name is the (last) %-prefixed
    token of the piece, not its first word.  Older dumps (bare ``%lhs``)
    parse identically.
    """
    out, depth, cur = [], 0, []
    for ch in rest:
        if ch in "([{":
            depth += 1
            cur.append(ch)
        elif ch in ")]}":
            if ch == ")" and depth == 0:
                break               # end of the operand list
            depth = max(0, depth - 1)
            cur.append(ch)
        elif ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    names = []
    for o in out:
        pct = re.findall(r"%([\w.\-]+)", o)
        if pct:
            names.append(pct[-1])
            continue
        m = re.match(r"([\w.\-]+)", o)
        if m:
            names.append(m.group(1))
    return names


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        # Computation headers start at column 0 ("%name (params) -> type {"
        # or "ENTRY %name ..."); instructions are indented.  Params may be
        # tuple-typed (nested parens), so match only the leading name.
        if line and not line[0].isspace():
            header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if header and stripped.endswith("{") and "->" in stripped:
                cur = Computation(header.group(1), [], {})
                comps[cur.name] = cur
                continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        parsed = _parse_instr_line(line)
        if parsed is None:
            continue
        name, type_str, opcode, rest = parsed
        instr = Instr(name, type_str, opcode, rest, _split_operands(rest))
        cur.instrs.append(instr)
        cur.shapes[name] = type_str
    return comps


def _attr(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=([^,]+(?:{[^}]*})?)", rest)
    return m.group(1) if m else None


def _called(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def _group_size(rest: str, total_devices: int) -> int:
    """Participants per replica group from 'replica_groups=[G,S]<=[...]'."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return max(1, int(m.group(2)))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", rest)
    if m:
        return max(1, len(m.group(1).split(",")))
    return total_devices


def _trip_count(ins: Instr, cond: Optional[Computation]) -> float:
    """Trip count: prefer the while op's backend_config known_trip_count,
    fall back to the largest positive constant in the condition region."""
    m = re.search(r'known_trip_count[^0-9]*"n":"(\d+)"', ins.rest)
    if m:
        return float(m.group(1))
    if cond is None:
        return 1.0
    best = 1.0
    for cins in cond.instrs:
        if cins.opcode == "constant":
            cm = re.search(r"constant\((\d+)\)", cins.rest)
            if cm:
                best = max(best, float(cm.group(1)))
    return best


def _dot_flops(comp: Computation, ins: Instr) -> float:
    _, out_dims = _shape_dims(ins.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    lhs = ins.operands[0] if ins.operands else None
    lhs_shape = comp.shapes.get(lhs, "")
    _, lhs_dims = _shape_dims(lhs_shape)
    cdims = _attr(ins.rest, "lhs_contracting_dims")
    csize = 1
    if cdims and lhs_dims:
        for idx in re.findall(r"\d+", cdims):
            i = int(idx)
            if i < len(lhs_dims):
                csize *= lhs_dims[i]
    return 2.0 * out_elems * csize


def _instr_cost(comps, comp: Computation, ins: Instr, devices: int,
                memo) -> Cost:
    c = Cost()
    op = ins.opcode
    if op in _SKIP_OPS:
        # custom-calls and control ops: charge output bytes only
        if op == "custom-call":
            c.hbm_bytes += _shape_bytes(ins.type_str)
        return c
    if op == "while":
        body = _called(ins.rest, "body")
        cond = _called(ins.rest, "condition")
        trips = _trip_count(ins, comps.get(cond))
        if body in comps:
            c.add(computation_cost(comps, body, devices, memo), trips)
        if cond in comps:
            c.add(computation_cost(comps, cond, devices, memo), trips)
        return c
    if op in ("call", "fusion"):
        # fusion: flops from the callee; bytes = output + refined operand
        # charges (an operand whose only callee use is dynamic-slice/gather
        # is charged the sliced bytes, not the full array — matches XLA's
        # bytes-accessed model and is what makes scan-over-stacked-params
        # costing sane).
        callee = _called(ins.rest, "calls")
        if callee and callee in comps:
            inner = computation_cost(comps, callee, devices, memo,
                                     bytes_free=True)
            c.add(inner)
            # output charge: a fusion whose root is an (in-place)
            # dynamic-update-slice writes only the slice, not the whole
            # aliased buffer — charging the full output would bill scan-ys
            # accumulators their entire stacked size per iteration.
            dus_update = _fusion_root_dus_update_bytes(comps[callee])
            if dus_update is not None:
                c.hbm_bytes += dus_update
            else:
                c.hbm_bytes += _shape_bytes(ins.type_str)
            callee_comp = comps[callee]
            param_names = [i.name for i in callee_comp.instrs
                           if i.opcode == "parameter"]
            for idx, o in enumerate(ins.operands):
                full = _shape_bytes(comp.shapes.get(o, ""))
                if idx < len(param_names):
                    refined = _refined_param_bytes(
                        callee_comp, param_names[idx], full)
                    c.hbm_bytes += refined
                else:
                    c.hbm_bytes += full
        else:
            c.hbm_bytes += _shape_bytes(ins.type_str)
            for o in ins.operands:
                c.hbm_bytes += _shape_bytes(comp.shapes.get(o, ""))
        return c
    if op == "conditional":
        for key in ("true_computation", "false_computation"):
            callee = _called(ins.rest, key)
            if callee and callee in comps:
                c.add(computation_cost(comps, callee, devices, memo))
        return c
    if op in _COLLECTIVES:
        nbytes = _shape_bytes(ins.type_str)
        # Logical-dtype correction: the CPU backend upcasts every bf16 dot
        # to f32 (no native bf16 GEMM), and SPMD collectives then ship the
        # f32 upcasts.  A TPU build communicates the logical bf16 values.
        # If the collective's operands are produced by convert-from-bf16
        # (directly or as a fusion root), charge 2 bytes/elem.
        scale = _logical_dtype_scale(comps, comp, ins)
        nbytes *= scale
        gsz = _group_size(ins.rest, devices)
        ring = (gsz - 1) / gsz if gsz > 1 else 0.0
        wire = nbytes * ring * (2.0 if op == "all-reduce" else 1.0)
        c.coll_bytes[op] = c.coll_bytes.get(op, 0.0) + nbytes
        c.coll_wire_bytes += wire
        c.hbm_bytes += nbytes  # the local read/write of the buffer
        return c
    # generic compute op
    if op == "dot":
        c.flops += _dot_flops(comp, ins)
    elif op == "convolution":
        _, out_dims = _shape_dims(ins.type_str)
        out_elems = 1
        for d in out_dims:
            out_elems *= d
        rhs_shape = comp.shapes.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
        _, rdims = _shape_dims(rhs_shape)
        kernel = 1
        for d in rdims[:-1]:
            kernel *= d
        c.flops += 2.0 * out_elems * kernel
    else:
        _, out_dims = _shape_dims(ins.type_str)
        out_elems = 1
        for d in out_dims:
            out_elems *= d
        c.flops += float(out_elems)  # elementwise ~1 flop/elem
    c.hbm_bytes += _op_bytes(comp, ins)
    return c


def _find_instr(comp: "Computation", name: str) -> Optional["Instr"]:
    for ins in comp.instrs:
        if ins.name == name:
            return ins
    return None


def _root_is_bf16_convert(comp: "Computation", ins, depth: int = 0) -> bool:
    if ins is None or depth > 4:
        return False
    if ins.opcode == "convert":
        src = ins.operands[0] if ins.operands else None
        return src is not None and "bf16" in comp.shapes.get(src, "")
    if ins.opcode in ("bitcast", "copy", "transpose", "reshape"):
        src = ins.operands[0] if ins.operands else None
        return _root_is_bf16_convert(comp, _find_instr(comp, src), depth + 1)
    return False


def _produces_from_bf16_convert(comps, comp: "Computation", name: str,
                                depth: int = 0) -> bool:
    producer = _find_instr(comp, name)
    if producer is None or depth > 3:
        return False
    if producer.opcode == "convert":
        src = producer.operands[0] if producer.operands else None
        return src is not None and "bf16" in comp.shapes.get(src, "")
    if producer.opcode in ("bitcast", "copy", "transpose", "reshape"):
        return _produces_from_bf16_convert(
            comps, comp, producer.operands[0], depth + 1)
    if producer.opcode == "fusion":
        callee = _called(producer.rest, "calls")
        if callee in comps and comps[callee].instrs:
            return _root_is_bf16_convert(comps[callee],
                                         comps[callee].instrs[-1])
    return False


def _logical_dtype_scale(comps, comp: "Computation", ins: "Instr") -> float:
    """Fraction of the collective's f32 bytes that are logically bf16."""
    total = 0.0
    saved = 0.0
    for o in ins.operands:
        ty = comp.shapes.get(o, "")
        ob = _shape_bytes(ty)
        total += ob
        if ob > 0 and "f32" in ty and _produces_from_bf16_convert(comps, comp, o):
            saved += ob / 2.0
    if total <= 0:
        return 1.0
    return max(0.5, (total - saved) / total)


def _fusion_root_dus_update_bytes(callee: "Computation") -> Optional[float]:
    """If a fusion's root is a dynamic-update-slice (possibly via bitcast /
    copy), return the write charge for the UPDATE (2× its bytes: the slice
    is read-modified-written); else None."""
    if not callee.instrs:
        return None
    ins = callee.instrs[-1]
    depth = 0
    while ins is not None and depth < 4:
        if ins.opcode == "dynamic-update-slice":
            if len(ins.operands) > 1:
                return 2.0 * _shape_bytes(callee.shapes.get(ins.operands[1], ""))
            return None
        if ins.opcode in ("bitcast", "copy", "convert", "reshape"):
            src = ins.operands[0] if ins.operands else None
            ins = _find_instr(callee, src) if src else None
            depth += 1
            continue
        return None
    return None


def _refined_param_bytes(callee: "Computation", param_name: str,
                         full_bytes: float) -> float:
    """Bytes actually read from a fusion operand: if every callee use of the
    parameter is a dynamic-slice / gather / slice, charge those outputs."""
    sliced = 0.0
    for ins in callee.instrs:
        if param_name in ins.operands:
            if ins.opcode in ("dynamic-slice", "gather", "slice"):
                if ins.operands and ins.operands[0] == param_name:
                    sliced += _shape_bytes(ins.type_str)
                else:       # parameter used as index operand: negligible
                    sliced += _shape_bytes(callee.shapes.get(param_name, ""))
            elif ins.opcode == "dynamic-update-slice":
                # in-place update: charge the update size, not the buffer
                if len(ins.operands) > 1:
                    sliced += _shape_bytes(callee.shapes.get(ins.operands[1], ""))
            else:
                return full_bytes
    return min(sliced, full_bytes) if sliced else 0.0


def _op_bytes(comp: "Computation", ins: "Instr") -> float:
    """XLA-flavoured bytes-accessed model for a single (unfused) op."""
    op = ins.opcode
    out_b = _shape_bytes(ins.type_str)

    def operand_b(i):
        if i < len(ins.operands):
            return _shape_bytes(comp.shapes.get(ins.operands[i], ""))
        return 0.0

    if op in ("dynamic-slice", "slice"):
        return 2.0 * out_b
    if op == "dynamic-update-slice":
        return 2.0 * operand_b(1)
    if op == "gather":
        return 2.0 * out_b + operand_b(1)
    if op == "scatter":
        return 2.0 * operand_b(2) + operand_b(1)
    if op in ("broadcast", "iota", "constant"):
        return out_b
    total = out_b
    for i in range(len(ins.operands)):
        total += operand_b(i)
    return total


def computation_cost(comps, name: str, devices: int, memo,
                     bytes_free: bool = False) -> Cost:
    key = (name, bytes_free)
    if key in memo:
        return memo[key]
    comp = comps[name]
    total = Cost()
    for ins in comp.instrs:
        ic = _instr_cost(comps, comp, ins, devices, memo)
        if bytes_free:
            # inside a fusion: intermediates don't touch HBM
            ic = Cost(flops=ic.flops, hbm_bytes=0.0,
                      coll_bytes=ic.coll_bytes,
                      coll_wire_bytes=ic.coll_wire_bytes)
        total.add(ic)
    memo[key] = total
    return total


def entry_cost(text: str, devices: int) -> Cost:
    comps = parse_hlo(text)
    # entry is the computation containing ROOT at top level; heuristically the
    # one named 'main...' or the last one defined.
    entry = None
    for name in comps:
        if name.startswith("main"):
            entry = name
    if entry is None:
        entry = list(comps)[-1]
    memo: Dict = {}
    return computation_cost(comps, entry, devices, memo)
