"""Roofline terms from compiled dry-run artifacts (assignment §ROOFLINE).

    compute term    = HLO_FLOPs   / (chips × 197e12)
    memory term     = HLO_bytes   / (chips × 819e9)
    collective term = coll_bytes  / (chips × 50e9)

HLO_FLOPs / HLO_bytes come from the custom HLO walker (per-device numbers ×
chips = global), because XLA's cost_analysis counts scan bodies once.
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per the assignment.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.roofline import hlo_parse, hw


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device (as parsed; shapes in optimized HLO are post-SPMD)
    device_flops: float
    device_hbm_bytes: float
    device_coll_bytes: float
    coll_breakdown: Dict[str, float]
    # terms in seconds
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    # context
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    bottleneck: str = ""
    step_time_s: float = 0.0
    # memory analysis
    arg_bytes_per_device: float = 0.0
    temp_bytes_per_device: float = 0.0
    fits_hbm: bool = True
    note: str = ""

    def finish(self):
        self.compute_s = self.device_flops / hw.PEAK_FLOPS_BF16
        self.memory_s = self.device_hbm_bytes / hw.HBM_BW
        self.collective_s = self.device_coll_bytes / (hw.ICI_LINK_BW * hw.ICI_LINKS)
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        self.step_time_s = max(terms.values())
        global_flops = self.device_flops * self.chips
        self.useful_ratio = (self.model_flops / global_flops) if global_flops else 0.0
        total_state = self.arg_bytes_per_device + self.temp_bytes_per_device
        self.fits_hbm = total_state <= hw.HBM_PER_CHIP
        return self

    def roofline_fraction(self) -> float:
        """Fraction of the ideal (model-flops-only) time: how close the step
        is to the best achievable on the dominant resource."""
        ideal = self.model_flops / (self.chips * hw.PEAK_FLOPS_BF16)
        return ideal / self.step_time_s if self.step_time_s > 0 else 0.0

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def model_flops_for(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D with N = active params; decode: D = batch tokens (1 step)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens          # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze_compiled(compiled, cfg: ModelConfig, shape: ShapeConfig,
                     mesh_name: str, chips: int,
                     note: str = "") -> RooflineReport:
    text = compiled.as_text()
    cost = hlo_parse.entry_cost(text, chips)
    ma = compiled.memory_analysis()
    rep = RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        device_flops=cost.flops,
        device_hbm_bytes=cost.hbm_bytes,
        device_coll_bytes=cost.coll_wire_bytes,
        coll_breakdown=dict(cost.coll_bytes),
        model_flops=model_flops_for(cfg, shape),
        arg_bytes_per_device=float(getattr(ma, "argument_size_in_bytes", 0)),
        temp_bytes_per_device=float(getattr(ma, "temp_size_in_bytes", 0)),
        note=note,
    )
    return rep.finish()


def save_report(rep: RooflineReport, outdir: str):
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, f"{rep.arch}_{rep.shape}_{rep.mesh}.json")
    with open(path, "w") as f:
        json.dump(rep.to_json(), f, indent=2)
    return path


def format_row(rep: RooflineReport) -> str:
    return (f"| {rep.arch} | {rep.shape} | {rep.mesh} | "
            f"{rep.compute_s*1e3:.1f} | {rep.memory_s*1e3:.1f} | "
            f"{rep.collective_s*1e3:.1f} | {rep.bottleneck} | "
            f"{rep.useful_ratio:.2f} | {rep.roofline_fraction()*100:.0f}% | "
            f"{(rep.arg_bytes_per_device+rep.temp_bytes_per_device)/2**30:.1f} GiB |")
