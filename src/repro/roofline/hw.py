"""TPU v5e hardware constants (assignment §ROOFLINE ANALYSIS)."""

PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link
HBM_PER_CHIP = 16 * 2**30       # 16 GiB

# v5e 2D torus: 4 ICI links per chip usable; conservative single-link model
# per the assignment formula (collective_bytes / (chips × link_bw)).
ICI_LINKS = 1
