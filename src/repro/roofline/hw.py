"""TPU v5e hardware constants (assignment §ROOFLINE ANALYSIS)."""

PEAK_FLOPS_BF16 = 197e12        # per chip
PEAK_FLOPS_FP32 = 98.5e12       # per chip, fp32 MXU inputs (half the bf16 rate)
PEAK_FLOPS_VPU = 2e12           # per chip, element-wise ops (order-of-magnitude
                                # estimate; used only for hash-cost modeling)
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link
HBM_PER_CHIP = 16 * 2**30       # 16 GiB

# v5e 2D torus: 4 ICI links per chip usable; conservative single-link model
# per the assignment formula (collective_bytes / (chips × link_bw)).
ICI_LINKS = 1
