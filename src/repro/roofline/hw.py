"""TPU v5e hardware constants (assignment §ROOFLINE ANALYSIS)."""

PEAK_FLOPS_BF16 = 197e12        # per chip
PEAK_FLOPS_FP32 = 98.5e12       # per chip, fp32 MXU inputs (half the bf16 rate)
PEAK_FLOPS_VPU = 2e12           # per chip, element-wise ops (order-of-magnitude
                                # estimate; used only for hash-cost modeling)
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link
HBM_PER_CHIP = 16 * 2**30       # 16 GiB

# v5e 2D torus: 4 ICI links per chip usable; conservative single-link model
# per the assignment formula (collective_bytes / (chips × link_bw)).
ICI_LINKS = 1

# Effective per-chip interconnect bandwidth used by the collective terms in
# roofline/sketch_model (psum of the sharded-sketch partials, the dist
# solver's per-iteration reductions).  Single-link conservative, matching
# ICI_LINKS above.
ICI_BW = ICI_LINK_BW * ICI_LINKS

# Minimum useful HBM transaction: a gathered (non-contiguous) row shorter
# than this still pays for the full transaction — the term that makes
# per-example (n = 1) gathers so expensive and batched gathers cheap.
HBM_TRANSACTION_BYTES = 512.0

# Per-kernel-launch dispatch/teardown overhead (host + XLA + DMA warmup);
# the term that makes B single-example launches lose to one batched launch.
KERNEL_LAUNCH_US = 5.0
