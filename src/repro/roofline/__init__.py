"""Roofline analysis from compiled dry-run artifacts (no real hardware).

``sketch_model`` adds an analytic per-kernel model for the FlashSketch
v1/v2 generations (MXU / VPU-hash / HBM terms, mixed-precision aware).
"""
