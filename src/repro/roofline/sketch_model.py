"""Analytic roofline model for the FlashSketch kernel generations.

Models one application ``Y = S A`` (or the transpose) on a single TPU chip
as ``max(MXU term, VPU term, HBM term)``:

  * MXU — the one-hot Φ contraction: ``2·κ·B_r·d_pad·n`` MACs·2, identical
    for v1 and v2 (fusing the κ reduction moves *where* the adds happen,
    not how many).
  * VPU — Φ construction from counter-based hashes.  v1 rebuilds the
    (B_r, B_c) tile for every program ``(j, g, ℓ)`` ⇒ n/T_n rebuilds per
    block pair; v2 caches the stacked Φ in VMEM scratch and rebuilds only
    at ``j == 0`` ⇒ exactly κ·M tile builds per launch, an n/T_n-fold
    saving.
  * HBM — the dominant term in the paper's d ≫ k regime.  Both versions
    stream each input block κ times (every input block feeds κ output
    blocks).  v1's κ-revisiting grid reduction charges a read-modify-write
    of the fp32 output tile per revisit (``(2κ−1)·k_pad·n`` fp32 accesses,
    the semantics the paper ascribes to scatter-style sketches); v2 writes
    each output tile exactly once.  v2 streams the input at the plan's
    precision-policy width on top — bf16 halves it, the fp8 policies
    quarter it (1 byte/elem; fp32 accumulate in-register, per Jeendgar
    et al. sketching is robust to this rounding).  v1 is fp32-only.

These terms feed ``benchmarks/kernel_bench.py`` (modeled speedups alongside
measured interpret-mode ones) and ``core.variants`` cost models.
"""
from __future__ import annotations

import dataclasses

from repro.core.blockperm import SKETCH_VARIANTS, BlockPermPlan
from repro.roofline import hw

# ~ops per hashed word: 5-word hash_words chain, ~6 ALU ops per mix/combine.
HASH_OPS_PER_WORD = 30.0

VARIANTS = SKETCH_VARIANTS


@dataclasses.dataclass(frozen=True)
class KernelCost:
    """Per-chip cost terms for one kernel launch (plus any collective).

    ``ici_bytes`` is the per-chip interconnect traffic of a trailing
    collective (0 for single-chip launches).  Collectives do not overlap
    the compute of the same launch in this first-order model, so the ICI
    term ADDS to the roofline max instead of joining it.
    """

    mxu_flops: float
    vpu_flops: float
    hbm_bytes: float
    # bf16-streaming kernels feed the MXU bf16 inputs (fp32 accumulate);
    # fp32 streams run at the half-rate fp32 MXU throughput.
    mxu_peak: float = hw.PEAK_FLOPS_FP32
    ici_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.mxu_flops / self.mxu_peak

    @property
    def vpu_s(self) -> float:
        return self.vpu_flops / hw.PEAK_FLOPS_VPU

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def ici_s(self) -> float:
        return self.ici_bytes / hw.ICI_BW

    @property
    def modeled_us(self) -> float:
        return 1e6 * (max(self.compute_s, self.vpu_s, self.memory_s)
                      + self.ici_s)

    @property
    def bottleneck(self) -> str:
        terms = {"mxu": self.compute_s, "vpu": self.vpu_s,
                 "hbm": self.memory_s, "ici": self.ici_s}
        return max(terms, key=terms.get)


def kernel_cost(
    plan: BlockPermPlan,
    n: int,
    *,
    version: str = "v2",
    variant: str = "fwd",
    tn: int = 128,
    gather: bool = False,
    batch: int = 1,
) -> KernelCost:
    """Single-launch cost terms.

    ``batch`` folds a B-stack into the column axis (the batched apply):
    every term scales with ``n·batch`` but the Φ build cost stays per-launch
    — the cached Φ tile is reused across the whole batch.

    ``gather`` models the gather-fused load (``fwd``/``blockrow`` only):
    each of the κ·d_pad gathered rows is a non-contiguous HBM read of
    ``tn·itemsize`` bytes per column tile, charged at transaction
    granularity (``hw.HBM_TRANSACTION_BYTES`` floor) — wide tiles amortize
    the transaction, skinny per-example launches eat it whole.

    Global families (countsketch/graph) need NO special casing: their plans
    carry ``kappa == M`` (every input block feeds every output block), so
    the formulas below price them verbatim — MXU work becomes the dense-like
    ``2·k_pad·d_pad·n`` (the structural reason BlockPerm wins the Pareto
    race on the matrix unit), the input is streamed M times, and the Φ build
    count ``κ·M = M²`` matches the M² tiles the fused kernel materializes.
    """
    if version not in ("v1", "v2"):
        raise ValueError(f"version must be 'v1' or 'v2', got {version!r}")
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}, got {variant!r}")
    if gather and variant == "transpose":
        raise ValueError("gather-fused loads exist for fwd/blockrow only")
    p = plan
    # v1 predates the mixed-precision path: always streams fp32.  v2
    # streams at the precision policy's width (1 B fp8, 2 B bf16, 4 B
    # fp32) and feeds the MXU at the policy's compute width (fp8 upcasts
    # to bf16 in VMEM — HBM pays 1 B/elem, the MXU runs at the bf16 rate).
    prec = p.precision
    in_itemsize = prec.itemsize if version == "v2" else 4
    mxu_itemsize = prec.compute_itemsize if version == "v2" else 4
    n_eff = n * max(1, batch)
    n_tiles = max(1, (n_eff + tn - 1) // tn)

    mxu = 2.0 * p.kappa * p.Br * p.d_pad * n_eff

    # Φ tile build: s hash passes over the hashed axis (Bc words for the
    # column-pattern kernels, Br for blockrow's per-row pattern).
    words = p.Br if variant == "blockrow" else p.Bc
    per_tile = HASH_OPS_PER_WORD * p.s * words
    tile_builds = p.kappa * p.M * (n_tiles if version == "v1" else 1)
    vpu = per_tile * tile_builds

    if variant == "transpose":
        in_elems = p.kappa * p.k_pad * n_eff  # Y gathered κ× via inverse maps
        out_elems = p.d_pad * n_eff
    else:
        in_elems = p.kappa * p.d_pad * n_eff  # A streamed κ×
        out_elems = p.k_pad * n_eff
    out_accesses = (2 * p.kappa - 1) * out_elems if version == "v1" else out_elems
    if gather:
        # κ·d_pad row reads per column tile, each at transaction granularity
        row_bytes = max(float(tn * in_itemsize), hw.HBM_TRANSACTION_BYTES)
        in_bytes = p.kappa * p.d_pad * n_tiles * row_bytes
    else:
        in_bytes = in_itemsize * in_elems
    hbm = in_bytes + 4.0 * out_accesses

    peak = hw.PEAK_FLOPS_BF16 if mxu_itemsize == 2 else hw.PEAK_FLOPS_FP32
    return KernelCost(mxu_flops=mxu, vpu_flops=vpu, hbm_bytes=hbm,
                      mxu_peak=peak)


def cost_of(lw) -> KernelCost:
    """Per-chip cost of a ``kernels.lowering.Lowering`` record.

    THE bridge between dispatch and model: the terms are derived from the
    record that actually launches — the resolved kernel generation
    (``pallas_v1`` models v1, everything else the v2 formulation the
    lowering would run on TPU), the resolved tile, the *launched* gather
    organization (``gather_fused`` — a materialized fallback is charged as
    the regular kernel it runs), the per-device workload under sharding —
    so the model cannot drift from the kernel without the Lowering record
    itself changing (which the golden-snapshot test turns into an explicit
    diff).

    Sharding: ``shard="row"`` routes to ``dist_sketch_cost`` (1/P compact
    partial + ring psum); ``"col"``/``"batch"`` are collective-free and
    charge the per-device slab (``n_loc``/``batch_loc``); ``"none"`` is
    ``kernel_cost`` verbatim.  A row-sharded record downgraded to the jnp
    oracle partial is still charged as the sharded ORGANIZATION (1/P slab
    streams + the psum): the roofline describes the data movement of the
    organization, and the oracle einsum moves the same slab — executor
    overhead is out of the first-order model's scope.

    Note: a ``tn=None`` record (the xla oracle) is charged at the default
    128-wide tile — the modeled hardware is a TPU regardless of which
    backend traced the lowering.
    """
    tn = lw.tn if lw.tn is not None else 128
    if lw.shard == "row":
        return dist_sketch_cost(lw.plan, lw.n_eff, lw.devices,
                                variant=lw.op, tn=tn)
    return kernel_cost(
        lw.plan, lw.n_loc,
        version=lw.version, variant=lw.op, tn=tn,
        gather=lw.gather_fused, batch=lw.batch_loc,
    )


def modeled_speedup(
    plan: BlockPermPlan,
    n: int,
    *,
    variant: str = "fwd",
    tn: int = 128,
) -> float:
    """Modeled-TPU speedup of v2 (at the plan's dtype) over fp32 v1."""
    v1 = kernel_cost(plan, n, version="v1", variant=variant, tn=tn)
    v2 = kernel_cost(plan, n, version="v2", variant=variant, tn=tn)
    return v1.modeled_us / v2.modeled_us


def psum_bytes_per_chip(payload_bytes: float, devices: int) -> float:
    """Per-chip ICI traffic of a ring all-reduce of ``payload_bytes``:
    reduce-scatter + all-gather each move ``(P-1)/P`` of the payload."""
    if devices <= 1:
        return 0.0
    return 2.0 * (devices - 1) / devices * payload_bytes


def dist_sketch_cost(
    plan: BlockPermPlan,
    n: int,
    devices: int,
    *,
    variant: str = "fwd",
    tn: int = 128,
    exact_reduction: bool = True,
) -> KernelCost:
    """Per-chip cost of the ROW-SHARDED sketch (``distributed.sharded_apply``).

    Each of ``devices`` chips runs the partial kernel on its ``d_pad/P``
    row slab — the dominant HBM input stream scales 1/P — then psums the
    partials.  With ``exact_reduction`` (the implemented protocol) the
    per-ℓ partials stay stacked, so the collective payload AND the local
    partial writes are κ·k_pad·n fp32 (the price of bit-exactness);
    ``exact_reduction=False`` models a plain (k_pad, n) psum.  MXU,
    HBM-input and Φ-build (VPU) all shard 1/P because the partial kernel's
    grid is COMPACT — ``(M_loc, κ, n/tn)`` over the κ·M/P owned (g, ℓ)
    pairs only (ownership is a partition, π_ℓ a permutation); the model
    charges exactly what ``flashsketch_pallas_partial`` executes.

    Only ``variant="fwd"`` is modeled: the FLASHBLOCKROW partial is
    masked full-grid (iid wiring is no permutation) and does NOT shard
    its per-chip compute — returning 1/P terms for it would certify
    scaling the kernel cannot deliver, so anything else raises.
    """
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    if variant != "fwd":
        raise ValueError(
            f"dist_sketch_cost models the compact 'fwd' partial only; "
            f"variant={variant!r} has no sharded-compute formulation")
    base = kernel_cost(plan, n, version="v2", variant=variant, tn=tn)
    if devices == 1:
        return base
    p = plan
    kappa_out = p.kappa if exact_reduction else 1
    in_bytes = p.stream_itemsize * p.kappa * (p.d_pad / devices) * n
    out_bytes = 4.0 * kappa_out * p.k_pad * n
    payload = 4.0 * kappa_out * p.k_pad * n
    return KernelCost(
        mxu_flops=base.mxu_flops / devices,
        vpu_flops=base.vpu_flops / devices,
        hbm_bytes=in_bytes + out_bytes,
        mxu_peak=base.mxu_peak,
        ici_bytes=psum_bytes_per_chip(payload, devices),
    )


def modeled_dist_speedup(
    plan: BlockPermPlan,
    n: int,
    devices: int,
    *,
    variant: str = "fwd",
    tn: int = 128,
    exact_reduction: bool = True,
) -> float:
    """Modeled multi-chip scaling: single-chip v2 time over per-chip
    row-sharded time (local partial + psum).  The number the
    ``dist_bench`` gate holds ≥ 1.5× at 8 devices — in the paper's d ≫ k
    regime the 1/P HBM saving dominates the κ·k·n psum."""
    single = kernel_cost(plan, n, version="v2", variant=variant, tn=tn)
    dist = dist_sketch_cost(plan, n, devices, variant=variant, tn=tn,
                            exact_reduction=exact_reduction)
    return single.modeled_us / dist.modeled_us


def grass_sketch_cost(
    plan: BlockPermPlan,
    batch: int,
    *,
    fused: bool = True,
    batched: bool = True,
    version: str = "v2",
    tn: int = 128,
    variant: str = "fwd",
) -> float:
    """Modeled us to sketch ``batch`` sparsified per-example gradients.

    The GraSS inner loop (sparsify → sketch, §7.4/App. E), in its four
    organizations:

      * ``fused & batched`` — ONE gather-fused launch over the whole batch
        folded into the column axis (the PR-3 path).
      * ``fused, not batched`` — B gather-fused single-column launches.
      * ``not fused, batched`` — a gather pass materializes ``A[mask]``
        (transaction-granular read + contiguous write), then one batched
        sketch launch re-reads it κ×.
      * ``not fused, not batched`` — the seed pipeline: per example, a
        materializing gather + a skinny (n = 1) sketch launch.  Every
        gathered element pays a full HBM transaction and every example
        pays two kernel launches.

    ``plan.d`` is the sparsified dim d_keep; the source dim only enters
    through the transaction-granular gather term (index-independent).
    """
    if fused:
        eff_tn = min(tn, max(1, batch)) if batched else 1
        kc = kernel_cost(plan, 1, version=version, variant=variant,
                         tn=max(8, eff_tn), gather=True,
                         batch=batch if batched else 1)
        if batched:
            return kc.modeled_us + hw.KERNEL_LAUNCH_US
        return batch * (kc.modeled_us + hw.KERNEL_LAUNCH_US)
    # unfused: materialize A[mask] first, then run the regular kernel on it
    cols = batch if batched else 1
    row_read = max(4.0 * cols, hw.HBM_TRANSACTION_BYTES)   # per gathered row
    gather_us = 1e6 * (plan.d * row_read + 4.0 * plan.d * cols) / hw.HBM_BW
    kc = kernel_cost(plan, 1, version=version, variant=variant,
                     tn=max(8, min(tn, cols)), batch=cols)
    per_pass = gather_us + kc.modeled_us + 2 * hw.KERNEL_LAUNCH_US
    return per_pass if batched else batch * per_pass
