"""The paper's own evaluation configuration (FlashSketch defaults).

Sketch shapes from §7 / App. F: d ∈ {16384, 65536, 131072, 262144},
n ∈ {512, 1024}, k ∈ {64 ... 4096}, κ ∈ {1, 2, 4, 8}, s ∈ {1, 2, 4}.
GraSS MLP: 3-layer ReLU MLP, 109,386 params, sketch 4k -> k ∈ {1024, 2048, 4096}.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class PaperSketchConfig:
    d_values: Tuple[int, ...] = (16_384, 65_536, 131_072, 262_144)
    n_for_small_d: int = 1024          # d <= 65536
    n_for_large_d: int = 512
    k_values: Tuple[int, ...] = (64, 256, 512, 1024, 2048, 4096)
    kappa_values: Tuple[int, ...] = (1, 2, 4, 8)
    s_values: Tuple[int, ...] = (1, 2, 4)
    datasets: Tuple[str, ...] = (
        "gaussian", "lowrank_noise", "sparse_suitesparse_like", "llm_weights_like"
    )

    def n_for(self, d: int) -> int:
        return self.n_for_small_d if d <= 65_536 else self.n_for_large_d


CONFIG = PaperSketchConfig()


@dataclasses.dataclass(frozen=True)
class GrassConfig:
    """GraSS end-to-end pipeline config (paper App. E)."""
    mlp_hidden: Tuple[int, ...] = (256, 256)
    mlp_in: int = 784                   # MNIST-like
    mlp_out: int = 10
    grad_dim_sketch_from: int = 4096    # "sketch down from dimension 4k"
    k_values: Tuple[int, ...] = (1024, 2048, 4096)
    n_subsets: int = 50                 # m=50 LDS retraining subsets
    subset_frac: float = 0.5            # alpha=0.5
    sparsify_keep: float = 0.25         # gradient sparsification fraction


GRASS = GrassConfig()
