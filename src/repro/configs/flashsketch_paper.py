"""The paper's own evaluation configuration (FlashSketch defaults).

Sketch shapes from §7 / App. F: d ∈ {16384, 65536, 131072, 262144},
n ∈ {512, 1024}, k ∈ {64 ... 4096}, κ ∈ {1, 2, 4, 8}, s ∈ {1, 2, 4}.
GraSS MLP: 3-layer ReLU MLP, 109,386 params, sketch 4k -> k ∈ {1024, 2048, 4096}.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class PaperSketchConfig:
    d_values: Tuple[int, ...] = (16_384, 65_536, 131_072, 262_144)
    n_for_small_d: int = 1024          # d <= 65536
    n_for_large_d: int = 512
    k_values: Tuple[int, ...] = (64, 256, 512, 1024, 2048, 4096)
    kappa_values: Tuple[int, ...] = (1, 2, 4, 8)
    s_values: Tuple[int, ...] = (1, 2, 4)
    datasets: Tuple[str, ...] = (
        "gaussian", "lowrank_noise", "sparse_suitesparse_like", "llm_weights_like"
    )

    def n_for(self, d: int) -> int:
        return self.n_for_small_d if d <= 65_536 else self.n_for_large_d


CONFIG = PaperSketchConfig()


@dataclasses.dataclass(frozen=True)
class SolverPreset:
    """One named operating point of the RandNLA solver layer
    (``repro.solvers``): how big a sketch to draw, which BlockPerm-SJLT
    quality knobs to use, and which solve strategy to run on top.

    ``sampling_factor`` sets sketch rows k = ⌈γ·n⌉ for an n-column problem;
    larger γ → smaller embedding distortion ε ≈ √(1/γ) → fewer LSQR
    iterations, at more sketch/factor cost.  ``num_sketches > 1`` switches
    to adaptive multisketching (independent seeds + residual-based
    restarts).
    """

    name: str
    sampling_factor: float = 4.0
    kappa: int = 4
    s: int = 2
    dtype: str = "float32"          # sketch streaming dtype
    method: str = "lsqr"            # "lsqr" | "cg" (iterative) | "direct"
    factorization: str = "qr"       # "qr" | "chol"
    tol: float = 1e-6
    max_iters: int = 200
    num_sketches: int = 1           # >1 => multisketch with restarts


# Named operating points, runnable via ``repro.solvers.solve_preset`` —
# examples/least_squares.py demos them and tests/test_solvers.py exercises
# every entry.  Ordered safest -> fastest.  ("precise" assumes f64 solver
# iterations — in plain fp32 it stops at the ~5e-7 residual floor.)
SOLVER_PRESETS = {
    # Reference-quality: QR factorization, κ=4 fp32 sketch, tight tol.
    "precise": SolverPreset("precise", sampling_factor=4.0, kappa=4, s=2,
                            dtype="float32", method="lsqr",
                            factorization="qr", tol=1e-10),
    # Default: same sketch, benchmark tolerance.
    "default": SolverPreset("default", sampling_factor=4.0, kappa=4, s=2,
                            dtype="float32", method="lsqr",
                            factorization="qr", tol=1e-6),
    # Throughput: bf16-streamed sketch + Cholesky factor (cheapest factor,
    # fine because the sketch is well-conditioned); costs a few extra
    # LSQR iterations per the quality-vs-speed knob.
    "fast": SolverPreset("fast", sampling_factor=4.0, kappa=2, s=1,
                         dtype="bfloat16", method="lsqr",
                         factorization="chol", tol=1e-6),
    # One-shot sketch-and-solve: no iterations, (1+ε)-optimal residual;
    # oversample more because ε lands directly in the answer.
    "direct": SolverPreset("direct", sampling_factor=8.0, kappa=4, s=2,
                           dtype="float32", method="direct"),
    # Adaptive multisketch: t cheap independent draws + restarts
    # (Higgins & Boman); per-draw sampling_factor applies to EACH sketch.
    "multisketch": SolverPreset("multisketch", sampling_factor=2.0, kappa=2,
                                s=1, dtype="float32", method="lsqr",
                                factorization="qr", tol=1e-6,
                                num_sketches=2),
}


def solver_sketch_rows(n: int, sampling_factor: float = 4.0) -> int:
    """Sketch rows k for an n-column problem: k = max(⌈γ·n⌉, n+8).

    Single source of the sizing rule — ``repro.solvers`` and the presets
    both use it (per sketch, when multisketching)."""
    return max(int(sampling_factor * n), n + 8)


@dataclasses.dataclass(frozen=True)
class GrassConfig:
    """GraSS end-to-end pipeline config (paper App. E)."""
    mlp_hidden: Tuple[int, ...] = (256, 256)
    mlp_in: int = 784                   # MNIST-like
    mlp_out: int = 10
    grad_dim_sketch_from: int = 4096    # "sketch down from dimension 4k"
    k_values: Tuple[int, ...] = (1024, 2048, 4096)
    n_subsets: int = 50                 # m=50 LDS retraining subsets
    subset_frac: float = 0.5            # alpha=0.5
    sparsify_keep: float = 0.25         # gradient sparsification fraction


GRASS = GrassConfig()
