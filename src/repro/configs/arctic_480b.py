"""arctic-480b [moe]: 128 experts top-2 + dense residual branch.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 (per expert) vocab=32000.
[hf:Snowflake/snowflake-arctic-base; hf]

~480B total params.  Requires ZeRO-3 + bf16 optimizer state + expert
parallelism; the multi-pod (512-chip) mesh is the intended fit.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    dense_residual_ff=4864,
    capacity_factor=1.25,
    param_dtype="bfloat16",
    optstate_dtype="bfloat16",
    zero3=True,
    source="hf:Snowflake/snowflake-arctic-base; hf",
)
