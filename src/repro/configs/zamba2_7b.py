"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
[arXiv:2411.15242; unverified]

Zamba2 interleaves a *shared* (weight-tied) full-attention block into a
Mamba2 stack; we apply it after every 6th SSM layer (13 applications over
81 layers), matching the paper's periodic shared-block design.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_kind="mamba2",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    param_dtype="bfloat16",
    source="arXiv:2411.15242; unverified",
)
