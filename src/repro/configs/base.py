"""Model / shape configuration schema for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (one per assigned arch)."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # explicit (qwen3) or d_model//n_heads
    qk_norm: bool = False
    attention_bias: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    dense_residual_ff: int = 0              # arctic: parallel dense MLP branch
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM / hybrid ---
    ssm_kind: str = ""                      # "mamba2" | "rwkv6"
    ssm_state: int = 0                      # mamba2 d_state
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    attn_every: int = 0                     # hybrid: shared attn after every N ssm layers

    # --- enc-dec ---
    encoder_layers: int = 0
    encoder_seq: int = 1024                 # stub audio frontend frame count

    # --- VLM ---
    cross_attn_every: int = 0               # insert cross-attn every N layers
    image_tokens: int = 1600                # stub vision frontend patch count

    # --- numerics / scale policy ---
    param_dtype: str = "bfloat16"
    optstate_dtype: str = "float32"         # bf16 for the mega models (fits HBM)
    zero3: bool = False                     # shard params/opt over data axis too
    remat: bool = True
    source: str = ""                        # provenance note [paper/hf; tier]

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 524k-token decode? (SSM/hybrid/linear-attn only)"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has a decode path (seamless is enc-dec)

    @property
    def vocab_padded(self) -> int:
        return ((self.vocab_size + 127) // 128) * 128

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model-FLOPs)."""
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        hd = self.resolved_head_dim
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        emb = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        total = emb
        if self.family == "encdec":
            attn = d * q + 2 * d * kv + q * d
            ffp = 3 * d * ff
            total += self.encoder_layers * (attn + ffp)        # encoder
            total += L * (2 * attn + ffp)                       # dec self+cross
            return total
        attn = d * q + 2 * d * kv + q * d
        if self.family in ("ssm", "hybrid") and self.ssm_kind == "mamba2":
            d_in = self.ssm_expand * d
            # in_proj -> [z, x, B, C, dt] + out_proj (no per-layer FFN:
            # zamba2's FFN lives only in the shared attention block)
            per_layer = d * (2 * d_in + 2 * self.ssm_state + self.n_ssm_heads) \
                + d_in * d + 2 * d_in
        elif self.ssm_kind == "rwkv6":
            per_layer = 6 * d * d + 2 * d * ff  # tmix ~5-6 d², cmix 2·d·ff(ish)
        else:
            per_layer = attn
        if self.n_experts:
            per_layer += self.n_experts * 3 * d * ff + d * self.n_experts
            if self.dense_residual_ff:
                per_layer += 3 * d * self.dense_residual_ff
        elif not self.ssm_kind:   # standard transformer layers get a SwiGLU FFN
            per_layer += 3 * d * ff
        total += L * per_layer
        if self.family == "hybrid" and self.attn_every:
            total += attn + 3 * d * ff      # one shared attention block
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = L // self.cross_attn_every
            total += n_cross * attn
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        full = self.param_count()
        inactive = L * (self.n_experts - self.top_k) * 3 * d * ff
        return full - inactive

    @property
    def n_ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment skip rules. Returns (runnable, reason_if_not)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attn@524k): O(L²) attention, no sub-quadratic path"
    return True, ""


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=max(2, min(cfg.n_layers, 2 if not cfg.attn_every else cfg.attn_every + 1)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        d_ff=128,
        vocab_size=512,
        head_dim=16 if cfg.head_dim else None,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        dense_residual_ff=64 if cfg.dense_residual_ff else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_kind else 64,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16 if cfg.encoder_layers else 1024,
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        image_tokens=8 if cfg.cross_attn_every else 1600,
        attn_every=2 if cfg.attn_every else 0,
        param_dtype="float32",
        optstate_dtype="float32",
        remat=False,
    )
