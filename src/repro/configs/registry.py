"""Architecture registry: ``--arch <id>`` resolution for all launchers."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, SHAPES, SHAPES_BY_NAME, shape_applicable
from repro.configs import (  # noqa: F401
    zamba2_7b,
    seamless_m4t_large_v2,
    deepseek_7b,
    internlm2_1_8b,
    qwen3_0_6b,
    command_r_plus_104b,
    rwkv6_7b,
    qwen3_moe_30b_a3b,
    arctic_480b,
    llama_3_2_vision_11b,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        zamba2_7b,
        seamless_m4t_large_v2,
        deepseek_7b,
        internlm2_1_8b,
        qwen3_0_6b,
        command_r_plus_104b,
        rwkv6_7b,
        qwen3_moe_30b_a3b,
        arctic_480b,
        llama_3_2_vision_11b,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells():
    """Yield every (arch, shape, runnable, reason) assignment cell (40 total)."""
    for arch_name, cfg in ARCHS.items():
        for shape in SHAPES:
            ok, reason = shape_applicable(cfg, shape)
            yield cfg, shape, ok, reason


__all__ = ["ARCHS", "get_arch", "all_cells", "SHAPES", "SHAPES_BY_NAME"]
