"""Architecture + shape configs (one module per assigned architecture)."""
