"""qwen3-moe-30b-a3b [moe]: 128 experts, top-8, GQA kv=4, head_dim=128.

48L d_model=2048 32H (GQA kv=4) d_ff=768 (per expert) vocab=151936.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    capacity_factor=1.25,
    param_dtype="bfloat16",
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
