"""command-r-plus-104b [dense]: GQA, no-bias, mega-scale dense decoder.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.
[hf:CohereForAI/c4ai-command-r-v01; unverified]

104B dense params: requires ZeRO-3 (params + optimizer states sharded over
data×model) and bf16 optimizer state to fit a v5e-256 pod (see DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    attention_bias=False,
    param_dtype="bfloat16",
    optstate_dtype="bfloat16",
    zero3=True,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
