"""seamless-m4t-large-v2 [audio]: encoder-decoder, multimodal backbone.

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.
[arXiv:2308.11596; hf]

Per the assignment the audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, T_frames, d) to the 24-layer encoder; the
24-layer text decoder attends over them via cross-attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,                  # decoder layers
    encoder_layers=24,
    encoder_seq=1024,             # stub audio frames
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    param_dtype="bfloat16",
    source="arXiv:2308.11596; hf",
)
