"""llama-3.2-vision-11b [vlm]: cross-attn image layers every 5th layer.

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (B, image_tokens, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,
    image_tokens=1600,
    param_dtype="bfloat16",
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
