"""Hand-rolled AdamW with configurable state dtype (bf16 states for the
mega models so optimizer memory fits HBM — see DESIGN.md §6), global-norm
clipping, and a warmup-cosine schedule.

States are plain pytrees mirroring params, so they inherit the parameter
partition specs (ZeRO: opt state sharded exactly like params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"       # "bfloat16" for mega models
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.state_dtype]
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  lr: Optional[jnp.ndarray] = None) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"]
    lr = schedule(cfg, step) if lr is None else lr

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)

    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
