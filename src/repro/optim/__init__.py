"""Optimizers + distributed-optimization tricks (sketched gradient compression)."""
