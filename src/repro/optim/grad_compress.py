"""Sketched gradient compression with error feedback (the paper's operator
deployed as a distributed-optimization trick — DESIGN.md §4.2).

Cross-pod data parallelism all-reduces the full gradient every step; at
ratio r = d/k, sketching the per-bucket gradients with a *shared-seed*
BLOCKPERM-SJLT before the inter-pod reduction cuts those collective bytes by
r.  Error feedback (EF14/EF21 family) keeps the compression bias from
accumulating:

    e ← 0
    each step:  g' = g + e
                ĝ  = Sᵀ · AllReduce_pods( S g' )      # k ≪ d bytes on the wire
                e  = g' - ĝ                            # residual fed back
                optimizer consumes ĝ

S is identical on every pod (same seed ⇒ same plan ⇒ same hash stream), so
sketch-space vectors are addable across pods.  ĝ = SᵀS g' is an unbiased-in-
expectation estimate with contraction factor δ ≥ 1/r; with EF the method
converges at the full-precision rate asymptotically (Stich et al. 2018).

The per-bucket transform uses the same FlashSketch kernel family as the
RandNLA path (transpose apply = the decompressor).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.blockperm import BlockPermPlan, make_plan
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    ratio: int = 8               # d/k compression per bucket
    kappa: int = 4
    s: int = 2
    seed: int = 0x5EC7
    min_bucket: int = 4096       # leaves smaller than this are left dense
    impl: str = "xla"            # kernel dispatch for the sketch ops
    n_rotations: int = 4         # rotate among R sketch draws (step % R)
    damping: float = 0.0         # 0 => auto γ = k/(k+d)

    def gamma(self, plan: BlockPermPlan) -> float:
        """Contraction damping.  For a JL sketch E‖SᵀSx‖² ≈ (1+d/k)‖x‖², so
        γ·SᵀS with γ = k/(k+d) makes x ↦ γSᵀSx a (k/(k+d))-contraction in
        expectation — the condition error feedback needs to converge
        (Stich et al. 2018).  Without damping, ‖I−SᵀS‖₂ ≈ (1+√(d/k))²−1 > 1
        and EF *diverges* (verified in tests)."""
        if self.damping > 0:
            return self.damping
        return plan.k_pad / (plan.k_pad + plan.d_pad)


def plan_for_leaf(cfg: CompressConfig, size: int) -> Optional[BlockPermPlan]:
    if size < cfg.min_bucket:
        return None
    k = max(256, size // cfg.ratio)
    return make_plan(size, k, kappa=cfg.kappa, s=cfg.s, seed=cfg.seed)


def init_error_state(params) -> Any:
    """Error-feedback residuals, one per leaf (f32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _leaf_compress(cfg: CompressConfig,
                   plan: Optional[BlockPermPlan],
                   g: jnp.ndarray, e: jnp.ndarray,
                   pod_axis: Optional[str],
                   step) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compress one leaf. Returns (ĝ, new_error). Inside shard_map when
    pod_axis is set (the psum over pods happens in sketch space).

    Re-randomization: one static plan (compile-once), but the gradient is
    circularly SHIFTED by a step-dependent offset before sketching and
    unshifted after — S_t = S∘R_t is a fresh sketch draw each step whose
    ranges jointly cover ℝ^d over a rotation cycle.  With a fixed S the
    untransmitted null(S) component of the error would grow forever.
    """
    if plan is None:
        gd = g.astype(jnp.float32)
        if pod_axis is not None:
            gd = jax.lax.pmean(gd, pod_axis)
        return gd.astype(g.dtype), e
    d = g.size
    g_eff = g.astype(jnp.float32).reshape(-1) + e.reshape(-1)
    if cfg.n_rotations > 1:
        stride = (int(0.6180339 * d) | 1)   # golden-ratio stride: spread shifts
        shift = (jnp.asarray(step, jnp.int32) * stride) % d
        g_in = jnp.roll(g_eff, shift)
    else:
        shift = None
        g_in = g_eff
    col = g_in[:, None]                                    # (d, 1)
    y = kops.sketch_apply(plan, col, cfg.impl)             # (k, 1)
    if pod_axis is not None:
        y = jax.lax.pmean(y, pod_axis)                     # k ≪ d on the wire
    xhat = cfg.gamma(plan) * kops.sketch_apply_t(plan, y, cfg.impl)[:, 0]
    if shift is not None:
        xhat = jnp.roll(xhat, -shift)
    g_hat = xhat
    new_e = g_eff - g_hat
    return g_hat.reshape(g.shape).astype(g.dtype), new_e.reshape(e.shape)


def compress_gradients(cfg: CompressConfig, grads, err_state,
                       pod_axis: Optional[str] = None, step=0):
    """Apply sketch-compress + error feedback to a gradient pytree.

    ``pod_axis``: shard_map axis name for the inter-pod mean (None = single
    pod; the transform is then a pure EF-sketch round-trip, used in tests).
    ``step``: rotates the sketch draw (step % n_rotations) — fresh randomness
    each step is part of the EF contraction argument.
    """
    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_e = jax.tree.leaves(err_state)
    out_g, out_e = [], []
    for g, e in zip(leaves_g, leaves_e):
        plan = plan_for_leaf(cfg, g.size)
        gh, ne = _leaf_compress(cfg, plan, g, e, pod_axis, step)
        out_g.append(gh)
        out_e.append(ne)
    return jax.tree.unflatten(treedef, out_g), jax.tree.unflatten(treedef, out_e)


def wire_bytes(cfg: CompressConfig, params) -> Dict[str, float]:
    """Collective-byte model: dense vs sketched inter-pod all-reduce."""
    dense = 0
    sketched = 0
    for p in jax.tree.leaves(params):
        dense += p.size * 4
        plan = plan_for_leaf(cfg, p.size)
        sketched += (plan.k if plan is not None else p.size) * 4
    return {"dense_bytes": float(dense), "sketched_bytes": float(sketched),
            "reduction": float(dense) / max(float(sketched), 1.0)}
