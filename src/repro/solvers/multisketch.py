"""Multisketch least squares with residual-based adaptive restarts.

Higgins & Boman (arXiv:2508.14209) observe that a cheap sparse sketch
(CountSketch there; BlockPerm-SJLT here) occasionally draws a poor
preconditioner — the failure probability is per-draw, so instead of paying
for one conservative large sketch, draw ``t`` small INDEPENDENT-SEED
sketches, stack them, and monitor the solver: if the residual decay rate
says the preconditioner is bad, throw it away and re-draw.  Expected cost
stays near the optimistic single-sketch cost while the tail disappears.

Everything is deterministic under a fixed master seed: per-sketch seeds are
derived by a fixed affine rule from (seed, round, slot), so two runs with
the same inputs produce bit-identical iterates and restart decisions.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.blockperm import (BlockPermPlan, FAMILY_DEFAULT_S,
                                  make_plan)
from repro.kernels import ops
from repro.solvers import sketch_precondition as sp

# Deterministic per-draw seed derivation (odd multipliers, splitmix-style).
_ROUND_STRIDE = 0x9E3779B1
_SLOT_STRIDE = 0x85EBCA77

# Seed space is 31 bits; the top 4 bits are a STREAM id, the low 27 the
# mixed draw.  Each sketch family gets its own stream, so independent
# draws across families provably come from disjoint seed ranges — no
# collision is possible between e.g. a countsketch redraw rung and a
# blockperm one, whatever the (round, slot) mixing lands on.
_STREAM_SHIFT = 27
_STREAM_MASK = 0xF
_MIX_MASK = (1 << _STREAM_SHIFT) - 1

_FAMILY_STREAMS = {"blockperm": 0, "countsketch": 1, "graph": 2}


def family_stream(family: str) -> int:
    """Disjoint 4-bit seed-stream id of a sketch family."""
    try:
        return _FAMILY_STREAMS[family]
    except KeyError:
        raise ValueError(
            f"no seed stream registered for family {family!r}; known: "
            f"{sorted(_FAMILY_STREAMS)}") from None


def derive_seed(master_seed: int, round_idx: int, slot: int,
                *, stream: Optional[int] = None) -> int:
    """Seed of sketch ``slot`` in restart round ``round_idx`` — a fixed
    injective-in-practice mixing of the master seed, so restarts are
    reproducible and all draws are distinct.

    ``stream`` selects one of 16 provably disjoint seed ranges (the top 4
    bits of the 31-bit seed space; use ``family_stream(name)`` for the
    per-family ids).  ``None`` inherits the master seed's own stream bits,
    so raw small master seeds keep deriving in stream 0 exactly as before
    the partition existed.
    """
    mixed = (master_seed
             + _ROUND_STRIDE * (round_idx + 1)
             + _SLOT_STRIDE * (slot + 1)) & _MIX_MASK
    if stream is None:
        stream = (master_seed >> _STREAM_SHIFT) & _STREAM_MASK
    return ((stream & _STREAM_MASK) << _STREAM_SHIFT) | mixed


def multisketch_plans(
    d: int,
    k_each: int,
    t: int,
    *,
    kappa: int = 4,
    s: Optional[int] = None,
    seed: int = 0,
    round_idx: int = 0,
    dtype: str = "float32",
    family: str = "blockperm",
) -> Tuple[BlockPermPlan, ...]:
    """``t`` independent-seed plans of ``k_each`` rows each (total t·k_each).

    ``family`` picks the sketch construction, its canonical per-column
    nonzero count (``s=None`` resolves to ``FAMILY_DEFAULT_S[family]`` —
    countsketch means s=1, graph means s=4) AND its disjoint seed stream,
    so mixing families under one master seed never collides draws."""
    stream = family_stream(family)     # validates family before s lookup
    if s is None:
        s = FAMILY_DEFAULT_S[family]
    return tuple(
        make_plan(d, k_each, kappa=kappa, s=s,
                  seed=derive_seed(seed, round_idx, i, stream=stream),
                  dtype=dtype, family=family)
        for i in range(t)
    )


def multisketch_apply(
    plans: Sequence[BlockPermPlan],
    A: jnp.ndarray,
    impl: str = "auto",
) -> jnp.ndarray:
    """Stacked sketch ``[S₁A; …; S_tA] / √t`` — rows (Σᵢ kᵢ, n).

    The 1/√t rescale keeps the stack an (approximate) isometry, so it plugs
    into ``ops.sketch_qr``-style factorizations unchanged.  Plans are
    static, so this is t kernel launches (one per independent seed), not a
    batched launch — the sketches differ in their Φ tables, not their data.
    """
    t = len(plans)
    parts = [ops.sketch_apply(p, A, impl) for p in plans]
    return jnp.concatenate(parts, axis=0) / jnp.sqrt(float(t))


@dataclasses.dataclass
class MultisketchResult:
    """Outcome of an adaptive multisketch solve.

    Attributes:
      x:           (n,) solution.
      iterations:  total LSQR iterations across all rounds.
      restarts:    number of re-sketch rounds taken (0 = first draw worked).
      relres:      final exact ``||Ax-b||/||b||``.
      converged:   relres <= tol.
      seeds:       derived seeds actually used, per round (for audit /
                   determinism tests).
    """

    x: jnp.ndarray
    iterations: int
    restarts: int
    relres: float
    converged: bool
    seeds: List[Tuple[int, ...]]


def multisketch_lstsq(
    A: jnp.ndarray,
    b: jnp.ndarray,
    *,
    k_each: Optional[int] = None,
    t: int = 2,
    kappa: int = 2,
    s: int = 1,
    seed: int = 0,
    dtype: str = "float32",
    tol: float = 1e-6,
    iters_per_round: int = 25,
    max_restarts: int = 3,
    stall_factor: float = 0.5,
    factorization: str = "qr",
    impl: str = "auto",
) -> MultisketchResult:
    """Adaptive multisketch sketch-and-precondition least squares.

    Per round: stack ``t`` independent ``k_each``-row sketches, factor, run
    up to ``iters_per_round`` preconditioned LSQR iterations warm-started
    from the current iterate.  If the round shrank the residual by less
    than ``stall_factor`` (i.e. the draw preconditions poorly — a good draw
    contracts by orders of magnitude in 25 iterations), re-draw with fresh
    round-derived seeds and repeat, keeping the iterate.

    Defaults use deliberately *cheap* per-draw sketches (κ=2, s=1, small
    k_each) — the restart safety-net is what makes that aggressive choice
    sound, per Higgins & Boman.

    Args:
      A, b: the (d, n) / (d,) least-squares problem.
      k_each: rows per individual sketch (default 2n, so the stack has 2tn).
      t: independent sketches per round.
      kappa, s, dtype: per-sketch BlockPerm-SJLT knobs.
      seed: master seed — the ONLY randomness input; fixed seed ⇒ bitwise
        reproducible trajectory including restart decisions.
      tol: target relative residual.
      iters_per_round / max_restarts / stall_factor: restart policy.
      factorization, impl: forwarded to the factor/sketch steps.

    Returns:
      ``MultisketchResult``.
    """
    d, n = A.shape
    if k_each is None:
        k_each = max(2 * n, n + 8)
    bnorm = float(jnp.linalg.norm(b))
    x = jnp.zeros(n, b.dtype)
    relres = 1.0
    total_iters = 0
    restarts = 0
    seeds_used: List[Tuple[int, ...]] = []

    def draw(round_idx: int) -> jnp.ndarray:
        plans = multisketch_plans(d, k_each, t, kappa=kappa, s=s, seed=seed,
                                  round_idx=round_idx, dtype=dtype)
        seeds_used.append(tuple(p.seed for p in plans))
        SA = multisketch_apply(plans, A.astype(jnp.float32), impl)
        return ops.triangular_factor(SA, factorization).astype(b.dtype)

    R = draw(0)
    # Total-iteration budget: the work one conservative single-sketch solve
    # would have spent; restarts spend it in chunks.
    budget = iters_per_round * (max_restarts + 2)
    while total_iters < budget:
        res = sp.lsqr(A, b, R=R, x0=x, tol=tol, max_iters=iters_per_round)
        total_iters += res.iterations
        new_relres = float(jnp.linalg.norm(A @ res.x - b)) / max(bnorm, 1e-30)
        prev_relres = relres
        if new_relres < relres:
            x, relres = res.x, new_relres
        if relres <= tol:
            return MultisketchResult(x, total_iters, restarts, relres,
                                     True, seeds_used)
        # Residual-based restart rule: a good draw contracts the residual
        # by orders of magnitude per round; a round that fails to shrink it
        # below stall_factor × (previous) means the draw preconditions
        # poorly — discard it and re-draw with fresh round-derived seeds,
        # keeping the iterate.  Otherwise keep the factor and keep going.
        if new_relres > stall_factor * prev_relres:
            if restarts >= max_restarts:
                break
            restarts += 1
            R = draw(restarts)

    return MultisketchResult(x, total_iters, restarts, relres,
                             relres <= tol, seeds_used)
