"""Sketch-and-precondition least squares (the paper's headline RandNLA task).

For tall ``A (d, n)`` with ``d >> n``, solve ``min_x ||A x - b||_2`` to
machine precision:

  1. sketch:  ``SA = S A`` with a BlockPerm-SJLT plan, ``k = O(n)`` rows
     (one FlashSketch kernel launch — the only pass over ``A`` besides the
     iteration matvecs);
  2. factor:  ``R`` upper-triangular with ``SAᵀSA = RᵀR`` (QR of the small
     ``(k, n)`` sketch, or Cholesky of its Gram);
  3. iterate: LSQR (or CG on the normal equations) on the preconditioned
     operator ``A R⁻¹``, whose condition number is ``(1+ε)/(1-ε)`` when S
     is an ε-subspace-embedding for range(A).

Chen et al. (arXiv:2506.03070) show this sparse-sign variant is the
GPU-friendly way to run regression: the sketch is one memory-bound kernel,
the factorization is a tiny ``n × n`` problem, and the iteration count is
O(1) in cond(A).  The sketch quality knobs (κ, s, streaming dtype) move the
embedding distortion ε, which shows up directly — and only — in the
iteration count; the converged solution matches the direct solver because
the preconditioner never biases the fixed point.

Precision notes: the sketch + factorization run in the plan's streaming
precision (fp32 or bf16-streamed); the LSQR/CG iteration runs in the dtype
of ``A``/``b`` (pass float64 arrays under ``jax.config jax_enable_x64`` for
residuals below fp32 rounding).  A bf16 sketch only perturbs R — i.e. costs
a few extra iterations — never the attainable accuracy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro.configs import flashsketch_paper
from repro.core.blockperm import (BlockPermPlan, FAMILY_DEFAULT_S,
                                  make_plan)
from repro.kernels import lowering, ops


@dataclasses.dataclass
class SolveResult:
    """Outcome of an iterative least-squares solve.

    Attributes:
      x:          (n,) solution (original, un-preconditioned variables).
      iterations: number of LSQR/CG iterations actually run.
      relres:     final ``||A x - b|| / ||b||`` (recurrence estimate for
                  LSQR, recomputed exactly by the drivers that report it).
      converged:  whether ``relres <= tol`` was reached before the cap.
      lowering:   the ``kernels.lowering.Lowering`` record of the sketch
                  launch that built the preconditioner (``None`` for
                  drivers that never sketched, e.g. plain ``lsqr``) — how
                  the sketch actually ran: impl, tile, dtype, downgrades.
      health:     the ``repro.health.report.HealthReport`` of a guarded
                  solve — every guard verdict on the sketch/factor plus
                  the escalation-ladder actions taken (``None`` when the
                  solve ran unguarded).
    """

    x: jnp.ndarray
    iterations: int
    relres: float
    converged: bool
    lowering: Optional[object] = None
    health: Optional[object] = None


def _identity(v):
    return v


def _right_precond_ops(
    A: Optional[jnp.ndarray],
    R: Optional[jnp.ndarray],
    matvec: Optional[Callable] = None,
    rmatvec: Optional[Callable] = None,
):
    """(matvec, rmatvec, unprecondition) for the operator ``A R⁻¹``.

    The base operator is either the dense array ``A`` or INJECTED
    ``matvec``/``rmatvec`` closures (e.g. ``shard_map``'d products over a
    row-sharded A — see ``repro.distributed.dist_solvers``); the
    right-preconditioner composition is identical either way.
    """
    mv = matvec if matvec is not None else (lambda v: A @ v)
    rmv = rmatvec if rmatvec is not None else (lambda u: A.T @ u)
    if R is None:
        return mv, rmv, _identity
    Rt = R.T

    def pmatvec(v):                     # A R⁻¹ v
        return mv(jsl.solve_triangular(R, v, lower=False))

    def prmatvec(u):                    # R⁻ᵀ Aᵀ u
        return jsl.solve_triangular(Rt, rmv(u), lower=True)

    def unprecondition(y):              # x = R⁻¹ y
        return jsl.solve_triangular(R, y, lower=False)

    return pmatvec, prmatvec, unprecondition


def _lsqr_recurrence(matvec, rmatvec, unprec, base_matvec, b, x0, nvars,
                     *, tol: float, max_iters: int):
    """Golub–Kahan LSQR on ``min ||A R⁻¹ y - b||`` with x = R⁻¹ y.

    Operator-agnostic core (traced under jit by both the dense and the
    injected-ops drivers): ``matvec``/``rmatvec`` are the PRECONDITIONED
    products, ``base_matvec`` the raw ``A·`` used for the warm-start
    residual.  Carries the standard (u, v, w, phibar, rhobar) recurrence;
    stops when the recurrence residual estimate ``phibar / ||b||`` drops
    below ``tol`` or ``max_iters`` is hit.  Returns
    (x, iterations, relres_estimate).
    """
    dtype = b.dtype
    eps = jnp.finfo(dtype).tiny

    r0 = b - base_matvec(x0) if x0 is not None else b
    bnorm = jnp.maximum(jnp.linalg.norm(b), eps)
    beta = jnp.linalg.norm(r0)
    u = r0 / jnp.maximum(beta, eps)
    v = rmatvec(u)
    alpha = jnp.linalg.norm(v)
    v = v / jnp.maximum(alpha, eps)

    def cond(state):
        it, _, _, _, _, _, phibar, _ = state
        return jnp.logical_and(it < max_iters, phibar / bnorm > tol)

    def body(state):
        it, y, u, v, w, alpha, phibar, rhobar = state
        u_next = matvec(v) - alpha * u
        beta = jnp.linalg.norm(u_next)
        u_next = u_next / jnp.maximum(beta, eps)
        v_next = rmatvec(u_next) - beta * v
        alpha_next = jnp.linalg.norm(v_next)
        v_next = v_next / jnp.maximum(alpha_next, eps)
        rho = jnp.sqrt(rhobar ** 2 + beta ** 2)
        c = rhobar / rho
        s = beta / rho
        theta = s * alpha_next
        rhobar_next = -c * alpha_next
        phi = c * phibar
        phibar_next = s * phibar
        y = y + (phi / rho) * w
        w = v_next - (theta / rho) * w
        return (it + 1, y, u_next, v_next, w, alpha_next,
                phibar_next, rhobar_next)

    y0 = jnp.zeros(nvars, dtype)
    state = (jnp.int32(0), y0, u, v, v, alpha, beta, alpha)
    it, y, *_, phibar, _ = jax.lax.while_loop(cond, body, state)
    x = unprec(y)
    if x0 is not None:
        x = x + x0
    return x, it, phibar / bnorm


@functools.partial(jax.jit, static_argnames=("tol", "max_iters", "has_R"))
def _lsqr_jit(A, b, R, x0, *, tol: float, max_iters: int, has_R: bool):
    """Dense-array LSQR chunk (shape-keyed jit cache over A/b/R)."""
    matvec, rmatvec, unprec = _right_precond_ops(A, R if has_R else None)
    return _lsqr_recurrence(matvec, rmatvec, unprec, lambda v: A @ v,
                            b, x0, A.shape[1], tol=tol, max_iters=max_iters)


def lsqr(
    A: jnp.ndarray,
    b: jnp.ndarray,
    R: Optional[jnp.ndarray] = None,
    x0: Optional[jnp.ndarray] = None,
    tol: float = 1e-6,
    max_iters: Optional[int] = None,
    restart_every: int = 50,
) -> SolveResult:
    """LSQR for ``min ||A x - b||``, optionally right-preconditioned by R.

    Runs the Golub–Kahan recurrence in chunks of ``restart_every``
    iterations, recomputing the EXACT residual ``b - A x`` between chunks
    and warm-restarting from it.  In fp32 the recurrence residual estimate
    drifts from the true residual after a few dozen iterations (lost
    orthogonality), which stalls a non-restarted solver around 1e-5; the
    exact-residual restart is the textbook fix and costs one extra matvec
    per chunk.

    Args:
      A: (d, n) operator, d >= n.
      b: (d,) right-hand side.
      R: optional (n, n) upper-triangular preconditioner (from
        ``ops.sketch_qr``); iterations then run on ``A R⁻¹``.
      x0: optional warm start (the restart hook used by ``multisketch``).
      tol: stop when ``||A x - b|| / ||b|| <= tol`` (checked exactly at
        chunk boundaries, by recurrence estimate inside a chunk).
      max_iters: iteration cap (default ``4 n`` unpreconditioned, 200
        preconditioned — a subspace-embedding preconditioner converges in
        tens of iterations or something is wrong).
      restart_every: chunk length between exact-residual recomputations.

    Returns:
      ``SolveResult`` with the *recomputed* (not recurrence) final relres.
    """
    if max_iters is None:
        max_iters = 200 if R is not None else 4 * A.shape[1]
    R_arg = R if R is not None else jnp.zeros(())

    def run_chunk(x, chunk):
        return _lsqr_jit(A, b, R_arg, x, tol=float(tol),
                         max_iters=chunk, has_R=R is not None)

    return _restarted_drive(run_chunk, lambda x: A @ x - b, b, x0,
                            nvars=A.shape[1], tol=tol,
                            max_iters=int(max_iters),
                            restart_every=restart_every)


def lsqr_operator(
    matvec: Callable,
    rmatvec: Callable,
    b: jnp.ndarray,
    *,
    nvars: int,
    R: Optional[jnp.ndarray] = None,
    x0: Optional[jnp.ndarray] = None,
    tol: float = 1e-6,
    max_iters: Optional[int] = None,
    restart_every: int = 50,
) -> SolveResult:
    """LSQR on an ABSTRACT operator given by injected matvec ops.

    Identical semantics to ``lsqr`` (chunked Golub–Kahan with
    exact-residual restarts), but ``A`` never has to exist as one dense
    array: ``matvec(v) -> (d,)`` and ``rmatvec(u) -> (n,)`` may be
    arbitrary closures — ``repro.distributed.dist_solvers`` injects
    ``shard_map``'d products over a row-sharded A, so the iteration runs
    with only matrix SLABS resident per device.

    Args:
      matvec / rmatvec: the base (un-preconditioned) operator products.
      b: (d,) right-hand side.
      nvars: n, the number of unknowns (``rmatvec`` output length).
      R / x0 / tol / max_iters / restart_every: as in ``lsqr``.

    Returns:
      ``SolveResult`` with the recomputed (not recurrence) final relres.
    """
    if max_iters is None:
        max_iters = 200 if R is not None else 4 * nvars
    has_R = R is not None
    R_arg = R if has_R else jnp.zeros(())

    # One jit per lsqr_operator call (the closures are fresh objects);
    # fine for the distributed use where a solve is few, large chunks.
    @functools.partial(jax.jit, static_argnames=("chunk",))
    def _chunk_jit(Rv, x, *, chunk):
        mv, rmv, unprec = _right_precond_ops(
            None, Rv if has_R else None, matvec=matvec, rmatvec=rmatvec)
        return _lsqr_recurrence(mv, rmv, unprec, matvec, b, x, nvars,
                                tol=float(tol), max_iters=chunk)

    def run_chunk(x, chunk):
        return _chunk_jit(R_arg, x, chunk=chunk)

    return _restarted_drive(run_chunk, lambda x: matvec(x) - b, b, x0,
                            nvars=nvars, tol=tol, max_iters=int(max_iters),
                            restart_every=restart_every)


def _restarted_drive(run_chunk, resid, b, x0, *, nvars, tol, max_iters,
                     restart_every) -> SolveResult:
    """Shared chunk driver: run ``restart_every``-iteration recurrence
    chunks, recompute the EXACT residual between chunks, warm-restart, and
    stop on convergence or stall (precision floor)."""
    bnorm = float(jnp.linalg.norm(b))
    x = x0
    total = 0
    relres = float("inf")
    while total < max_iters:
        chunk = min(int(restart_every), max_iters - total)
        x_new, it, _ = run_chunk(x, chunk)
        total += int(it)
        new_relres = float(jnp.linalg.norm(resid(x_new))) / max(bnorm, 1e-30)
        stalled = new_relres >= relres
        if new_relres < relres:
            x, relres = x_new, new_relres
        if relres <= tol:
            break
        if stalled:
            # the chunk produced no improvement, so x is unchanged and the
            # next chunk would deterministically recompute the identical
            # result — we are at the precision floor; stop now instead of
            # burning the rest of max_iters on byte-identical work
            break
    if x is None:               # max_iters == 0 edge case
        x = jnp.zeros(nvars, b.dtype)
    return SolveResult(x=x, iterations=total, relres=relres,
                       converged=relres <= tol)


@functools.partial(jax.jit, static_argnames=("tol", "max_iters"))
def _pcg_normal_jit(A, b, R, *, tol: float, max_iters: int):
    """CG on the preconditioned normal equations ``(AR⁻¹)ᵀ(AR⁻¹) y = (AR⁻¹)ᵀb``."""
    matvec, rmatvec, unprec = _right_precond_ops(A, R)
    dtype = b.dtype
    rhs = rmatvec(b)
    rhs_norm = jnp.maximum(jnp.linalg.norm(rhs), jnp.finfo(dtype).tiny)

    def normal_op(y):
        return rmatvec(matvec(y))

    y0 = jnp.zeros(A.shape[1], dtype)
    r0 = rhs
    state = (jnp.int32(0), y0, r0, r0, jnp.vdot(r0, r0))

    def cond(state):
        it, _, r, _, rr = state
        return jnp.logical_and(it < max_iters,
                               jnp.sqrt(rr) / rhs_norm > tol)

    def body(state):
        it, y, r, p, rr = state
        Ap = normal_op(p)
        alpha = rr / jnp.vdot(p, Ap)
        y = y + alpha * p
        r = r - alpha * Ap
        rr_next = jnp.vdot(r, r)
        p = r + (rr_next / rr) * p
        return (it + 1, y, r, p, rr_next)

    it, y, _, _, rr = jax.lax.while_loop(cond, body, state)
    return unprec(y), it, jnp.sqrt(rr) / rhs_norm


def pcg_normal(
    A: jnp.ndarray,
    b: jnp.ndarray,
    R: jnp.ndarray,
    tol: float = 1e-6,
    max_iters: int = 100,
) -> SolveResult:
    """Preconditioned CG on the normal equations (cheaper per-iter than
    LSQR — one fewer vector — but squares the effective condition number;
    safe here because ``A R⁻¹`` is near-orthonormal).

    Args as ``lsqr``, but ``tol`` is on the NORMAL-EQUATION residual
    ``||(AR⁻¹)ᵀ(Ax-b)||`` relative to ``||(AR⁻¹)ᵀb||`` — the natural CG
    quantity — and ``converged`` reports that criterion.  The returned
    ``relres`` is still the plain residual ``||Ax-b||/||b||`` for
    comparability with ``lsqr`` (it is NOT what ``converged`` tests).
    """
    x, it, normal_relres = _pcg_normal_jit(A, b, R, tol=float(tol),
                                           max_iters=int(max_iters))
    relres = float(jnp.linalg.norm(A @ x - b) / jnp.linalg.norm(b))
    return SolveResult(x=x, iterations=int(it), relres=relres,
                       converged=bool(float(normal_relres) <= tol))


def default_sketch_rows(n: int, sampling_factor: float = 4.0) -> int:
    """Sketch size k for an n-column problem (k = ⌈γ n⌉, γ ≈ 4 gives
    ε ≈ 1/2 distortion and ~20 LSQR iterations to 1e-14).  Delegates to
    the shared sizing rule in ``configs.flashsketch_paper``."""
    return flashsketch_paper.solver_sketch_rows(n, sampling_factor)


def _run_iteration(A, b, R, method, tol, max_iters) -> SolveResult:
    if method == "lsqr":
        return lsqr(A, b, R=R, tol=tol, max_iters=max_iters)
    if method == "cg":
        return pcg_normal(A, b, R, tol=tol, max_iters=max_iters)
    raise ValueError(f"method must be 'lsqr' or 'cg', got {method!r}")


def _diverged(res: SolveResult) -> bool:
    """Mid-solve divergence: the exact-residual chunk driver stopped
    without convergence at a residual no better than x = 0 (or NaN) — the
    preconditioner actively hurt, not merely underperformed."""
    import math
    return (not res.converged
            and (not math.isfinite(res.relres) or res.relres >= 1.0))


def sketch_precondition_lstsq(
    A: jnp.ndarray,
    b: jnp.ndarray,
    plan: Optional[BlockPermPlan] = None,
    *,
    k: Optional[int] = None,
    kappa: int = 4,
    s: Optional[int] = None,
    seed: int = 0,
    dtype: str = "float32",
    precision: Optional[object] = None,
    family: str = "blockperm",
    sampling_factor: float = 4.0,
    factorization: str = "qr",
    method: str = "lsqr",
    tol: float = 1e-6,
    max_iters: int = 100,
    impl: str = "auto",
    guard: bool = False,
    policy: Optional[object] = None,
    probe: bool = False,
) -> SolveResult:
    """Solve ``min_x ||A x - b||`` by sketch-and-precondition.

    Args:
      A: (d, n) tall matrix (d >> n).
      b: (d,) right-hand side.
      plan: optional pre-built sketch plan (wins over k/kappa/s/seed/dtype).
      k: sketch rows; default ``sampling_factor * n``.
      kappa, s, seed, dtype: BlockPerm-SJLT knobs (see ``make_plan``);
        κ/s/dtype trade sketch speed against preconditioner quality, i.e.
        against LSQR iteration count.
      precision: optional precision policy — a registered name/alias
        (``"fp8_e4m3_sr"``, ``"bf16"``, ...) or a ``core.precision.Precision``
        record.  Overrides ``dtype`` when given; the policy rides the plan,
        so lower-precision streaming surfaces directly as a higher
        ``.iterations`` count, and the guarded path reads its per-policy
        isometry/OSE tolerance bands (fp8 draws are judged against the
        widened fp8 bands, not the fp32 ones).
      family: sketch construction ("blockperm" | "countsketch" | "graph")
        — the preconditioning pipeline is family-parametric; the family
        rides the plan through every guard rung and re-sketch restart.
        ``s=None`` resolves to the family's CANONICAL nonzero count
        (``FAMILY_DEFAULT_S``: blockperm 2, countsketch 1, graph 4) and
        the plan seed is drawn from the family's disjoint seed stream —
        the same construction ``variants.make_sketch(family, ...)``
        builds, so e.g. countsketch and graph solves under one master
        seed are genuinely different sketches.
      factorization: "qr" | "chol" (see ``ops.sketch_qr``).
      method: "lsqr" | "cg".
      tol / max_iters: iteration stopping rule.
      impl: kernel dispatch for the sketch ("auto"|"pallas"|"pallas_v1"|"xla").
      guard: run the post-launch validators (``repro.health.guards``) on
        every sketch/factor and climb the ``RedrawPolicy`` escalation
        ladder on a ``failed`` verdict (re-draw seed → bump κ → bump the
        sampling factor — the paper's δ/κ tradeoff run in reverse); a
        diverging iteration additionally triggers a re-sketch restart.
        The guarded path attaches a ``HealthReport`` to ``.health``.
      policy: optional ``repro.health.policy.RedrawPolicy`` overriding the
        default escalation budget (ignored unless ``guard=True``).
      probe: with ``guard=True``, additionally run the O(d·n²) ground-truth
        OSE probe (σ_min of S·orth(A)) per attempt — the strictest
        acceptance check; off by default (the cheap guards catch the same
        catastrophic draws at a fraction of the cost).

    Returns:
      ``SolveResult``; ``.iterations`` is the paper's quality-vs-speed knob
      made visible (κ=1 sketches are fastest but precondition worst).
    """
    d, n = A.shape
    if precision is not None:
        from repro.core import precision as precision_mod
        dtype = precision_mod.canonical(
            precision.name if isinstance(precision, precision_mod.Precision)
            else precision)
    if s is None:
        # unknown families fall through to make_plan/family_stream, whose
        # ValueError names the valid set
        s = FAMILY_DEFAULT_S.get(family, 2)
    if plan is None and family != "blockperm":
        # match variants.make_sketch: non-blockperm families draw their
        # plan seed from the family's disjoint stream
        from repro.solvers.multisketch import derive_seed, family_stream
        seed = derive_seed(seed, 0, 0, stream=family_stream(family))
    if not guard:
        if plan is None:
            plan = make_plan(d, k or default_sketch_rows(n, sampling_factor),
                             kappa=kappa, s=s, seed=seed, dtype=dtype,
                             family=family)
        _, R = ops.sketch_qr(plan, A.astype(jnp.float32), impl,
                             factorization=factorization)
        res = _run_iteration(A, b, R.astype(b.dtype), method, tol, max_iters)
        # attach the record of how the sketch actually launched (trace-time
        # metadata only — the engine memoizes, so this re-lower is free)
        res.lowering = lowering.lower(
            plan, lowering.LaunchSpec(op="fwd", n=n, impl=impl))
        return res

    # ---- guarded path (eager by construction: guards read values) -------
    from repro.health import guards
    from repro.health import report as health_report
    from repro.health.policy import RedrawPolicy

    pol = policy if policy is not None else RedrawPolicy()
    rpt = health_report.HealthReport(op="sketch_precondition_lstsq")
    A32 = A.astype(jnp.float32)
    base_seed = plan.seed if plan is not None else seed
    base_kappa = plan.kappa if plan is not None else kappa
    base_s = plan.s if plan is not None else s
    base_k = plan.k_req if plan is not None else k
    base_family = plan.family if plan is not None else family

    def draw_and_check(p):
        """Sketch + factor + guard verdict for one attempt's plan."""
        SA, R = ops.sketch_qr(p, A32, impl, factorization=factorization)
        # judge the draw against ITS policy's tolerance bands — an fp8
        # sketch that lands inside the widened fp8 band is a healthy fp8
        # sketch, not a degraded fp32 one
        findings = [guards.finite_guard(SA, "SA"),
                    guards.isometry_guard(A32, SA, "SA",
                                          **p.precision.isometry_band()),
                    guards.finite_guard(R, "R"),
                    guards.r_condition_guard(R, "R")]
        if probe:
            findings.append(guards.ose_probe(p, A32, impl=impl,
                                             **p.precision.ose_band()))
        findings = [f for f in findings if f is not None]
        for f in findings:
            rpt.add(f)
        verdict = health_report.worst_status(
            *[f.status for f in findings]) if findings else \
            health_report.HEALTHY
        return R, verdict

    accepted = None          # (plan, R)
    best = None              # least-bad fallback if the budget exhausts
    best_rank = len(health_report.STATUS_ORDER)
    for attempt in pol.attempts(seed=base_seed, kappa=base_kappa,
                                sampling_factor=sampling_factor):
        if attempt.index == 0 and plan is not None:
            p = plan
        else:
            p = pol.plan_for(attempt, d, n, s=base_s, dtype=dtype, k=base_k,
                             family=base_family)
        pol.record(attempt)
        if attempt.index > 0:
            rpt.act(attempt.describe())
        rpt.attempts += 1
        R, verdict = draw_and_check(p)
        rank = health_report.STATUS_ORDER.index(verdict)
        if rank < best_rank:
            best, best_rank = (p, R), rank
        if pol.accepts(verdict):
            accepted = (p, R)
            break
    if accepted is None:
        # every rung failed: proceed with the least-bad draw rather than
        # silently returning garbage or raising — the report says so.
        accepted = best
        rpt.act("escalation_budget_exhausted")
        health_report.record("policy.budget_exhausted")
    p, R = accepted
    res = _run_iteration(A, b, R.astype(b.dtype), method, tol, max_iters)

    # Mid-solve divergence → re-sketch restart (the multisketch restart
    # rule applied to the guarded single-sketch solver): an accepted factor
    # whose iteration still diverges means the draw was bad in a way the
    # cheap guards missed; throw it away and re-draw from a disjoint seed
    # stream.
    from repro.solvers.multisketch import derive_seed, \
        family_stream   # lazy: no cycle
    restarts = 0
    while _diverged(res) and restarts < pol.max_resketch_restarts:
        restarts += 1
        new_seed = derive_seed(p.seed, pol.budget + restarts, 3,
                               stream=family_stream(p.family))
        p = make_plan(d, p.k_req, kappa=p.kappa, s=p.s, seed=new_seed,
                      dtype=dtype, family=p.family)
        rpt.act(f"resketch_restart(seed={new_seed})")
        health_report.record("policy.resketch_restart")
        R, verdict = draw_and_check(p)
        rpt.attempts += 1
        res = _run_iteration(A, b, R.astype(b.dtype), method, tol, max_iters)

    res.health = rpt
    res.lowering = lowering.lower(
        p, lowering.LaunchSpec(op="fwd", n=n, impl=impl))
    return res
