"""One-shot sketch-and-solve: regression and low-rank approximation.

Unlike sketch-and-precondition (which iterates to machine precision),
sketch-and-solve answers from the sketch alone: solve the small sketched
problem and accept a ``(1+ε)``-optimal answer, where ε is the sketch's
subspace-embedding distortion (ε ≈ √(n/k) for a k-row sketch of an
n-dimensional subspace).  One pass over A, no iterations — the right tool
when A is streamed once or a few digits suffice.

The low-rank path is the sketched randomized range-finder: a row-space
sketch ``B = S A`` captures the dominant right-singular subspace of A
(Halko–Martinsson–Tropp, single-pass variant), and projecting A onto it
reduces the SVD to a tall-thin problem.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.blockperm import BlockPermPlan
from repro.kernels import ops


def subspace_embedding_eps(plan: BlockPermPlan, n: int) -> float:
    """Heuristic embedding distortion ε of the plan for an n-dim subspace.

    Sparse-sign embeddings with κs nonzeros/column behave like ε ≈ √(n/k)
    once κs ≥ 2 (Cohen's bound, constants ≈ 1 in practice); a κs = 1
    (single-permutation, s=1) sketch is a weaker OSNAP and gets a 2×
    penalty.  Used for sanity bounds and adaptive-restart budgeting, not
    as a guarantee.
    """
    base = math.sqrt(n / max(plan.k, 1))
    return min(2.0 * base if plan.nnz_per_col < 2 else base, 0.99)


def sketch_and_solve_lstsq(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    b: jnp.ndarray,
    impl: str = "auto",
) -> jnp.ndarray:
    """Direct sketch-and-solve regression: ``argmin_x ||S A x - S b||``.

    A and b are sketched TOGETHER in one kernel launch (b rides along as an
    extra column), then the small ``(k, n)`` problem is solved by QR-based
    lstsq.  Residual guarantee: ``||A x̂ - b|| ≤ (1+ε)/(1-ε) · min_x ||A x - b||``
    when S is an ε-embedding of ``range([A | b])``.

    Args:
      plan: sketch plan with ``plan.k ≳ 4 (n+1)`` rows for a useful ε.
      A: (d, n); b: (d,).
      impl: kernel dispatch (see ``ops.sketch_apply``).

    Returns:
      x̂ (n,), in fp32 (the sketched problem is solved in fp32).
    """
    Ab = jnp.concatenate([A, b[:, None]], axis=1).astype(jnp.float32)
    SAb = ops.sketch_apply(plan, Ab, impl)
    SA, Sb = SAb[:, :-1], SAb[:, -1]
    return jnp.linalg.lstsq(SA, Sb)[0]


def sketched_rowspace(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    rank: int,
    impl: str = "auto",
) -> jnp.ndarray:
    """Orthonormal basis V (n, rank) of the approximate dominant row space.

    ``B = S A`` is a (k, n) row-space sketch of A; the top right-singular
    vectors of B approximate those of A when S embeds the corresponding
    subspace.  This is the single-pass range-finder primitive behind
    ``sketched_svd``.
    """
    B = ops.sketch_apply(plan, A.astype(jnp.float32), impl)     # (k, n)
    _, _, Vt = jnp.linalg.svd(B, full_matrices=False)
    return Vt[:rank].T                                          # (n, rank)


def sketched_svd(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    rank: int,
    oversample: int = 8,
    impl: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sketched low-rank SVD: ``A ≈ U diag(s) Vt`` with ``U (d, rank)``.

    Pipeline: row-space sketch ``B = S A`` (one FlashSketch launch — the
    expensive O(d·n) sketch work) → orthonormal ``V`` from B's top
    ``rank + oversample`` right-singular vectors → project ``C = A V``
    (tall-thin, d × (rank+oversample)) → exact SVD of C, truncated.

    Args:
      plan: sketch plan; needs ``plan.k ≥ rank + oversample`` (more rows →
        tighter spectral capture).
      A: (d, n) with d >> n.
      rank: target rank r.
      oversample: extra range-finder columns p (standard HMT slack).
      impl: kernel dispatch.

    Returns:
      (U, s, Vt): (d, r), (r,), (r, n) — the rank-r approximation
      ``U @ diag(s) @ Vt ≈ A``, exact when A has rank ≤ r and the sketch
      preserves its row space.
    """
    ell = min(rank + oversample, min(A.shape))
    if plan.k < ell:
        raise ValueError(
            f"plan.k={plan.k} must be >= rank+oversample={ell} "
            f"for the range-finder to capture the subspace")
    V = sketched_rowspace(plan, A, ell, impl)                   # (n, ℓ)
    C = A.astype(jnp.float32) @ V                               # (d, ℓ)
    U, svals, Wt = jnp.linalg.svd(C, full_matrices=False)
    Vt = (V @ Wt.T).T                                           # (ℓ, n)
    return U[:, :rank], svals[:rank], Vt[:rank]
