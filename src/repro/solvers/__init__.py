"""RandNLA solvers built on the FlashSketch kernels (paper §7 "standard
RandNLA benchmarks" — overdetermined least squares and low-rank
approximation driven by the sketch).

Three layers, lowest to highest risk/speed:

  sketch_precondition — sketch → QR/Cholesky factor → preconditioned
                        LSQR/CG to machine-precision least squares
                        (Rokhlin–Tygert / Blendenpik lineage; the
                        GPU-friendly formulation of Chen et al. 2025,
                        arXiv:2506.03070)
  sketch_solve        — direct sketch-and-solve regression and sketched
                        randomized range-finder / low-rank SVD (one-shot,
                        residual within (1+ε) of optimal)
  multisketch         — independent-seed multisketching with
                        residual-based adaptive restarts (Higgins & Boman
                        2025, arXiv:2508.14209)

All solvers consume ``core.blockperm.make_plan`` plans and apply the sketch
through ``kernels.ops`` (Pallas on TPU, XLA oracle on CPU), so the paper's
κ/s/dtype quality-vs-speed knobs surface directly in iteration counts.
"""
from repro.solvers.sketch_precondition import (  # noqa: F401
    SolveResult,
    lsqr,
    lsqr_operator,
    pcg_normal,
    sketch_precondition_lstsq,
)
from repro.solvers.sketch_solve import (  # noqa: F401
    sketch_and_solve_lstsq,
    sketched_rowspace,
    sketched_svd,
    subspace_embedding_eps,
)
from repro.solvers.multisketch import (  # noqa: F401
    MultisketchResult,
    multisketch_apply,
    multisketch_lstsq,
    multisketch_plans,
)


def solve_preset(A, b, preset, *, seed: int = 0, impl: str = "auto"):
    """Run a named solver operating point from
    ``configs.flashsketch_paper.SOLVER_PRESETS`` on ``min ||A x - b||``.

    Args:
      A, b: the (d, n) / (d,) problem.
      preset: a preset name (``"precise" | "default" | "fast" | "direct" |
        "multisketch"``) or a ``SolverPreset`` instance.
      seed: master sketch seed.
      impl: kernel dispatch forwarded to the sketch.

    Returns:
      ``SolveResult`` (iterative presets), ``MultisketchResult``
      (``num_sketches > 1``), or — for ``method="direct"`` — a
      ``SolveResult`` with ``iterations=0`` and ``converged=True``
      (one-shot: the answer is (1+ε)-optimal by construction, there is no
      tolerance to iterate toward).
    """
    import jax.numpy as jnp

    from repro.configs.flashsketch_paper import SOLVER_PRESETS, solver_sketch_rows
    from repro.core.blockperm import make_plan
    from repro.solvers.sketch_precondition import SolveResult

    if isinstance(preset, str):
        preset = SOLVER_PRESETS[preset]
    d, n = A.shape
    k = solver_sketch_rows(n, preset.sampling_factor)
    if preset.method == "direct":
        plan = make_plan(d, k, kappa=preset.kappa, s=preset.s, seed=seed,
                         dtype=preset.dtype)
        x = sketch_and_solve_lstsq(plan, A, b, impl=impl)
        relres = float(jnp.linalg.norm(A @ x - b) / jnp.linalg.norm(b))
        return SolveResult(x=x, iterations=0, relres=relres, converged=True)
    if preset.num_sketches > 1:
        return multisketch_lstsq(
            A, b, k_each=k, t=preset.num_sketches, kappa=preset.kappa,
            s=preset.s, seed=seed, dtype=preset.dtype, tol=preset.tol,
            factorization=preset.factorization, impl=impl)
    return sketch_precondition_lstsq(
        A, b, k=k, kappa=preset.kappa, s=preset.s, seed=seed,
        dtype=preset.dtype, factorization=preset.factorization,
        method=preset.method, tol=preset.tol, max_iters=preset.max_iters,
        impl=impl)
