"""Data pipeline: deterministic, shard-aware synthetic token streams."""
