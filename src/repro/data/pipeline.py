"""Deterministic shard-aware synthetic LM data pipeline.

Properties a production input pipeline must have, implemented + tested here:
  * determinism: batch(step) is a pure function of (seed, step) — restart at
    step k reproduces the exact stream (required for checkpoint/restart);
  * shard-awareness: host i materializes only its slice of the global batch
    (``host_batch_slice``), no host ever holds the global array;
  * learnable structure: tokens follow a stationary bigram process, so a real
    model trained on it shows a decreasing loss (used by examples/train_lm).
  * prefetch: a small background double-buffer (thread) hides host latency.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    bigram_alpha: float = 0.9      # strength of the learnable structure


def _bigram_next_state(cfg: DataConfig):
    """Fixed random bigram table: next(v) = perm[v] with prob alpha."""
    rng = np.random.default_rng(cfg.seed + 0xB16)
    return rng.permutation(cfg.vocab_size)


def host_batch_slice(cfg: DataConfig, host_id: int, n_hosts: int) -> Tuple[int, int]:
    per = cfg.global_batch // n_hosts
    return host_id * per, per


def make_batch(cfg: DataConfig, step: int, host_id: int = 0,
               n_hosts: int = 1) -> Dict[str, np.ndarray]:
    """Pure function of (cfg, step, host): the host-local batch slice."""
    start, per = host_batch_slice(cfg, host_id, n_hosts)
    perm = _bigram_next_state(cfg)
    out_tok = np.empty((per, cfg.seq_len + 1), np.int32)
    for i in range(per):
        row_rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 131_071 + (start + i))
        toks = np.empty(cfg.seq_len + 1, np.int32)
        toks[0] = row_rng.integers(cfg.vocab_size)
        noise = row_rng.random(cfg.seq_len)
        rand_tok = row_rng.integers(cfg.vocab_size, size=cfg.seq_len)
        for t in range(cfg.seq_len):
            toks[t + 1] = perm[toks[t]] if noise[t] < cfg.bigram_alpha \
                else rand_tok[t]
        out_tok[i] = toks
    return {"tokens": out_tok[:, :-1], "labels": out_tok[:, 1:]}


class Prefetcher:
    """Double-buffered background batch producer."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 host_id: int = 0, n_hosts: int = 1, depth: int = 2):
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def work():
            step = start_step
            while not self._stop.is_set():
                batch = make_batch(cfg, step, host_id, n_hosts)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def __next__(self):
        return self._q.get()

    def __iter__(self) -> Iterator:
        return self

    def close(self):
        self._stop.set()
