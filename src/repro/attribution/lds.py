"""Linear datamodeling score (LDS) — TRAK / GraSS evaluation metric (App. E.2).

LDS(τ, z) = Spearman-ρ( {f(z; θ*(S_j))}_j , {Σ_{i∈S_j} τ(z)_i}_j )
over m random α-fraction subsets S_j, averaged over test examples z.
"""
from __future__ import annotations

from typing import Callable, List

import numpy as np


def _rank(a: np.ndarray) -> np.ndarray:
    """Average-rank transform (ties get mean rank) along the last axis."""
    order = np.argsort(a, axis=-1, kind="stable")
    ranks = np.empty_like(order, dtype=np.float64)
    n = a.shape[-1]
    arange = np.arange(n, dtype=np.float64)
    np.put_along_axis(ranks, order, arange, axis=-1)
    # tie correction: average ranks within equal-value groups
    sorted_vals = np.take_along_axis(a, order, axis=-1)
    out = ranks.copy()
    for idx in np.ndindex(a.shape[:-1]):
        sv = sorted_vals[idx]
        r = ranks[idx]
        i = 0
        while i < n:
            j = i
            while j + 1 < n and sv[j + 1] == sv[i]:
                j += 1
            if j > i:
                mean_rank = (i + j) / 2.0
                for t in range(i, j + 1):
                    out[idx][order[idx][t]] = mean_rank
            i = j + 1
    return out


def spearman(a: np.ndarray, b: np.ndarray) -> float:
    """Spearman rank correlation of two 1-D sequences."""
    a = np.asarray(a, np.float64).reshape(-1)
    b = np.asarray(b, np.float64).reshape(-1)
    ra, rb = _rank(a), _rank(b)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra ** 2).sum() * (rb ** 2).sum())
    if denom == 0:
        return 0.0
    return float((ra * rb).sum() / denom)


def sample_subsets(n_train: int, m: int, alpha: float, seed: int = 0) -> np.ndarray:
    """(m, n_train) boolean masks, each keeping an α fraction."""
    rng = np.random.default_rng(seed)
    keep = int(round(alpha * n_train))
    masks = np.zeros((m, n_train), bool)
    for j in range(m):
        idx = rng.choice(n_train, size=keep, replace=False)
        masks[j, idx] = True
    return masks


def lds_score(true_outputs: np.ndarray, tau: np.ndarray,
              masks: np.ndarray) -> float:
    """true_outputs: (m, n_test) counterfactual f(z;θ*(S_j));
    tau: (n_test, n_train) attribution scores; masks: (m, n_train)."""
    m, n_test = true_outputs.shape
    preds = tau @ masks.T.astype(np.float64)            # (n_test, m)
    scores: List[float] = []
    for z in range(n_test):
        scores.append(spearman(true_outputs[:, z], preds[z]))
    return float(np.mean(scores))
