"""The GraSS evaluation model: a 3-layer ReLU MLP (paper App. E.2 uses
109,386 params on MNIST; smoke tests shrink it) + a plain training loop used
both for the base model and the m=50 LDS retrainings."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_in: int = 784
    hidden: Tuple[int, ...] = (128, 64)
    n_classes: int = 10
    lr: float = 0.05
    steps: int = 120
    seed: int = 0


def init_mlp(cfg: MLPConfig, key) -> Dict:
    dims = (cfg.d_in, *cfg.hidden, cfg.n_classes)
    params = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k1 = jax.random.split(key)
        params[f"w{i}"] = jax.random.normal(k1, (a, b), jnp.float32) / np.sqrt(a)
        params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
    return params


def mlp_apply(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    n = len(params) // 2
    h = x
    for i in range(n):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def nll_loss(params, x, y):
    logits = mlp_apply(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def margin_output(params, x, y):
    """TRAK's scalar model output f(z;θ): correct-class margin."""
    logits = mlp_apply(params, x)
    gold = jnp.take_along_axis(logits, y[:, None], axis=1)[:, 0]
    other = logits - 1e9 * jax.nn.one_hot(y, logits.shape[-1])
    return gold - jax.nn.logsumexp(other, axis=-1)


def train_mlp(cfg: MLPConfig, x, y, key=None,
              mask: Optional[np.ndarray] = None) -> Dict:
    """Full-batch GD training (optionally on a row subset — LDS retrains)."""
    key = key if key is not None else jax.random.PRNGKey(cfg.seed)
    if mask is not None:
        x = x[mask]
        y = y[mask]
    params = init_mlp(cfg, key)
    grad_fn = jax.jit(jax.grad(nll_loss))

    @jax.jit
    def step(p, _):
        g = jax.grad(nll_loss)(p, x, y)
        return jax.tree.map(lambda a, b: a - cfg.lr * b, p, g), None

    params, _ = jax.lax.scan(step, params, None, length=cfg.steps)
    return params


def make_synthetic_mnist(n: int, d: int = 784, n_classes: int = 10,
                         seed: int = 0, noise: float = 1.2):
    """Class-centered Gaussian clusters: learnable, MNIST-shaped."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_classes, d)).astype(np.float32)
    y = rng.integers(n_classes, size=n).astype(np.int32)
    x = centers[y] + noise * rng.normal(size=(n, d)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)
