"""GraSS data-attribution pipeline (paper §7.4 / App. E) on FlashSketch."""
