"""GraSS: per-example gradient → sparsify → sketch → feature cache →
attribution (paper §7.4 / App. E).  The sparsify→sketch step — the paper's
measured bottleneck — runs on the gather-fused batched FlashSketch path:
per-example gradients are produced in ``lax.scan`` chunks (vmapped inside
each chunk), and every chunk is sketched in ONE kernel launch that gathers
the sparsify mask's coordinates directly out of the stacked gradients — no
``grads[:, mask]`` intermediate, no per-example launches.  Any variant from
``repro.core.variants`` can be swapped in for the Pareto benchmarks
(families without a fused kernel fall back to a materializing gather).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.attribution import mlp as mlp_lib
from repro.core import hashing
from repro.core.variants import SketchBase, make_sketch
from repro.health import report as health_report


@dataclasses.dataclass(frozen=True)
class GrassPipelineConfig:
    sparse_dim: int = 4096         # gradient sparsification target (App. E)
    sketch_dim: int = 1024         # k
    sketch_family: str = "blockperm"
    sketch_kwargs: tuple = ()      # extra (key, value) pairs
    seed: int = 0
    attribution: str = "dot"       # "dot" | "kernel" (TRAK preconditioned)
    lam_rel: float = 1.0           # kernel ridge relative to mean eigenvalue
    chunk: int = 64                # examples per scan step / fused launch
    fused: bool = True             # gather-fused sketch (False: materialize
                                   # grads[:, mask] — the pre-fusion path,
                                   # kept for A/B tests and benchmarks)


def _flat_grad_fn(params):
    """Per-example gradient of the margin output, flattened."""
    def gfn(p, x, y):
        g = jax.grad(lambda pp: mlp_lib.margin_output(pp, x[None], y[None])[0])(p)
        return jnp.concatenate([a.reshape(-1) for a in jax.tree.leaves(g)])
    return gfn


def sparsify_mask(d_total: int, d_keep: int, seed: int) -> jnp.ndarray:
    """GraSS gradient sparsification: a fixed random coordinate subset.

    Selects the d_keep coordinates with the SMALLEST hash scores via
    ``lax.top_k`` on the bitwise complement — O(d log k) with no d-length
    sort buffer, and bitwise-identical to the historical full
    ``argsort(scores)[:d_keep]`` (uint32 complement reverses the order
    exactly; both break ties toward the lower index).
    """
    u = jnp.arange(d_total, dtype=jnp.uint32)
    scores = hashing.hash_words(np.uint32(seed), np.uint32(0x6A55), u)
    _, idx = jax.lax.top_k(~scores, d_keep)
    return jnp.sort(idx)


class GrassPipeline:
    """Feature-cache builder around the fused batched sketch.

    ``featurize`` runs the per-example gradients in ``cfg.chunk``-sized
    ``lax.scan`` steps (vmap inside the step), each chunk feeding one
    gather-fused batched sketch launch; the feature cache is assembled
    chunk by chunk.  With ``cfg.fused=False`` the same scan materializes
    ``grads[:, mask]`` before sketching (the seed behavior, bit-compatible
    features).

    Multi-device: pass ``mesh``/``shard_axis`` to BATCH-SHARD featurize
    over the chunk axis — every device scans its own chunks (params and
    mask replicated, no collective; examples are independent), so the
    feature cache builds P× wider per step.  Features are identical to the
    single-device run (chunks are computed by the same per-chunk launch
    either way).

    Health: a per-example gradient with any non-finite entry (a NaN-poisoned
    batch element, an overflowed activation) is QUARANTINED in-kernel —
    zeroed before the sketch, so one bad example contributes nothing instead
    of poisoning its whole chunk's feature block — and counted
    (``.quarantined``, plus the process-wide ``grass.quarantined`` health
    counter).  The mask is computed inside the jitted scan (a ``jnp.where``
    per chunk), so the guarded path costs one finiteness reduction per
    gradient row.
    """

    def __init__(self, cfg: GrassPipelineConfig, params, mesh=None,
                 shard_axis: str = "data"):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.quarantined = 0           # rows zeroed across all featurize calls
        d_total = sum(p.size for p in jax.tree.leaves(params))
        self.d_total = d_total
        d_keep = min(cfg.sparse_dim, d_total)
        self.mask = sparsify_mask(d_total, d_keep, cfg.seed)
        self.sketch: SketchBase = make_sketch(
            cfg.sketch_family, d_keep, cfg.sketch_dim, seed=cfg.seed,
            **dict(cfg.sketch_kwargs))
        self._gfn = _flat_grad_fn(params)

        def sketch_chunk(grads):                    # (c, D) -> (c, k)
            if cfg.fused:
                return self.sketch.apply_gather(grads.T, self.mask).T
            return self.sketch.apply(grads[:, self.mask].T).T

        def featurize(p, xs, ys):
            b = xs.shape[0]
            c = max(1, min(cfg.chunk, b))
            n_chunks = -(-b // c)
            if mesh is not None:
                # batch-sharded: every device scans n_chunks/P chunks
                n_dev = mesh.shape[shard_axis]
                n_chunks = -(-n_chunks // n_dev) * n_dev
            pad = n_chunks * c - b
            if pad:
                # repeat the first example: gradients stay well-defined and
                # the padded features are sliced off below
                xs = jnp.concatenate([xs, jnp.broadcast_to(
                    xs[:1], (pad,) + xs.shape[1:])])
                ys = jnp.concatenate([ys, jnp.broadcast_to(
                    ys[:1], (pad,) + ys.shape[1:])])
            xc = xs.reshape((n_chunks, c) + xs.shape[1:])
            yc = ys.reshape((n_chunks, c) + ys.shape[1:])

            def chunk_feats(p_, xy):
                """One chunk: vmapped per-example grads -> quarantine ->
                fused sketch.  The SAME body drives both branches, so
                sharded features cannot drift from single-device ones.
                Non-finite gradient rows are zeroed (quarantined) before
                the sketch and flagged — jit-compatible (a where, not a
                branch)."""
                xb, yb = xy
                grads = jax.vmap(lambda x, y: self._gfn(p_, x, y))(xb, yb)
                ok = jnp.all(jnp.isfinite(grads), axis=1)
                grads = jnp.where(ok[:, None], grads, 0.0)
                return sketch_chunk(grads), ~ok     # (c, k), (c,) per chunk

            if mesh is None:
                _, (feats, bad) = jax.lax.scan(
                    lambda car, xy: (car, chunk_feats(p, xy)), 0, (xc, yc))
            else:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P

                def scan_local(p_, xcl, ycl):
                    _, fb = jax.lax.scan(
                        lambda car, xy: (car, chunk_feats(p_, xy)),
                        0, (xcl, ycl))
                    return fb

                feats, bad = shard_map(
                    scan_local, mesh=mesh,
                    in_specs=(P(), P(shard_axis), P(shard_axis)),
                    out_specs=(P(shard_axis), P(shard_axis)),
                    check_rep=False,
                )(p, xc, yc)
            # padded tail rows are sliced off BEFORE the bad-row count, so
            # a quarantined example is never double-counted via its padding
            # copies
            return (feats.reshape(n_chunks * c, -1)[:b],
                    bad.reshape(n_chunks * c)[:b])

        self._featurize = jax.jit(featurize)

    def featurize(self, xs, ys) -> jnp.ndarray:
        """Sketched features for a batch; quarantines non-finite rows.

        Returns the ``(b, k)`` feature block.  Rows whose per-example
        gradient contained any non-finite entry come back as zeros and are
        added to ``.quarantined`` / the ``grass.quarantined`` counter.
        """
        feats, bad = self._featurize(self.params, xs, ys)
        self._note_quarantine(bad)
        return feats

    def _note_quarantine(self, bad) -> None:
        nbad = int(np.asarray(bad).sum())
        if nbad:
            self.quarantined += nbad
            health_report.record("grass.quarantined", n=nbad,
                                 detail=f"{nbad} non-finite gradient rows "
                                        f"zeroed before sketch")

    def health(self) -> health_report.HealthReport:
        """A ``HealthReport`` summarizing this pipeline's quarantine state."""
        rpt = health_report.HealthReport(op="featurize",
                                         quarantined=self.quarantined)
        if self.quarantined:
            rpt.add(health_report.GuardFinding(
                "finite", "grads", health_report.DEGRADED,
                value=float(self.quarantined),
                detail=f"{self.quarantined} gradient rows quarantined"))
        return rpt

    def sketch_lowering(self):
        """The ``kernels.lowering.Lowering`` record of one featurize-chunk
        sketch launch — how the sparsify→sketch step actually runs (fused
        gather or materialized, which kernel, which tile).  ``None`` for
        sketch families without a FlashSketch kernel (they run as plain
        XLA ops).  Inspect with ``.describe()`` or price it with
        ``repro.engine.cost_of``."""
        return self.sketch.lowering_for(max(1, self.cfg.chunk),
                                        gather=self.cfg.fused)

    # ---------------------------------------------------------------- cache
    def build_cache(self, x_train, y_train, batch: int = 256) -> Tuple[jnp.ndarray, float]:
        """Feature cache Φ ∈ (n_train, k); returns (cache, sketch_seconds).

        Each ``batch`` slab runs one jitted scan whose per-chunk fused
        launches write the cache incrementally (chunk size ``cfg.chunk``).
        """
        feats = []
        t = 0.0
        for i in range(0, x_train.shape[0], batch):
            xb = x_train[i:i + batch]
            yb = y_train[i:i + batch]
            t0 = time.perf_counter()
            f, bad = self._featurize(self.params, xb, yb)
            f.block_until_ready()
            t += time.perf_counter() - t0
            self._note_quarantine(bad)
            feats.append(f)
        return jnp.concatenate(feats, axis=0), t

    # ----------------------------------------------------------- attribution
    def attribute(self, cache: jnp.ndarray, x_test, y_test) -> np.ndarray:
        """τ(z)_i: sketched-gradient similarity.

        "dot":    τ = φ_z · φ_i           (GraSS default; robust at small n)
        "kernel": τ = φ_zᵀ (ΦᵀΦ + λI)⁻¹ φ_i  (TRAK preconditioning; λ set
                  relative to the mean kernel eigenvalue).
        """
        phi_z = self.featurize(x_test, y_test)                   # (nt, k)
        if self.cfg.attribution == "dot":
            tau = phi_z @ cache.T                                # (nt, n_train)
            return np.asarray(tau)
        k = cache.shape[1]
        K = cache.T @ cache
        lam = self.cfg.lam_rel * jnp.trace(K) / k
        sol = jnp.linalg.solve(K + lam * jnp.eye(k), phi_z.T)    # (k, nt)
        tau = cache @ sol                                        # (n_train, nt)
        return np.asarray(tau.T)                                 # (nt, n_train)


def run_grass_lds(
    pipe_cfg: GrassPipelineConfig,
    mlp_cfg: mlp_lib.MLPConfig,
    n_train: int = 512,
    n_test: int = 32,
    m_subsets: int = 20,
    alpha: float = 0.5,
    seed: int = 0,
) -> Dict[str, float]:
    """End-to-end GraSS + LDS evaluation (the paper Fig. 4 pipeline)."""
    from repro.attribution import lds as lds_lib

    x, y = mlp_lib.make_synthetic_mnist(n_train + n_test, mlp_cfg.d_in,
                                        mlp_cfg.n_classes, seed=seed)
    x_tr, y_tr = x[:n_train], y[:n_train]
    x_te, y_te = x[n_train:], y[n_train:]

    base = mlp_lib.train_mlp(mlp_cfg, x_tr, y_tr)
    pipe = GrassPipeline(pipe_cfg, base)
    cache, sketch_s = pipe.build_cache(x_tr, y_tr)
    tau = pipe.attribute(cache, x_te, y_te)

    masks = lds_lib.sample_subsets(n_train, m_subsets, alpha, seed)
    true_out = np.empty((m_subsets, n_test))
    for j in range(m_subsets):
        pj = mlp_lib.train_mlp(mlp_cfg, x_tr, y_tr,
                               key=jax.random.PRNGKey(1000 + j),
                               mask=masks[j])
        true_out[j] = np.asarray(mlp_lib.margin_output(pj, x_te, y_te))
    score = lds_lib.lds_score(true_out, tau, masks)
    return {
        "lds": score,
        "sketch_seconds": sketch_s,
        "sketch_family": pipe_cfg.sketch_family,
        "k": pipe_cfg.sketch_dim,
        "per_sample_us": 1e6 * sketch_s / n_train,
    }
