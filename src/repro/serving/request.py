"""Request/response vocabulary of the sketch server.

The serving contract (the acceptance gate of the fault-injection-under-
load bench) is: NO SILENT FAILURES.  Every submitted request terminates
in exactly one of the explicit states below, and any response whose
result was touched by a guard failure, a retry, or a degradation rung
carries a non-healthy ``HealthReport`` — a degraded or failed sketch is
a *flagged* response, never a quietly wrong array.

Terminal statuses:

  * ``ok``        — served; first draw, no downgrades, all guards healthy.
  * ``degraded``  — served, but something non-default happened: a redraw
                    recovered a bad draw, a degradation rung (bf16 / κ
                    drop / breaker-suppressed retries) changed the launch,
                    or a guard returned a degraded verdict.  The report
                    says exactly what.
  * ``failed``    — served best-effort (or not at all) after an
                    unrecoverable guard failure — e.g. a NaN-poisoned
                    operand that no redraw can fix.  ``result`` may be
                    unusable; the report says why.
  * ``shed``      — rejected at admission: the bounded queue was full
                    (the load-shedding half of admission control).
  * ``deadline``  — rejected: the per-request deadline expired before or
                    during service and no usable result was produced in
                    time.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Optional

from repro.health.report import HealthReport

OK = "ok"
DEGRADED = "degraded"
FAILED = "failed"
SHED = "shed"
DEADLINE = "deadline"

TERMINAL_STATUSES = (OK, DEGRADED, FAILED, SHED, DEADLINE)
#: statuses whose ``result`` is meant to be used by the caller
SERVED_STATUSES = (OK, DEGRADED)
#: explicit rejections (no result; the caller must retry or give up)
REJECTED_STATUSES = (SHED, DEADLINE)

_IDS = itertools.count()


@dataclasses.dataclass
class SketchRequest:
    """One tenant request: sketch (``Y = S A``) or solve (``min ‖Ax−b‖``).

    Attributes:
      tenant:   tenant id — scopes the plan cache and the circuit breaker.
      kind:     ``"sketch"`` | ``"solve"``.
      operand:  ``(d, n)`` array (``A``).
      rhs:      ``(d,)`` right-hand side, solve requests only.
      plan_params: sketch-plan knobs ``{d, k, kappa, s, seed, dtype,
                family}`` — resolved through the tenant's plan cache so
                identical specs share one frozen plan (and therefore one
                coalescing group).
      deadline_s: RELATIVE deadline budget in seconds from arrival
                (``None`` = no deadline).
      arrival_s / deadline_at: stamped by the server at submit (clock
                time); ``deadline_at`` is absolute.
      request_id: unique per process (monotone counter).
    """

    tenant: str
    kind: str
    operand: Any
    plan_params: Dict[str, Any]
    rhs: Any = None
    deadline_s: Optional[float] = None
    solver_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # stamped by the server:
    arrival_s: float = 0.0
    deadline_at: Optional[float] = None
    request_id: int = dataclasses.field(default_factory=lambda: next(_IDS))

    def expired(self, now: float) -> bool:
        return self.deadline_at is not None and now > self.deadline_at

    def remaining(self, now: float) -> float:
        """Seconds of deadline budget left (+inf when no deadline)."""
        if self.deadline_at is None:
            return float("inf")
        return self.deadline_at - now


@dataclasses.dataclass
class SketchResponse:
    """Terminal outcome of one request — always explicit, never silent.

    ``health`` is attached to EVERY served response; ``status`` is
    derived from it (`ok` requires a clean report).  Rejections
    (``shed``/``deadline``) carry a report too when guards already ran.
    """

    request_id: int
    tenant: str
    kind: str
    status: str
    result: Any = None
    health: Optional[HealthReport] = None
    latency_s: float = float("nan")
    batch_size: int = 0            # coalesced group size that served it
    attempts: int = 0              # sketch draws consumed for this request
    detail: str = ""

    @property
    def served(self) -> bool:
        return self.status in SERVED_STATUSES

    @property
    def rejected(self) -> bool:
        return self.status in REJECTED_STATUSES

    @property
    def flagged(self) -> bool:
        """Anything non-default happened (the no-silent-failures bit):
        a non-``ok`` status, or a health report with findings beyond
        uniformly-healthy first-attempt guards."""
        if self.status != OK:
            return True
        return self.health is not None and (
            self.health.actions
            or any(f.status != "healthy" for f in self.health.findings))

    def to_json(self) -> Dict:
        return {
            "request_id": self.request_id,
            "tenant": self.tenant,
            "kind": self.kind,
            "status": self.status,
            "latency_s": self.latency_s,
            "batch_size": self.batch_size,
            "attempts": self.attempts,
            "detail": self.detail,
            "health": self.health.to_json() if self.health else None,
        }
