"""Admission control: bounded queue, load shedding, backpressure.

The server's queue is BOUNDED (``max_queue`` requests).  A full queue
sheds at the door — an explicit ``shed`` response the caller can retry
against, which is strictly better than unbounded queueing where every
request eventually misses its deadline anyway.  Requests that arrive
already expired (or whose deadline budget cannot cover even the minimum
service estimate) are rejected as ``deadline`` at admission instead of
occupying a slot they can never use.

``backpressure()`` is the overload signal: queue occupancy in [0, 1].
Clients use it to slow down; the degradation ladder
(:mod:`repro.serving.degrade`) uses it to pick its rung.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.health import report as health_report
from repro.serving.request import SketchRequest


@dataclasses.dataclass
class AdmissionDecision:
    admitted: bool
    status: Optional[str] = None   # "shed" | "deadline" when rejected
    detail: str = ""


class AdmissionController:
    """Stateless policy over the live queue depth (the batcher owns the
    queue; this object owns the accept/reject rule and the counters)."""

    def __init__(self, max_queue: int = 256,
                 min_service_estimate_s: float = 0.0):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = max_queue
        #: optimistic lower bound on service time; a request whose whole
        #: deadline budget is below it can never be served in time.
        self.min_service_estimate_s = min_service_estimate_s
        self.admitted = 0
        self.shed = 0
        self.rejected_deadline = 0

    def backpressure(self, queue_depth: int) -> float:
        """Queue occupancy in [0, 1] — the client-facing overload signal."""
        return min(1.0, queue_depth / self.max_queue)

    def admit(self, req: SketchRequest, queue_depth: int,
              now: float) -> AdmissionDecision:
        """Accept/reject one request against the current queue depth."""
        if req.expired(now) or (
                req.deadline_s is not None
                and req.deadline_s < self.min_service_estimate_s):
            self.rejected_deadline += 1
            health_report.record("serve.reject.deadline")
            return AdmissionDecision(
                False, status="deadline",
                detail=f"deadline budget {req.deadline_s}s cannot be met "
                       f"(min service estimate "
                       f"{self.min_service_estimate_s}s)")
        if queue_depth >= self.max_queue:
            self.shed += 1
            health_report.record("serve.reject.shed")
            return AdmissionDecision(
                False, status="shed",
                detail=f"queue full ({queue_depth}/{self.max_queue}); "
                       f"load shed")
        self.admitted += 1
        return AdmissionDecision(True)
