"""Graceful degradation under overload: an explicit, recorded ladder.

When backpressure rises past the rung thresholds, the server trades
quality/latency-variance for throughput through a FIXED ladder — and
every rung it applies to a request is recorded as a ``GuardFinding`` in
that request's health report (plus a process-wide counter), so degraded
service is always visible, never silent:

  rung 1 ``shrink_wait`` — collapse the batch-coalescing window to 0:
         groups dispatch as soon as a worker is free, trading batching
         efficiency for queue drain.  Result-identical (the batch fold
         is exact), so the finding is informational (``healthy``).
  rung 2 ``dtype_bf16``  — stream the sketch operand in bfloat16 (half
         the HBM traffic, fp32 accumulate).  Changes low-order result
         bits → the response is flagged ``degraded``.
  rung 3 ``dtype_fp8``   — deepen the precision cut: stream in
         fp8-e4m3 with seeded stochastic rounding (quarter HBM traffic,
         still fp32 accumulate; the ``fp8_e4m3_sr`` policy of
         ``core.precision``).  SUPERSEDES rung 2 — one dtype override
         and one ``dtype`` finding per dispatch, naming the deepest
         engaged precision rung.  Flagged ``degraded``.
  rung 4 ``cheap_lowering`` — re-lower the launch onto a structurally
         cheaper sketch: κ halved (floor 1), i.e. half the operand
         streams, at the cost of embedding quality (the paper's δ/κ
         trade run toward speed).  Flagged ``degraded``.

Rungs compose cumulatively (level 4 = all four, with the dtype rungs
collapsing to the deeper of the two).  Hysteresis: a rung engages at
its high-water mark and releases only ``hysteresis`` below it, so the
ladder does not flap at a threshold.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.blockperm import BlockPermPlan, make_plan
from repro.health import report as health_report
from repro.health.report import DEGRADED, HEALTHY, GuardFinding

RUNGS = ("shrink_wait", "dtype_bf16", "dtype_fp8", "cheap_lowering")

# the precision policy rung 3 lowers onto: fp8 stream + stochastic
# rounding (unbiased across requests), fp32 accumulate
FP8_RUNG_POLICY = "fp8_e4m3_sr"


@dataclasses.dataclass
class DegradeDecision:
    """The ladder's verdict for one dispatch: what to change, and the
    findings to attach to every affected response."""

    level: int
    batch_wait_s: float
    dtype: Optional[str]           # streaming-dtype override, or None
    plan: BlockPermPlan            # possibly κ-reduced
    findings: List[GuardFinding]


class DegradeLadder:
    """Backpressure → ladder level, with hysteresis; level → decision."""

    def __init__(self, *, thresholds=(0.5, 0.75, 0.85, 0.95),
                 hysteresis: float = 0.15):
        if len(thresholds) != len(RUNGS) or sorted(thresholds) != list(
                thresholds):
            raise ValueError(
                f"thresholds must be {len(RUNGS)} ascending fractions, "
                f"got {thresholds}")
        self.thresholds = tuple(thresholds)
        self.hysteresis = hysteresis
        self.level = 0

    def update(self, backpressure: float) -> int:
        """Advance/relax the ladder against the current occupancy."""
        level = 0
        for i, th in enumerate(self.thresholds):
            # an engaged rung releases only hysteresis below its mark
            release = th - self.hysteresis if self.level > i else th
            if backpressure >= release:
                level = i + 1
        if level != self.level:
            health_report.record(
                f"serve.ladder.{'up' if level > self.level else 'down'}",
                detail=f"level {self.level} -> {level} "
                       f"@ backpressure {backpressure:.2f}")
        self.level = level
        return level

    def decide(self, plan: BlockPermPlan,
               batch_wait_s: float) -> DegradeDecision:
        """Apply the current level to one dispatch.  Never silent: each
        applied rung yields a ``GuardFinding`` (and a counter event)."""
        findings: List[GuardFinding] = []
        dtype: Optional[str] = None
        eff = plan
        wait = batch_wait_s
        if self.level >= 1:
            wait = 0.0
            findings.append(GuardFinding(
                "degrade", "batch_wait", HEALTHY, value=0.0,
                threshold=batch_wait_s,
                detail="rung 1: coalescing window collapsed under load "
                       "(result-identical)"))
        # rungs 2/3 are one knob at two depths: the deepest engaged rung
        # wins, so each dispatch carries at most ONE dtype override and
        # ONE ``dtype`` finding (counters stay one-per-dispatch)
        if self.level >= 3 and plan.precision.name != FP8_RUNG_POLICY:
            dtype = FP8_RUNG_POLICY
            findings.append(GuardFinding(
                "degrade", "dtype", DEGRADED,
                detail="rung 3: operand streamed in fp8-e4m3 with "
                       "stochastic rounding (fp32 accumulate) to quarter "
                       "HBM traffic"))
        elif self.level >= 2 and plan.dtype != "bfloat16":
            dtype = "bfloat16"
            findings.append(GuardFinding(
                "degrade", "dtype", DEGRADED,
                detail="rung 2: operand streamed in bf16 (fp32 "
                       "accumulate) to halve HBM traffic"))
        if self.level >= 4 and not plan.is_global and plan.kappa > 1:
            cheap = make_plan(plan.d, plan.k_req,
                              kappa=max(1, plan.kappa // 2), s=plan.s,
                              seed=plan.seed, dtype=plan.dtype,
                              family=plan.family)
            # the response shape is a contract: a κ-reduced plan whose
            # padded k differs cannot substitute (rung skipped, recorded)
            if cheap.k == plan.k:
                eff = cheap
                findings.append(GuardFinding(
                    "degrade", "lowering", DEGRADED, value=float(eff.kappa),
                    threshold=float(plan.kappa),
                    detail=f"rung 4: re-lowered onto κ={eff.kappa} "
                           f"(from κ={plan.kappa}) — cheaper launch, "
                           f"weaker embedding"))
        for f in findings:
            health_report.record(f"serve.degrade.{f.target}")
        return DegradeDecision(level=self.level, batch_wait_s=wait,
                               dtype=dtype, plan=eff, findings=findings)
