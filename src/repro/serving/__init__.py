"""Resilient sketch serving: deadline-aware batching over FlashSketch.

The serving layer turns the guarded sketch stack into a multi-tenant
service: concurrent ``sketch``/``solve`` requests are coalesced into
single batched kernel launches, admission control bounds the queue and
sheds load explicitly, overload degrades through a recorded ladder, and
guard failures climb the PR-6 redraw ladder per request — budgeted
against each request's deadline, with a per-(tenant, plan) circuit
breaker bounding retry cost under sustained faults.  The contract is NO
SILENT FAILURES: every request terminates in an explicit status, and any
touched result carries a non-healthy ``HealthReport``.

See ``docs/serving.md`` for the lifecycle, the coalescing rule, and the
bench schema; ``benchmarks/serve_bench.py`` for the load/fault harness;
``repro.launch.serve`` for the CLI.
"""
from repro.serving.admission import AdmissionController, AdmissionDecision
from repro.serving.batcher import Batcher, Group, PlanCache, plan_key
from repro.serving.breaker import CLOSED, HALF_OPEN, OPEN, CircuitBreaker
from repro.serving.clock import ManualClock, MonotonicClock
from repro.serving.degrade import (RUNGS, DegradeDecision, DegradeLadder)
from repro.serving.request import (DEADLINE, DEGRADED, FAILED, OK,
                                   REJECTED_STATUSES, SERVED_STATUSES, SHED,
                                   TERMINAL_STATUSES, SketchRequest,
                                   SketchResponse)
from repro.serving.server import SERVE_POLICY, SketchServer, ThreadedServer

__all__ = [
    "AdmissionController", "AdmissionDecision", "Batcher", "Group",
    "PlanCache", "plan_key", "CircuitBreaker", "CLOSED", "OPEN", "HALF_OPEN",
    "ManualClock", "MonotonicClock", "DegradeDecision", "DegradeLadder",
    "RUNGS", "SketchRequest", "SketchResponse", "OK", "DEGRADED", "FAILED",
    "SHED", "DEADLINE", "TERMINAL_STATUSES", "SERVED_STATUSES",
    "REJECTED_STATUSES", "SketchServer", "ThreadedServer", "SERVE_POLICY",
]
