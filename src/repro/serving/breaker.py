"""Per-(tenant, plan) circuit breaker over guard failures.

A tenant whose requests keep failing guards — adversarial operands that
defeat every draw, NaN-producing inputs — would otherwise burn the
redraw ladder's full retry budget on EVERY request, multiplying its cost
under exactly the conditions (overload + faults) where capacity matters
most.  The breaker bounds that: after ``fail_threshold`` consecutive
guard-failed requests for one (tenant, plan-key), it OPENS — retries are
suppressed and single-attempt results are served *flagged degraded*
(visible in the health report; the result contract stays explicit).
After ``cooldown_s`` it half-opens: the next request gets the full
guarded ladder again; a healthy initial verdict closes the breaker, a
failure re-opens it.

States: ``closed`` (normal) → ``open`` (retries suppressed) →
``half_open`` (one probe request) → ``closed`` | ``open``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.health import report as health_report

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


@dataclasses.dataclass
class _BreakerState:
    state: str = CLOSED
    consecutive_failures: int = 0
    opened_at: float = 0.0
    trips: int = 0


class CircuitBreaker:
    """Registry of per-(tenant, plan-key) breaker states."""

    def __init__(self, *, fail_threshold: int = 3, cooldown_s: float = 5.0):
        if fail_threshold < 1:
            raise ValueError(
                f"fail_threshold must be >= 1, got {fail_threshold}")
        self.fail_threshold = fail_threshold
        self.cooldown_s = cooldown_s
        self._states: Dict[Tuple[str, object], _BreakerState] = {}

    def _get(self, key: Tuple[str, object]) -> _BreakerState:
        return self._states.setdefault(key, _BreakerState())

    def state(self, tenant: str, plan_key: object, now: float) -> str:
        """Current state, promoting ``open`` → ``half_open`` after the
        cool-down has elapsed."""
        st = self._get((tenant, plan_key))
        if st.state == OPEN and now - st.opened_at >= self.cooldown_s:
            st.state = HALF_OPEN
            health_report.record("serve.breaker.half_open")
        return st.state

    def allows_retries(self, tenant: str, plan_key: object,
                       now: float) -> bool:
        """Whether the redraw ladder may run for this request.  ``open``
        suppresses retries; ``half_open`` and ``closed`` allow them."""
        return self.state(tenant, plan_key, now) != OPEN

    def record_success(self, tenant: str, plan_key: object) -> None:
        """A request whose INITIAL guard verdict was acceptable."""
        st = self._get((tenant, plan_key))
        if st.state == HALF_OPEN:
            health_report.record("serve.breaker.close")
        st.state = CLOSED
        st.consecutive_failures = 0

    def record_failure(self, tenant: str, plan_key: object,
                       now: float) -> str:
        """A request whose initial guard verdict FAILED.  Returns the
        resulting state (``open`` if this failure tripped it)."""
        st = self._get((tenant, plan_key))
        st.consecutive_failures += 1
        if st.state == HALF_OPEN or (
                st.state == CLOSED
                and st.consecutive_failures >= self.fail_threshold):
            st.state = OPEN
            st.opened_at = now
            st.trips += 1
            health_report.record(
                "serve.breaker.trip",
                detail=f"({tenant}) after {st.consecutive_failures} "
                       f"consecutive guard failures")
        elif st.state == OPEN:
            st.opened_at = now          # faults while open extend the hold
        return st.state

    def snapshot(self) -> Dict[str, Dict]:
        """Stats-endpoint view: per-key state and trip counts."""
        return {
            f"{tenant}:{hash(pk) & 0xFFFF:04x}": {
                "state": st.state,
                "consecutive_failures": st.consecutive_failures,
                "trips": st.trips,
            }
            for (tenant, pk), st in self._states.items()
        }
