"""The resilient sketch server: coalesce → admit → launch → guard → escalate.

``SketchServer`` is the synchronous, deterministically-steppable core —
every time-dependent decision reads an injectable clock, so tests drive
it with ``ManualClock`` and replay exact overload/deadline/fault
scenarios.  ``ThreadedServer`` wraps it with a worker thread for real
deployments (the ``launch/serve.py`` CLI).

Request lifecycle::

    submit ──► admission (bounded queue: shed / deadline-reject) ──► batcher
    batcher ──(window | max_batch | deadline pressure)──► group
    group  ──► degrade ladder (wait → bf16 → fp8+SR → cheap κ)
               [recorded findings]
           ──► ONE sketch_apply_batched launch (tile resolved once, batched
               shape class)
           ──► per-request guards (finite, isometry on each output slice)
                 ├─ acceptable  → ok / degraded (breaker success)
                 ├─ NaN operand → failed, unrecoverable, NO retries
                 ├─ breaker OPEN → served flagged, retries suppressed
                 └─ guard failure → RedrawPolicy ladder: fresh-seed
                    relaunches with exponential backoff, every rung
                    budgeted against the request deadline; exhaustion
                    serves the least-bad draw with
                    ``escalation_budget_exhausted`` recorded.

Wall time vs virtual time: after every launch the server feeds the
MEASURED wall duration to ``clock.advance`` — a no-op on the real clock
(time already passed) but exactly what moves a ``ManualClock`` forward,
so virtual-time benches get real service times inside simulated arrival
processes (see ``benchmarks/serve_bench.py``).
"""
from __future__ import annotations

import time as _time
import threading
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.health import report as health_report
from repro.health.guards import finite_guard, isometry_guard
from repro.health.policy import RedrawPolicy
from repro.health.report import (DEGRADED as F_DEGRADED, FAILED as F_FAILED,
                                 HEALTHY as F_HEALTHY, STATUS_ORDER,
                                 GuardFinding, HealthReport)
from repro.kernels import lowering, ops
from repro.serving.admission import AdmissionController
from repro.serving.batcher import Batcher, Group, PlanCache, plan_key
from repro.serving.breaker import OPEN, CircuitBreaker
from repro.serving.clock import MonotonicClock
from repro.serving.degrade import DegradeDecision, DegradeLadder
from repro.serving.request import (DEADLINE, DEGRADED, FAILED, OK, SHED,
                                   SketchRequest, SketchResponse)

#: server-side escalation default — sampling bumps are disabled because a
#: γ-bumped plan changes the response's k (the shape is a contract);
#: κ bumps are attempted but skipped per-attempt if the padded k moves.
SERVE_POLICY = RedrawPolicy(max_redraws=2, max_kappa_bumps=1,
                            max_sampling_bumps=0)


def _severity(status: str) -> int:
    return STATUS_ORDER.index(status)


class SketchServer:
    """Deadline-aware batching sketch/solve server (single-stepped core).

    Args:
      clock: time source (default real ``MonotonicClock``; tests inject
        ``ManualClock``).
      max_queue / max_batch / batch_wait_s: admission bound, coalescing
        cap and window.
      impl: kernel impl forwarded to every launch (``"auto"`` → xla
        oracle on CPU, pallas on TPU).
      guard: run post-launch guards + the escalation ladder.  ``False``
        is the unguarded baseline the bench compares overhead against.
      policy: ``RedrawPolicy`` for per-request escalation.
      backoff_base_s: first retry backoff; doubles per rung.
      service_estimate_s: optimistic per-launch estimate used by
        admission (deadline feasibility) and the batcher (deadline
        pressure); refined online from observed launches.
    """

    def __init__(self, *, clock=None, max_queue: int = 64,
                 max_batch: int = 8, batch_wait_s: float = 0.002,
                 impl: str = "auto", guard: bool = True,
                 policy: Optional[RedrawPolicy] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 ladder: Optional[DegradeLadder] = None,
                 backoff_base_s: float = 1e-4,
                 service_estimate_s: float = 0.0):
        self.clock = clock if clock is not None else MonotonicClock()
        self.impl = impl
        self.guard = guard
        self.policy = policy if policy is not None else SERVE_POLICY
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self.ladder = ladder if ladder is not None else DegradeLadder()
        self.backoff_base_s = backoff_base_s
        self.service_estimate_s = service_estimate_s
        self.admission = AdmissionController(
            max_queue=max_queue,
            min_service_estimate_s=service_estimate_s)
        self.plans = PlanCache()
        self.batcher = Batcher(max_batch=max_batch,
                               batch_wait_s=batch_wait_s,
                               service_estimate_s=service_estimate_s)
        self._done: Dict[int, SketchResponse] = {}
        self.served = 0

    # -- intake ------------------------------------------------------------

    def submit(self, req: SketchRequest) -> Union[int, SketchResponse]:
        """Admit one request.  Returns its ticket (``request_id``) when
        queued, or the immediate rejection ``SketchResponse`` when shed /
        deadline-rejected at the door."""
        now = self.clock.now()
        req.arrival_s = now
        if req.deadline_s is not None:
            req.deadline_at = now + req.deadline_s
        if req.kind not in ("sketch", "solve"):
            raise ValueError(f"kind must be 'sketch'|'solve', got {req.kind!r}")
        plan = self.plans.resolve(req.tenant, req.plan_params)
        if req.operand.shape[0] != plan.d:
            raise ValueError(
                f"operand has {req.operand.shape[0]} rows, plan.d={plan.d}")
        decision = self.admission.admit(req, self.batcher.depth(), now)
        if not decision.admitted:
            report = HealthReport(op="serve.admission")
            report.add(GuardFinding("admission", decision.status, F_FAILED,
                                    detail=decision.detail))
            resp = SketchResponse(
                request_id=req.request_id, tenant=req.tenant, kind=req.kind,
                status=decision.status, health=report, latency_s=0.0,
                detail=decision.detail)
            self._done[req.request_id] = resp
            return resp
        self.ladder.update(
            self.admission.backpressure(self.batcher.depth() + 1))
        self.batcher.submit(req, plan)
        return req.request_id

    def poll(self, ticket: int) -> Optional[SketchResponse]:
        """Pop the terminal response for a ticket, or None if in flight."""
        return self._done.pop(ticket, None)

    # -- dispatch ----------------------------------------------------------

    def run_pending(self, *, force: bool = False) -> int:
        """Dispatch every due group (all groups when ``force``).  Returns
        the number of responses produced.  This is the server's single
        step function: the threaded driver calls it in a loop; tests call
        it at chosen clock instants."""
        now = self.clock.now()
        level = self.ladder.update(
            self.admission.backpressure(self.batcher.depth()))
        wait = 0.0 if level >= 1 else None
        groups = self.batcher.drain() if force \
            else self.batcher.due_groups(now, wait)
        produced = 0
        for group in groups:
            produced += self._execute_group(group)
        return produced

    def _finalize(self, resp: SketchResponse) -> None:
        self._done[resp.request_id] = resp
        if resp.served:
            self.served += 1

    def _timed(self, fn, *args, **kwargs):
        """Run a launch, feed its measured wall time to the clock (no-op
        on the real clock, advances a ManualClock), return the result."""
        t0 = _time.perf_counter()
        out = jax.block_until_ready(fn(*args, **kwargs))
        dt = _time.perf_counter() - t0
        self.clock.advance(dt)
        # online service estimate: a running MINIMUM — the steady-state
        # launch cost, deliberately excluding first-call jit compile (a
        # pessimistic estimate would starve retry/deadline budgets)
        self.service_estimate_s = min(self.service_estimate_s or dt, dt)
        return out

    def _execute_group(self, group: Group) -> int:
        now = self.clock.now()
        live: List[SketchRequest] = []
        for req in group.requests:
            if req.expired(now):
                self._finalize(SketchResponse(
                    request_id=req.request_id, tenant=req.tenant,
                    kind=req.kind, status=DEADLINE,
                    latency_s=now - req.arrival_s,
                    detail="deadline expired while queued"))
            else:
                live.append(req)
        if not live:
            return len(group.requests)
        decision = self.ladder.decide(group.plan, self.batcher.batch_wait_s)
        if group.kind == "solve":
            for req in live:
                self._serve_solve(req, decision)
            return len(group.requests)
        self._serve_sketch_group(group, live, decision)
        return len(group.requests)

    # -- sketch path -------------------------------------------------------

    def _serve_sketch_group(self, group: Group, live: List[SketchRequest],
                            decision: DegradeDecision) -> None:
        plan, dtype = decision.plan, decision.dtype
        n = group.shape[1]
        # resolve the tile ONCE against the tuner's batched shape class;
        # every launch of the group reuses it (one lowering, one jit key)
        tn = lowering.lower(plan, lowering.LaunchSpec(
            op="fwd", n=n, impl=self.impl, tn=None, dtype=dtype,
            batch=len(live))).tn
        stacked = jnp.stack([jnp.asarray(r.operand) for r in live])
        Y = self._timed(ops.sketch_apply_batched, plan, stacked,
                        self.impl, tn, dtype)
        Y = np.asarray(Y)
        for j, req in enumerate(live):
            self._finish_sketch(req, group, decision, np.asarray(Y[j]),
                                batch=len(live))

    def _finish_sketch(self, req: SketchRequest, group: Group,
                       decision: DegradeDecision, Yj: np.ndarray,
                       batch: int) -> None:
        report = HealthReport(op="serve.sketch", attempts=1)
        report.findings.extend(decision.findings)
        if not self.guard:
            self._finalize(SketchResponse(
                request_id=req.request_id, tenant=req.tenant, kind=req.kind,
                status=DEGRADED if decision.level >= 2 else OK, result=Yj,
                health=report, latency_s=self.clock.now() - req.arrival_s,
                batch_size=batch, attempts=1))
            return
        A = np.asarray(req.operand)
        verdict = self._guard_slice(A, Yj, report)
        pk = plan_key(group.plan, group.shape[1])
        now = self.clock.now()
        status: str
        result = Yj
        if self.policy.accepts(verdict):
            # promote an expired-cooldown OPEN breaker to its half-open
            # probe state before crediting the success that closes it
            self.breaker.state(req.tenant, pk, now)
            self.breaker.record_success(req.tenant, pk)
            # rung 1 (collapsed window) is result-identical and stays
            # "ok"; any NON-healthy downgrade finding demotes the status
            downgraded = any(f.status != F_HEALTHY
                             for f in decision.findings)
            status = OK if (verdict == F_HEALTHY
                            and not downgraded) else DEGRADED
        else:
            breaker_state = self.breaker.record_failure(req.tenant, pk, now)
            f_op = finite_guard(A, "operand")
            if f_op is not None and f_op.status == F_FAILED:
                # the input itself is poisoned: no draw can fix it, so the
                # ladder is NOT spent — fail fast, explicitly
                report.add(f_op)
                report.act("unrecoverable_operand")
                health_report.record("serve.unrecoverable_operand")
                status = FAILED
            elif breaker_state == OPEN:
                report.add(GuardFinding(
                    "breaker", req.tenant, F_DEGRADED,
                    detail="circuit open: retries suppressed, serving "
                           "single-attempt result flagged"))
                status = FAILED if verdict == F_FAILED else DEGRADED
            else:
                result, verdict = self._retry_sketch(
                    req, group, decision, Yj, verdict, report)
                status = DEGRADED if self.policy.accepts(verdict) else FAILED
        self._finalize(SketchResponse(
            request_id=req.request_id, tenant=req.tenant, kind=req.kind,
            status=status, result=result, health=report,
            latency_s=self.clock.now() - req.arrival_s, batch_size=batch,
            attempts=report.attempts))

    def _guard_slice(self, A: np.ndarray, Yj: np.ndarray,
                     report: HealthReport) -> str:
        """Guard one request's output slice; returns the worst verdict.
        Guard time is fed to the clock like launch time, so virtual-time
        benches see the true guarded-vs-unguarded latency gap."""
        t0 = _time.perf_counter()
        verdicts = []
        for f in (finite_guard(Yj, "SA"), isometry_guard(A, Yj, "SA")):
            if f is not None:
                report.add(f)
                verdicts.append(f.status)
        self.clock.advance(_time.perf_counter() - t0)
        return STATUS_ORDER[max(map(_severity, verdicts))] \
            if verdicts else F_HEALTHY

    def _retry_sketch(self, req: SketchRequest, group: Group,
                      decision: DegradeDecision, Y0: np.ndarray,
                      verdict0: str, report: HealthReport
                      ) -> Tuple[np.ndarray, str]:
        """The per-request escalation ladder: fresh-seed relaunches with
        exponential backoff, each rung budgeted against the deadline.
        Returns the accepted draw, or the LEAST-BAD draw on exhaustion
        (with ``escalation_budget_exhausted`` recorded)."""
        plan = group.plan
        A = np.asarray(req.operand)
        n = group.shape[1]
        best: Tuple[int, np.ndarray, str] = (_severity(verdict0), Y0, verdict0)
        exhausted_by_deadline = False
        for attempt in self.policy.attempts(
                seed=plan.seed, kappa=plan.kappa, sampling_factor=4.0):
            if attempt.index == 0:
                continue            # the batched launch was attempt 0
            candidate = self.policy.plan_for(
                attempt, plan.d, n, s=plan.s, dtype=plan.dtype,
                k=plan.k_req, family=plan.family)
            if candidate.k != plan.k:
                # the response shape is a contract — a rung whose padded k
                # moves cannot substitute; skip it, visibly
                report.act(f"skip_{attempt.action}(k {candidate.k}"
                           f" != {plan.k})")
                continue
            backoff = self.backoff_base_s * (2 ** (attempt.index - 1))
            now = self.clock.now()
            if req.remaining(now) <= backoff + self.service_estimate_s:
                exhausted_by_deadline = True
                break
            self.clock.sleep(backoff)
            self.policy.record(attempt)
            report.act(attempt.describe())
            report.attempts += 1
            Y = np.asarray(self._timed(
                ops.sketch_apply, candidate, jnp.asarray(A), self.impl,
                None, decision.dtype))
            verdict = self._guard_slice(A, Y, report)
            if self.policy.accepts(verdict):
                return Y, verdict
            if _severity(verdict) < best[0]:
                best = (_severity(verdict), Y, verdict)
        report.act("escalation_budget_exhausted")
        health_report.record(
            "serve.escalation_budget_exhausted",
            detail=("deadline budget" if exhausted_by_deadline
                    else "draw budget") + f" (request {req.request_id})")
        return best[1], best[2]

    # -- solve path --------------------------------------------------------

    def _serve_solve(self, req: SketchRequest,
                     decision: DegradeDecision) -> None:
        from repro.solvers.sketch_precondition import sketch_precondition_lstsq
        plan = decision.plan
        if decision.dtype is not None:
            plan = plan.with_dtype(decision.dtype)
        pk = plan_key(plan, req.operand.shape[1])
        now = self.clock.now()
        suppressed = self.guard and not self.breaker.allows_retries(
            req.tenant, pk, now)
        policy = self.policy
        if suppressed:
            policy = RedrawPolicy(max_redraws=0, max_kappa_bumps=0,
                                  max_sampling_bumps=0,
                                  max_resketch_restarts=0)
        result = self._timed(
            sketch_precondition_lstsq, jnp.asarray(req.operand),
            jnp.asarray(req.rhs), plan, impl=self.impl, guard=self.guard,
            policy=policy, **req.solver_kwargs)
        report = result.health if result.health is not None \
            else HealthReport(op="serve.solve", attempts=1)
        report.findings.extend(decision.findings)
        if suppressed:
            report.add(GuardFinding(
                "breaker", req.tenant, F_DEGRADED,
                detail="circuit open: solve escalation suppressed"))
        if self.guard:
            if report.status == F_FAILED:
                self.breaker.record_failure(req.tenant, pk, self.clock.now())
            else:
                self.breaker.record_success(req.tenant, pk)
        downgraded = any(f.status != F_HEALTHY for f in decision.findings)
        if report.status == F_FAILED:
            status = FAILED
        elif (report.status == F_HEALTHY and not report.actions
                and not downgraded and not suppressed):
            status = OK
        else:
            status = DEGRADED
        self._finalize(SketchResponse(
            request_id=req.request_id, tenant=req.tenant, kind=req.kind,
            status=status, result=result, health=report,
            latency_s=self.clock.now() - req.arrival_s, batch_size=1,
            attempts=max(report.attempts, 1)))

    # -- introspection -----------------------------------------------------

    def drain(self) -> int:
        """Force-dispatch everything still queued (shutdown path)."""
        return self.run_pending(force=True)

    def stats(self) -> Dict[str, Any]:
        """The stats/backpressure endpoint: one JSON-able snapshot."""
        depth = self.batcher.depth()
        return {
            "queue_depth": depth,
            "queue_groups": self.batcher.group_count(),
            "backpressure": self.admission.backpressure(depth),
            "ladder_level": self.ladder.level,
            "admitted": self.admission.admitted,
            "shed": self.admission.shed,
            "rejected_deadline": self.admission.rejected_deadline,
            "served": self.served,
            "plan_cache_size": self.plans.size(),
            "service_estimate_s": self.service_estimate_s,
            "breakers": self.breaker.snapshot(),
        }


class ThreadedServer:
    """Async driver over the synchronous core: a worker thread steps
    ``run_pending`` while callers ``submit`` / ``result`` concurrently.
    The core is single-threaded by design — ALL access goes through one
    lock; the condition variable wakes waiters when responses land.

    Usage::

        with ThreadedServer(max_batch=8) as srv:
            t = srv.submit(SketchRequest(...))
            resp = srv.result(t, timeout=5.0)
    """

    def __init__(self, server: Optional[SketchServer] = None,
                 poll_interval_s: float = 2e-4, **server_kwargs):
        self.server = server if server is not None \
            else SketchServer(**server_kwargs)
        self.poll_interval_s = poll_interval_s
        self._cv = threading.Condition()
        self._results: Dict[int, SketchResponse] = {}
        self._running = False
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "ThreadedServer":
        with self._cv:
            if self._running:
                return self
            self._running = True
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="sketch-server")
        self._thread.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        with self._cv:
            self._running = False
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        if drain:
            with self._cv:
                self._harvest(self.server.drain())
                self._cv.notify_all()

    def __enter__(self) -> "ThreadedServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _harvest(self, produced: int) -> None:
        if produced:
            for ticket in list(self.server._done):
                self._results[ticket] = self.server._done.pop(ticket)

    def _run(self) -> None:
        while True:
            with self._cv:
                if not self._running:
                    return
                produced = self.server.run_pending()
                self._harvest(produced)
                if produced:
                    self._cv.notify_all()
                idle = self.server.batcher.depth() == 0
            if idle or not produced:
                _time.sleep(self.poll_interval_s)

    # -- client API --------------------------------------------------------

    def submit(self, req: SketchRequest) -> Union[int, SketchResponse]:
        with self._cv:
            out = self.server.submit(req)
            if isinstance(out, SketchResponse):
                self._results[out.request_id] = \
                    self.server._done.pop(out.request_id, out)
            return out

    def result(self, ticket: int,
               timeout: Optional[float] = 30.0) -> SketchResponse:
        """Block until the ticket's terminal response (raises
        ``TimeoutError`` after ``timeout`` seconds)."""
        deadline = None if timeout is None else _time.monotonic() + timeout
        with self._cv:
            while ticket not in self._results:
                left = None if deadline is None \
                    else deadline - _time.monotonic()
                if left is not None and left <= 0:
                    raise TimeoutError(
                        f"request {ticket} not finished after {timeout}s")
                self._cv.wait(timeout=left if left is None
                              else min(left, 0.05))
            return self._results.pop(ticket)

    def stats(self) -> Dict[str, Any]:
        with self._cv:
            return self.server.stats()
