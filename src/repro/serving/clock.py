"""Injectable time source for the sketch server.

Every time-dependent decision in ``repro.serving`` — batch-window expiry,
deadline checks, retry backoff, circuit-breaker cool-down — reads ONE
clock object instead of ``time.monotonic()`` directly, so the whole
request lifecycle can be driven deterministically:

  * ``MonotonicClock`` — production: wraps ``time.monotonic`` /
    ``time.sleep``; ``advance`` is a no-op (real time already passed).
  * ``ManualClock``    — tests and the virtual-time benchmark driver:
    time only moves when the driver says so (``advance``), and a
    ``sleep`` (retry backoff) advances it instead of blocking, so an
    overload → shed → recover scenario replays bit-identically.

The benchmark's Poisson-arrival driver runs the server on a
``ManualClock`` and advances it by the MEASURED wall time of each kernel
launch, so queueing dynamics are simulated in virtual time while service
times stay real — load behaves like rps vs. service rate without the
bench depending on scheduler jitter.
"""
from __future__ import annotations

import threading
import time


class MonotonicClock:
    """Real time: ``time.monotonic`` now, blocking ``sleep``."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)

    def advance(self, dt: float) -> None:
        """No-op: wall time advanced on its own while the work ran."""


class ManualClock:
    """Deterministic time: moves only via ``advance``/``sleep``.

    Thread-safe (the threaded server driver may sleep from a worker
    thread while a test advances from the main thread).
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            return self._t

    def sleep(self, dt: float) -> None:
        self.advance(dt)

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"time cannot move backwards (dt={dt})")
        with self._lock:
            self._t += float(dt)
