"""Request coalescing: one batched launch per (plan, shape class) window.

The two halves of the multi-tenant server meet here:

  * ``PlanCache`` — per-tenant memo from plan parameters to the frozen
    ``BlockPermPlan``, keyed the way ``tune.cache_key`` keys shape
    classes (family, padded dims, grid, κ, s, dtype).  Identical specs —
    across requests AND across tenants — resolve to equal (hashable)
    plans, which is exactly what makes them coalescible: a batched
    launch shares one S, so requests may share a launch iff their plans
    are equal and their operand shapes match.
  * ``Batcher`` — groups pending requests by ``(kind, plan, operand
    shape)`` and releases a group when its coalescing window expires,
    it reaches ``max_batch``, or DEADLINE PRESSURE says waiting longer
    would breach a member's budget (the window is a latency tax; a
    request that cannot afford it dispatches the group early).

A released sketch group becomes ONE ``ops.sketch_apply_batched`` launch
(batch folded into the column axis; the tile resolved once against the
tuner's batched shape class — see ``server._resolve_tile``).  Solve
groups share the plan/lowering resolution but execute per-request (each
has its own right-hand side and iteration); that asymmetry is the
documented coalescing rule.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.blockperm import BlockPermPlan, make_plan
from repro.kernels import tune
from repro.serving.request import SketchRequest

_PLAN_FIELDS = ("d", "k", "kappa", "s", "seed", "dtype", "family")
_PLAN_DEFAULTS = {"kappa": 4, "s": 2, "seed": 0, "dtype": "float32",
                  "family": "blockperm"}


class PlanCache:
    """Per-tenant plan memo (plans are deterministic in their params, so
    this only avoids rebuild cost — but it also gives each tenant a
    stable identity key for the breaker and the stats endpoint)."""

    def __init__(self):
        self._plans: Dict[str, Dict[Tuple, BlockPermPlan]] = {}

    def resolve(self, tenant: str, params: Dict) -> BlockPermPlan:
        unknown = set(params) - set(_PLAN_FIELDS)
        if unknown:
            raise ValueError(
                f"unknown plan params {sorted(unknown)}; valid: "
                f"{_PLAN_FIELDS}")
        if "d" not in params or "k" not in params:
            raise ValueError("plan_params must include 'd' and 'k'")
        full = {**_PLAN_DEFAULTS, **params}
        key = tuple(full[f] for f in _PLAN_FIELDS)
        per_tenant = self._plans.setdefault(tenant, {})
        plan = per_tenant.get(key)
        if plan is None:
            plan = make_plan(full["d"], full["k"], kappa=full["kappa"],
                             s=full["s"], seed=full["seed"],
                             dtype=full["dtype"], family=full["family"])
            per_tenant[key] = plan
        return plan

    def size(self, tenant: Optional[str] = None) -> int:
        if tenant is not None:
            return len(self._plans.get(tenant, {}))
        return sum(len(v) for v in self._plans.values())


def plan_key(plan: BlockPermPlan, n: int) -> Tuple:
    """Breaker/stats identity of a (plan, shape class) — the
    ``tune.cache_key`` spelling (minus backend/batch, which are not part
    of the sketch's identity)."""
    return tune.cache_key(plan, n, "fwd")[1:-1]


@dataclasses.dataclass
class Group:
    """One coalesced dispatch unit."""

    kind: str
    plan: BlockPermPlan
    shape: Tuple[int, ...]
    requests: List[SketchRequest]

    @property
    def key(self) -> Tuple:
        return (self.kind, self.plan, self.shape)


class Batcher:
    """The bounded, deadline-aware coalescing queue."""

    def __init__(self, *, max_batch: int = 8, batch_wait_s: float = 0.002,
                 service_estimate_s: float = 0.0):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.batch_wait_s = batch_wait_s
        #: conservative estimate of one launch, used for deadline pressure
        self.service_estimate_s = service_estimate_s
        self._pending: Dict[Tuple, Deque[SketchRequest]] = \
            collections.OrderedDict()
        self._oldest: Dict[Tuple, float] = {}

    def submit(self, req: SketchRequest, plan: BlockPermPlan) -> None:
        key = (req.kind, plan, tuple(req.operand.shape))
        q = self._pending.get(key)
        if q is None:
            q = collections.deque()
            self._pending[key] = q
            self._oldest[key] = req.arrival_s
        q.append(req)

    def depth(self) -> int:
        return sum(len(q) for q in self._pending.values())

    def group_count(self) -> int:
        return len(self._pending)

    def _ready(self, key: Tuple, now: float, batch_wait_s: float) -> bool:
        q = self._pending[key]
        if len(q) >= self.max_batch:
            return True
        if now - self._oldest[key] >= batch_wait_s:
            return True
        # deadline pressure: if any member cannot afford to keep waiting
        # for the window (remaining budget ≤ rest-of-window + service),
        # dispatch the group now rather than convert a latency tax into
        # a deadline miss.
        wait_left = batch_wait_s - (now - self._oldest[key])
        return any(r.remaining(now) <= wait_left + self.service_estimate_s
                   for r in q if r.deadline_at is not None)

    def due_groups(self, now: float,
                   batch_wait_s: Optional[float] = None) -> List[Group]:
        """Pop and return every group ready to dispatch at ``now``.
        ``batch_wait_s`` overrides the configured window (the degrade
        ladder's rung-1 passes 0 here)."""
        wait = self.batch_wait_s if batch_wait_s is None else batch_wait_s
        out: List[Group] = []
        for key in [k for k in self._pending
                    if self._ready(k, now, wait)]:
            q = self._pending[key]
            take = min(len(q), self.max_batch)
            reqs = [q.popleft() for _ in range(take)]
            if q:
                self._oldest[key] = q[0].arrival_s
            else:
                del self._pending[key]
                del self._oldest[key]
            kind, plan, shape = key
            out.append(Group(kind=kind, plan=plan, shape=shape,
                             requests=reqs))
        return out

    def drain(self) -> List[Group]:
        """Pop everything regardless of windows (shutdown / test path)."""
        out: List[Group] = []
        for key in list(self._pending):
            q = self._pending[key]
            kind, plan, shape = key
            while q:
                take = min(len(q), self.max_batch)
                out.append(Group(kind=kind, plan=plan, shape=shape,
                                 requests=[q.popleft()
                                           for _ in range(take)]))
            del self._pending[key]
            del self._oldest[key]
        return out
