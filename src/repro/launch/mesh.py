"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) over ('data','model') — 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) over ('pod','data','model') — 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use tiny ones, e.g. (2,2) on 4 host devices)."""
    return jax.make_mesh(shape, axes)


def batch_axes_of(mesh) -> Tuple[str, ...]:
    """All non-'model' axes carry the batch (pod composes with data)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def describe(mesh) -> str:
    return f"mesh{tuple(mesh.devices.shape)} axes={mesh.axis_names} chips={mesh.devices.size}"
