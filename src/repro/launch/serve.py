"""Sketch-server CLI: run the resilient serving layer against live load.

    # one batch of guarded sketch + solve requests, print statuses:
    PYTHONPATH=src python -m repro.launch.serve --smoke

    # sustained Poisson load at 200 req/s for 2 seconds (real threads):
    PYTHONPATH=src python -m repro.launch.serve --poisson-rps 200 --duration 2

    # same, with fault injection (NaN-poisoned + adversarial operands)
    # and the no-silent-failures check:
    PYTHONPATH=src python -m repro.launch.serve --poisson-rps 200 \
        --duration 2 --inject

This CLI drives the REAL threaded server (``serving.ThreadedServer``)
under wall-clock arrivals; the deterministic virtual-time harness with
JSON output and gates lives in ``benchmarks/serve_bench.py``.  (The LLM
decode-loop launcher that used to live here is ``repro.launch.generate``.)
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.health import report as health_report
from repro.health.inject import adversarial_input, inject_nan
from repro.serving import SketchRequest, ThreadedServer


def _print_stats(label, responses, srv):
    lat = sorted(r.latency_s for r in responses if r.served)
    by_status = {}
    for r in responses:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    print(f"[serve] {label}: {len(responses)} responses {by_status}")
    if lat:
        p50 = lat[len(lat) // 2]
        p99 = lat[min(len(lat) - 1, int(len(lat) * 0.99))]
        print(f"[serve]   latency p50={p50 * 1e3:.2f}ms "
              f"p99={p99 * 1e3:.2f}ms")
    print(f"[serve]   stats: {srv.stats()}")


def run_smoke() -> int:
    rng = np.random.default_rng(0)
    params = dict(d=256, k=64, kappa=2, s=2, seed=3)
    with ThreadedServer(max_batch=4, batch_wait_s=0.002) as srv:
        tickets = []
        for _ in range(8):
            A = rng.standard_normal((256, 16)).astype(np.float32)
            tickets.append(srv.submit(SketchRequest(
                tenant="smoke", kind="sketch", operand=A,
                plan_params=dict(params))))
        A = rng.standard_normal((256, 8)).astype(np.float32)
        b = rng.standard_normal(256).astype(np.float32)
        tickets.append(srv.submit(SketchRequest(
            tenant="smoke", kind="solve", operand=A, rhs=b,
            plan_params=dict(d=256, k=64, kappa=2, s=2, seed=3))))
        responses = [t if not isinstance(t, int) else srv.result(t)
                     for t in tickets]
        _print_stats("smoke", responses, srv)
    bad = [r for r in responses if not r.served]
    print(f"[serve] smoke {'FAILED' if bad else 'ok'}")
    return 1 if bad else 0


def run_poisson(rps: float, duration_s: float, inject: bool,
                seed: int = 0) -> int:
    rng = np.random.default_rng(seed)
    params = dict(d=256, k=64, kappa=2, s=2, seed=5)
    adv_params = dict(d=256, k=64, kappa=1, s=1, seed=5)
    n_req = max(1, int(rps * duration_s))
    gaps = rng.exponential(1.0 / rps, size=n_req)
    faulty = set()
    with ThreadedServer(max_batch=8, batch_wait_s=0.002,
                        max_queue=128) as srv:
        tickets = []
        for i, gap in enumerate(gaps):
            time.sleep(float(gap))
            A = rng.standard_normal((256, 16)).astype(np.float32)
            p = params
            if inject and i % 7 == 3:
                A = np.asarray(inject_nan(A, count=2, seed=i))
                faulty.add(i)
            elif inject and i % 7 == 5:
                plan_probe = srv.server.plans.resolve(
                    "load", dict(adv_params))
                A = np.asarray(adversarial_input(plan_probe, 16, seed=i))
                p = adv_params
                faulty.add(i)
            tickets.append(srv.submit(SketchRequest(
                tenant="load", kind="sketch", operand=A,
                plan_params=dict(p), deadline_s=2.0)))
        responses = [t if not isinstance(t, int) else srv.result(t)
                     for t in tickets]
        _print_stats(f"poisson rps={rps:g}", responses, srv)
    if inject:
        silent = [i for i in faulty
                  if responses[i].served and not responses[i].flagged]
        print(f"[serve] injected {len(faulty)} faults; "
              f"silent failures: {len(silent)}")
        print(f"[serve] counters: {health_report.summarize_counters()}")
        if silent:
            print("[serve] FAILED: silent failures detected")
            return 1
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="one mixed batch of requests, print statuses")
    ap.add_argument("--poisson-rps", type=float, default=None,
                    help="sustained Poisson load at this request rate")
    ap.add_argument("--duration", type=float, default=2.0,
                    help="seconds of Poisson load")
    ap.add_argument("--inject", action="store_true",
                    help="poison a fraction of requests (NaN/adversarial) "
                         "and check the no-silent-failures contract")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        return run_smoke()
    if args.poisson_rps is not None:
        return run_poisson(args.poisson_rps, args.duration, args.inject,
                           args.seed)
    ap.error("pick a mode: --smoke or --poisson-rps")


if __name__ == "__main__":
    raise SystemExit(main())
