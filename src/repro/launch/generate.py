"""Generation launcher: batched prefill + decode loop with sampling.

    PYTHONPATH=src python -m repro.launch.generate --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 16 --gen 32

(Formerly ``repro.launch.serve``; that module is now the sketch-server
CLI — this one owns the LLM decode loop.)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import smoke_config
from repro.configs.registry import ARCHS, get_arch
from repro.models.factory import build_model, extra_inputs_concrete


def generate(model, params, prompts: jnp.ndarray, gen: int, extra,
             temperature: float = 0.0, seed: int = 0):
    """prompts: (B, P) int32. Returns (B, P+gen) tokens + tok/s."""
    B, P = prompts.shape
    max_seq = P + gen
    state = model.init_decode_state(params, B, max_seq, extra)
    step = jax.jit(model.decode_step)
    key = jax.random.PRNGKey(seed)
    toks = prompts
    cur = prompts[:, :1]
    t0 = time.perf_counter()
    for pos in range(max_seq - 1):
        logits, state = step(params, state, cur, jnp.int32(pos))
        if pos + 1 < P:
            cur = prompts[:, pos + 1:pos + 2]       # teacher-forced prefill
            continue
        lg = logits[:, 0, :model.cfg.vocab_size]
        if temperature > 0:
            key, k = jax.random.split(key)
            cur = jax.random.categorical(k, lg / temperature)[:, None]
        else:
            cur = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        toks = jnp.concatenate([toks, cur], axis=1)
    dt = time.perf_counter() - t0
    return toks, (B * gen) / dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    extra = extra_inputs_concrete(cfg, args.batch, args.prompt_len, key)
    toks, tps = generate(model, params, prompts, args.gen, extra,
                         args.temperature)
    print(f"[generate] arch={cfg.name} generated {toks.shape} "
          f"({tps:.1f} tok/s on {jax.default_backend()})")
    print("[generate] sample:", toks[0, :32].tolist())


if __name__ == "__main__":
    main()
