import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × input shape) cell, lower + compile the train /
serve step on the production mesh — 16×16 (single pod, 256 chips) and
2×16×16 (two pods, 512 chips) — and record memory_analysis, cost_analysis
and the roofline terms (parsed from the optimized HLO, loop-body-aware).

    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both

Results land in experiments/dryrun/*.json (one per cell×mesh) and are
aggregated into EXPERIMENTS.md by benchmarks/roofline_table.py.
"""
import argparse
import gzip
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES_BY_NAME, shape_applicable, ShapeConfig
from repro.configs.registry import ARCHS, all_cells
from repro.launch import mesh as mesh_lib
from repro.models.factory import train_batch_specs
from repro.optim import adamw
from repro.roofline import analysis
from repro.sharding import partition as pt
from repro.train import train_step as ts

OUTDIR = os.environ.get("DRYRUN_OUT", "experiments/dryrun")


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def lower_train_cell(cfg, shape, mesh, ctx):
    """Lower+compile one training cell. Returns compiled executable."""
    opt_cfg = adamw.AdamWConfig(state_dtype=cfg.optstate_dtype)
    step_fn, model = ts.build_train_step(cfg, opt_cfg)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = pt.param_pspecs(params_shape, ctx)
    opt_shape = jax.eval_shape(
        lambda p: adamw.init_state(p, opt_cfg), params_shape)
    opt_specs = {"m": pspecs, "v": pspecs, "step": P()}
    batch_shape = train_batch_specs(cfg, shape)
    batch_specs = {
        k: P(ctx.batch_axes, *([None] * (len(v.shape) - 1)))
        for k, v in batch_shape.items()
    }
    err_shape = jax.tree.map(lambda x: jax.ShapeDtypeStruct((1,), jnp.float32),
                             {})  # compression off in baseline dry-run

    def step(params, opt_state, batch):
        p2, o2, _, metrics = step_fn(params, opt_state, {}, batch)
        return p2, o2, metrics["loss"]

    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, opt_specs),
                      _ns(mesh, batch_specs)),
        out_shardings=(_ns(mesh, pspecs), _ns(mesh, opt_specs),
                       NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    )
    lowered = jitted.lower(params_shape, opt_shape, batch_shape)
    return lowered.compile()


def lower_prefill_cell(cfg, shape, mesh, ctx):
    model = ts.build_serve_step(cfg)[1]
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = pt.param_pspecs(params_shape, ctx)
    B, S = shape.global_batch, shape.seq_len
    tok_shape = jax.ShapeDtypeStruct((B, S), jnp.int32)
    extra_shape = {}
    extra_specs = {}
    if cfg.family == "encdec":
        extra_shape["encoder_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        extra_specs["encoder_frames"] = P(ctx.batch_axes, None, None)
    if cfg.family == "vlm":
        extra_shape["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.image_tokens, cfg.d_model), jnp.float32)
        extra_specs["image_embeds"] = P(ctx.batch_axes, None, None)

    def step(params, tokens, extra):
        return model.prefill(params, tokens, extra)

    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, pspecs),
                      NamedSharding(mesh, P(ctx.batch_axes, None)),
                      _ns(mesh, extra_specs)),
        out_shardings=NamedSharding(mesh, P(ctx.batch_axes, "model")),
    )
    return jitted.lower(params_shape, tok_shape, extra_shape).compile()


def lower_decode_cell(cfg, shape, mesh, ctx):
    serve_fn, model = ts.build_serve_step(cfg)
    out = ts.decode_state_specs(cfg, mesh, model, shape)
    _, params_shape, pspecs, state_shape, state_specs, _extra = out
    B = shape.global_batch
    tok_shape = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
    data_size = 1
    for a in ctx.batch_axes:
        data_size *= mesh.shape[a]
    b_ax = ctx.batch_axes if B % data_size == 0 else None

    jitted = jax.jit(
        serve_fn,
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, state_specs),
                      NamedSharding(mesh, P(b_ax, None)),
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, P(b_ax, None, "model")),
                       _ns(mesh, state_specs)),
        donate_argnums=(1,),
    )
    return jitted.lower(params_shape, state_shape, tok_shape, pos_shape).compile()


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             skip_existing: bool = True, verbose: bool = True):
    cfg = ARCHS[arch_name]
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "pod512" if multi_pod else "pod256"
    outpath = os.path.join(OUTDIR, f"{cfg.name}_{shape.name}_{mesh_name}.json")
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
               "status": "skip", "reason": reason}
        os.makedirs(OUTDIR, exist_ok=True)
        with open(outpath, "w") as f:
            json.dump(rec, f, indent=2)
        if verbose:
            print(f"[dryrun] {cfg.name} × {shape.name} × {mesh_name}: {reason}")
        return rec
    if skip_existing and os.path.exists(outpath):
        with open(outpath) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            if verbose:
                print(f"[dryrun] {cfg.name} × {shape.name} × {mesh_name}: cached")
            return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    ctx = ts.sharding_ctx_for(mesh, cfg)
    t0 = time.time()
    try:
        with mesh, pt.activate(ctx):
            if shape.kind == "train":
                compiled = lower_train_cell(cfg, shape, mesh, ctx)
            elif shape.kind == "prefill":
                compiled = lower_prefill_cell(cfg, shape, mesh, ctx)
            else:
                compiled = lower_decode_cell(cfg, shape, mesh, ctx)
        ma = compiled.memory_analysis()
        rep = analysis.analyze_compiled(
            compiled, cfg, shape, mesh_name, mesh.devices.size)
        rec = rep.to_json()
        rec.update(status="ok", compile_s=time.time() - t0,
                   memory_analysis=str(ma))
        # archive the optimized HLO so roofline analysis can be re-run (and
        # hillclimb iterations inspected) without recompiling
        os.makedirs(OUTDIR, exist_ok=True)
        with gzip.open(os.path.join(
                OUTDIR, f"{cfg.name}_{shape.name}_{mesh_name}.hlo.gz"),
                "wt") as zf:
            zf.write(compiled.as_text())
        if verbose:
            print(f"[dryrun] {cfg.name} × {shape.name} × {mesh_name}: OK "
                  f"({rec['compile_s']:.0f}s compile) "
                  f"compute={rep.compute_s*1e3:.1f}ms "
                  f"memory={rep.memory_s*1e3:.1f}ms "
                  f"coll={rep.collective_s*1e3:.1f}ms "
                  f"bottleneck={rep.bottleneck} "
                  f"mem/dev={(rep.arg_bytes_per_device+rep.temp_bytes_per_device)/2**30:.2f}GiB")
            print(f"         memory_analysis: {ma}")
            print(f"         cost_analysis(flops/device): "
                  f"{compiled.cost_analysis().get('flops', 0):.3e} "
                  f"(walker: {rep.device_flops:.3e})")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
               "status": "fail", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:],
               "compile_s": time.time() - t0}
        if verbose:
            print(f"[dryrun] {cfg.name} × {shape.name} × {mesh_name}: "
                  f"FAIL {type(e).__name__}: {str(e)[:200]}")
    os.makedirs(OUTDIR, exist_ok=True)
    with open(outpath, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def reanalyze_all():
    """Recompute roofline records from archived HLO (after analyzer changes)."""
    import glob
    from repro.roofline import hlo_parse
    n = 0
    for path in glob.glob(os.path.join(OUTDIR, "*.hlo.gz")):
        base = path[:-len(".hlo.gz")]
        jpath = base + ".json"
        if not os.path.exists(jpath):
            continue
        with open(jpath) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        cfg = ARCHS[rec["arch"]]
        shape = SHAPES_BY_NAME[rec["shape"]]
        with gzip.open(path, "rt") as zf:
            text = zf.read()
        cost = hlo_parse.entry_cost(text, rec["chips"])
        rep = analysis.RooflineReport(
            arch=cfg.name, shape=shape.name, mesh=rec["mesh"],
            chips=rec["chips"], device_flops=cost.flops,
            device_hbm_bytes=cost.hbm_bytes,
            device_coll_bytes=cost.coll_wire_bytes,
            coll_breakdown=dict(cost.coll_bytes),
            model_flops=analysis.model_flops_for(cfg, shape),
            arg_bytes_per_device=rec.get("arg_bytes_per_device", 0.0),
            temp_bytes_per_device=rec.get("temp_bytes_per_device", 0.0),
            note=rec.get("note", ""),
        ).finish()
        new_rec = rep.to_json()
        new_rec.update(status="ok", compile_s=rec.get("compile_s"),
                       memory_analysis=rec.get("memory_analysis"))
        with open(jpath, "w") as f:
            json.dump(new_rec, f, indent=2)
        n += 1
    print(f"[dryrun] reanalyzed {n} records")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (or --all)")
    ap.add_argument("--shape", default=None,
                    help="train_4k|prefill_32k|decode_32k|long_500k")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--no-skip-existing", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute records from archived HLO, no compiles")
    args = ap.parse_args(argv)
    if args.reanalyze:
        reanalyze_all()
        return 0

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod]
    results = []
    if args.all:
        for cfg, shape, ok, reason in all_cells():
            for mp in meshes:
                results.append(run_cell(cfg.name, shape.name, mp,
                                        not args.no_skip_existing))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        for mp in meshes:
            results.append(run_cell(args.arch, args.shape, mp,
                                    not args.no_skip_existing))
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_skip = sum(1 for r in results if r.get("status") == "skip")
    n_fail = sum(1 for r in results if r.get("status") == "fail")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
