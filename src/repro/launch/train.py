"""Training launcher.

Production: forms the (data, model) mesh over real devices, shards params by
the partition rules, and runs the Trainer with checkpointing + compression.
Locally (1 CPU device) it runs the same code on a 1×1 mesh — the point is
that nothing changes between the two but the device set.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --smoke --steps 50 --grad-compress 8
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import smoke_config
from repro.configs.registry import ARCHS, get_arch
from repro.data import pipeline as dp
from repro.optim import adamw
from repro.optim import grad_compress as gc
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compress", type=int, default=0,
                    help="sketch compression ratio (0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    print(f"[train] arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    opt = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                            total_steps=args.steps,
                            state_dtype=cfg.optstate_dtype)
    data_cfg = dp.DataConfig(vocab_size=cfg.vocab_size,
                             global_batch=args.batch, seq_len=args.seq,
                             seed=args.seed)
    comp = (gc.CompressConfig(ratio=args.grad_compress)
            if args.grad_compress else None)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=max(10, args.steps // 4),
                         ckpt_dir=args.ckpt_dir, log_every=max(1, args.steps // 20))
    trainer = Trainer(cfg, opt, tcfg, data_cfg, compress=comp)
    out = trainer.fit()
    print(f"[train] done: first-5 loss {sum(out['losses'][:5])/5:.4f} -> "
          f"last-5 loss {sum(out['losses'][-5:])/5:.4f} "
          f"({out['wall_s']:.1f}s)")


if __name__ == "__main__":
    main()
