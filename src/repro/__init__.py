"""repro: FlashSketch / BLOCKPERM-SJLT JAX framework."""
