"""Cheap post-launch validators for sketches, factors and replicas.

Each guard inspects a CONCRETE artifact (a materialized sketch ``SA``, a
triangular factor ``R``, per-device replicas of a psum result), classifies
it ``healthy`` / ``degraded`` / ``failed`` and records the verdict both on
the returned :class:`~repro.health.report.GuardFinding` and in the global
counter registry.  Guards are O(artifact) or cheaper — they never touch
the big operand ``A`` beyond one Frobenius norm — with one deliberate
exception (``ose_probe``, the O(d·n²) ground-truth probe used by tests
and the escalation-ladder acceptance check).

Under a jax tracer the guards cannot read values; every guard then
returns ``None`` (check skipped) instead of a finding, so guarded entry
points stay safe to call from jitted code — they simply lose coverage
there.  The solver/distributed integrations run eagerly, where the guards
are always live.

Threshold rationale (the δ/ε vocabulary of the paper's Thm 6.2):

  * ``isometry_guard`` — ``E‖SA‖_F² = ‖A‖_F²`` holds for ANY sketch with
    unit-variance columns, so the Frobenius ratio is an expectation-exact
    probe: a ratio outside ``1 ± tol`` (default tol=0.5, the ε of the
    γ≈4 sampling rule) means the draw's distortion is far beyond what the
    sampling factor was sized for.
  * ``r_condition_guard`` — ``R`` inherits cond(A), so a large condition
    estimate alone is only ``degraded``; ``failed`` is reserved for what
    no legitimate input produces: non-finite entries or a diagonal ratio
    at the rank-deficiency floor.
  * ``ose_probe`` — σ_min(S·U) for an orthonormal basis U of range(A) is
    the quantity the OSE guarantee bounds below by 1−ε; a bad draw that
    annihilates a direction of range(A) sends it to ~0.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.core import precision as _precision
from repro.health import report as _report
from repro.health.report import DEGRADED, FAILED, HEALTHY, GuardFinding

# Default thresholds.  The isometry/OSE bands are single-sourced from the
# fp32 precision policy (``core.precision``) — per-policy widened bands
# (fp8) reach the guards via the keyword overrides, e.g.
# ``isometry_guard(..., **plan.precision.isometry_band())``.
_FP32 = _precision.resolve("float32")
ISOMETRY_TOL = _FP32.isometry_tol     # healthy band: ratio within 1 ± tol
ISOMETRY_FAIL = _FP32.isometry_fail   # failed band: ratio outside 1 ± fail
RCOND_DEGRADED = 1.0e6      # diag-ratio estimate above this: degraded
RCOND_FAILED = 1.0e12       # … above this (or 0/non-finite diag): failed
OSE_MIN_HEALTHY = _FP32.ose_min_healthy   # σ_min(SU) ≥ 1 − ε, default ε=1/2
OSE_MIN_FAILED = _FP32.ose_min_failed     # a range(A) direction annihilated


def concrete_or_none(x) -> Optional[np.ndarray]:
    """``np.asarray(x)`` when x holds real values, ``None`` under a tracer."""
    if isinstance(x, jax.core.Tracer):
        return None
    return np.asarray(x)


def _emit(finding: GuardFinding) -> GuardFinding:
    _report.record(f"guard.{finding.guard}.{finding.status}",
                   detail=finding.detail or None)
    return finding


def finite_guard(x, target: str = "operand") -> Optional[GuardFinding]:
    """Non-finite sentinel: ``failed`` iff any entry is NaN/Inf.

    The cheapest guard and the one that catches NaN-poisoned gradient
    chunks, overflowed accumulations and corrupted buffers outright.
    """
    arr = concrete_or_none(x)
    if arr is None:
        return None
    bad = int(np.size(arr) - np.count_nonzero(np.isfinite(arr)))
    if bad == 0:
        return _emit(GuardFinding("finite", target, HEALTHY, value=0.0))
    return _emit(GuardFinding(
        "finite", target, FAILED, value=float(bad),
        detail=f"{bad}/{np.size(arr)} non-finite entries"))


def isometry_guard(A, SA, target: str = "SA", *,
                   tol: float = ISOMETRY_TOL,
                   fail: float = ISOMETRY_FAIL) -> Optional[GuardFinding]:
    """Isometry-in-expectation probe: ``‖SA‖_F / ‖A‖_F`` vs ``1 ± tol``.

    ``healthy`` within ``1 ± tol``, ``degraded`` within ``1 ± fail``,
    ``failed`` outside (or non-finite / identically zero — a sketch that
    annihilated its input).  One reduction over each array; no extra
    sketch application.
    """
    a = concrete_or_none(A)
    sa = concrete_or_none(SA)
    if a is None or sa is None:
        return None
    na = float(np.linalg.norm(a))
    nsa = float(np.linalg.norm(sa))
    if not (np.isfinite(na) and np.isfinite(nsa)):
        return _emit(GuardFinding(
            "isometry", target, FAILED, value=float("nan"),
            detail="non-finite Frobenius norm"))
    ratio = nsa / na if na > 0 else (1.0 if nsa == 0 else float("inf"))
    dev = abs(ratio - 1.0)
    if dev <= tol:
        status = HEALTHY
    elif dev <= fail:
        status = DEGRADED
    else:
        status = FAILED
    return _emit(GuardFinding(
        "isometry", target, status, value=ratio, threshold=tol,
        detail=f"‖SA‖_F/‖A‖_F deviation {dev:.3g}"))


def r_condition_guard(R, target: str = "R", *,
                      degraded: float = RCOND_DEGRADED,
                      failed: float = RCOND_FAILED) -> Optional[GuardFinding]:
    """Triangular condition estimate on a preconditioner factor ``R``.

    Uses the diagonal ratio ``max|r_ii| / min|r_ii|`` — for a triangular
    matrix a free lower bound on cond(R).  ``failed`` only on what no
    legitimate (even ill-conditioned) input produces: non-finite entries,
    a zero diagonal, or a ratio at the rank-deficiency floor.  A merely
    large estimate is ``degraded`` (R inherits cond(A); the solver pays
    iterations, not correctness).
    """
    r = concrete_or_none(R)
    if r is None:
        return None
    if np.size(r) - np.count_nonzero(np.isfinite(r)):
        return _emit(GuardFinding(
            "r_condition", target, FAILED, value=float("nan"),
            detail="non-finite entries in triangular factor"))
    diag = np.abs(np.diagonal(r))
    dmin = float(diag.min()) if diag.size else 0.0
    dmax = float(diag.max()) if diag.size else 0.0
    est = float("inf") if dmin == 0.0 else dmax / dmin
    if est > failed:
        status = FAILED
    elif est > degraded:
        status = DEGRADED
    else:
        status = HEALTHY
    return _emit(GuardFinding(
        "r_condition", target, status, value=est, threshold=failed,
        detail=f"diag ratio estimate (lower bound on cond R)"))


def ose_probe(plan, A, target: str = "sketch", *, impl: str = "auto",
              min_healthy: float = OSE_MIN_HEALTHY,
              min_failed: float = OSE_MIN_FAILED) -> Optional[GuardFinding]:
    """Ground-truth OSE check: σ_min of ``S·U`` for U = orth(range(A)).

    The quantity Thm 6.2 bounds: an ε-subspace-embedding keeps every
    singular value of ``SU`` in ``[1−ε, 1+ε]``.  ``failed`` when a
    direction of range(A) is essentially annihilated (σ_min below
    ``min_failed``); ``degraded`` between the bands.  Costs an O(d·n²)
    orthogonalization plus one extra sketch application — this is the
    escalation-ladder acceptance check and the fault-injection test
    oracle, NOT a hot-path guard.

    The spectral error ``‖UᵀSᵀSU − I‖₂`` (``coherence.ose_spectral_error``)
    is reported in the detail string; σ_min is the classified value
    because the upper edge ``(1+ε)² − 1`` legitimately exceeds 1 at the
    default ε = 1/2.
    """
    a = concrete_or_none(A)
    if a is None:
        return None
    from repro.core import coherence            # lazy: keeps import DAG flat
    from repro.kernels import ops
    U = np.linalg.qr(np.asarray(a, np.float64))[0].astype(np.float32)
    SU = np.asarray(ops.sketch_apply(plan, U, impl))
    if not np.all(np.isfinite(SU)):
        return _emit(GuardFinding(
            "ose_probe", target, FAILED, value=float("nan"),
            detail="non-finite sketch of the probe basis"))
    smin = float(np.linalg.svd(SU, compute_uv=False).min())
    err = coherence.ose_spectral_error(U, SU)
    if smin < min_failed:
        status = FAILED
    elif smin < min_healthy:
        status = DEGRADED
    else:
        status = HEALTHY
    return _emit(GuardFinding(
        "ose_probe", target, status, value=smin, threshold=min_healthy,
        detail=f"σ_min(SU); spectral error {err:.3g}"))


def replica_arrays(x) -> List[np.ndarray]:
    """Per-device copies of a (supposedly) replicated jax.Array.

    One entry per addressable device.  A single-device array yields one
    copy (trivially consistent).
    """
    shards = getattr(x, "addressable_shards", None)
    if not shards:
        return [np.asarray(x)]
    return [np.asarray(s.data) for s in shards]


def replica_consistency_guard(
        replicas: Sequence[np.ndarray], target: str = "R", *,
        atol: float = 0.0) -> Optional[GuardFinding]:
    """Cross-replica agreement check on a replicated collective result.

    After a psum, every device must hold the IDENTICAL array (the sharded
    sketch is bit-exact by construction — see ``distributed.sharded_apply``)
    — so any deviation beyond ``atol`` (default: bitwise) means a corrupted
    collective contribution: a zeroed or permuted partial, a dropped
    participant, flipped bits on the interconnect.  Catches the class of
    fault that otherwise produces a silently wrong — not crashed — answer.
    """
    arrs = [concrete_or_none(r) for r in replicas]
    if any(a is None for a in arrs):
        return None
    if len(arrs) <= 1:
        return _emit(GuardFinding(
            "replica_consistency", target, HEALTHY, value=0.0,
            detail="single replica"))
    ref = arrs[0]
    worst = 0.0
    bad = 0
    for a in arrs[1:]:
        if a.shape != ref.shape:
            return _emit(GuardFinding(
                "replica_consistency", target, FAILED,
                detail=f"replica shape mismatch {a.shape} vs {ref.shape}"))
        dev = float(np.max(np.abs(a - ref))) if ref.size else 0.0
        if not np.isfinite(dev) or dev > atol:
            bad += 1
            worst = max(worst, dev if np.isfinite(dev) else float("inf"))
    if bad == 0:
        return _emit(GuardFinding(
            "replica_consistency", target, HEALTHY, value=0.0,
            threshold=atol, detail=f"{len(arrs)} replicas bit-consistent"))
    return _emit(GuardFinding(
        "replica_consistency", target, FAILED, value=worst, threshold=atol,
        detail=f"{bad}/{len(arrs) - 1} replicas deviate from replica 0"))
