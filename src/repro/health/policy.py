"""Seed re-draw escalation ladder for failed sketch draws.

BlockPerm-SJLT fails (is a bad embedding) with probability δ per draw,
and δ is controlled by exactly two paper-level knobs (Thm 6.2):

  * the nonzero budget ``κs ≥ C·ε⁻¹·(r + log 1/δ)`` — more κ, lower δ
    at the price of streaming the operand κ times;
  * the sketch size ``k ≥ C·μ·ε⁻²·(r + log 1/δ)`` — a larger sampling
    factor γ (k = γ·n), lower δ at the price of a bigger factor problem.

The ladder spends the CHEAP remedy first: failure probability is
per-draw and draws are independent, so simply re-drawing the seed
(``multisketch.derive_seed`` — the same deterministic derivation the
multisketch restarts use) resolves the generic δ-tail at zero extra
per-launch cost.  Only when fresh draws keep failing — i.e. the *input*
defeats this (κ, γ) operating point, not bad luck — does the ladder pay
for a structurally stronger sketch: bump κ, then bump γ.

Everything is deterministic under the master seed: the attempt sequence
(seeds, κ, γ per rung) is a pure function of the policy and the base
knobs, so two runs take identical escalation paths.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

from repro.health import report as _report

# Slot tags for derive_seed: redraw attempts and structural bumps draw from
# disjoint seed streams so a κ-bumped attempt never reuses a failed seed.
_SLOT_REDRAW = 0
_SLOT_KAPPA = 1
_SLOT_SAMPLING = 2


@dataclasses.dataclass(frozen=True)
class Attempt:
    """One rung of the escalation ladder: which sketch to try next.

    Attributes:
      index:   0-based attempt number (0 = the caller's original request).
      action:  ``"initial" | "redraw" | "kappa_bump" | "sampling_bump"``.
      seed:    plan seed for this attempt (derived, except attempt 0).
      kappa:   block degree κ for this attempt.
      sampling_factor: γ — sketch rows are ``k = γ·n`` (``solver_sketch_rows``).
    """

    index: int
    action: str
    seed: int
    kappa: int
    sampling_factor: float

    def describe(self) -> str:
        return (f"{self.action}(seed={self.seed}, kappa={self.kappa}, "
                f"gamma={self.sampling_factor:g})")


@dataclasses.dataclass(frozen=True)
class RedrawPolicy:
    """The escalation budget: how many rungs of each kind to climb.

    The total draw budget is ``1 + max_redraws + max_kappa_bumps +
    max_sampling_bumps`` (the acceptance criteria's "escalation budget").
    ``accept_degraded`` keeps mediocre-but-usable draws (the solver pays
    iterations, not correctness — invariant 4); only ``failed`` verdicts
    climb the ladder.

    Attributes:
      max_redraws:         fresh independent seeds at the SAME (κ, γ).
      max_kappa_bumps:     ×2 bumps of κ (capped at ``kappa_cap``).
      max_sampling_bumps:  ×2 bumps of the sampling factor γ.
      kappa_cap:           κ never exceeds this (κ ≤ M is required by the
                           wiring; 8 is already a conservative draw).
      max_resketch_restarts: mid-solve re-sketch restarts when the
                           iteration diverges/stalls after an accepted
                           factor (the multisketch restart rule applied
                           to the guarded single-sketch solver).
      accept_degraded:     accept ``degraded`` probe verdicts (default).
    """

    max_redraws: int = 2
    max_kappa_bumps: int = 1
    max_sampling_bumps: int = 1
    kappa_cap: int = 8
    max_resketch_restarts: int = 1
    accept_degraded: bool = True

    @property
    def budget(self) -> int:
        """Total sketch draws the ladder may consume."""
        return (1 + self.max_redraws + self.max_kappa_bumps
                + self.max_sampling_bumps)

    def attempts(self, *, seed: int, kappa: int,
                 sampling_factor: float) -> Iterator[Attempt]:
        """The deterministic attempt sequence for one guarded operation."""
        from repro.solvers.multisketch import derive_seed   # lazy: no cycle
        idx = 0
        yield Attempt(idx, "initial", seed, kappa, sampling_factor)
        for r in range(self.max_redraws):
            idx += 1
            yield Attempt(idx, "redraw",
                          derive_seed(seed, idx, _SLOT_REDRAW),
                          kappa, sampling_factor)
        kap = kappa
        for r in range(self.max_kappa_bumps):
            if kap >= self.kappa_cap:
                break
            kap = min(2 * kap, self.kappa_cap)
            idx += 1
            yield Attempt(idx, "kappa_bump",
                          derive_seed(seed, idx, _SLOT_KAPPA),
                          kap, sampling_factor)
        gamma = sampling_factor
        for r in range(self.max_sampling_bumps):
            gamma = 2.0 * gamma
            idx += 1
            yield Attempt(idx, "sampling_bump",
                          derive_seed(seed, idx, _SLOT_SAMPLING),
                          kap, gamma)

    def accepts(self, status: str) -> bool:
        """Whether a probe verdict lets the current attempt stand."""
        if status == _report.HEALTHY:
            return True
        return status == _report.DEGRADED and self.accept_degraded

    def plan_for(self, attempt: Attempt, d: int, n: int, *, s: int,
                 dtype: str = "float32", k: Optional[int] = None,
                 family: str = "blockperm"):
        """The ``BlockPermPlan`` of one attempt.

        ``k`` pins the sketch rows of attempt 0 (the caller's explicit
        request); escalated attempts size ``k`` from the rung's sampling
        factor so a ``sampling_bump`` actually grows the sketch.
        ``family`` carries the sketch construction through every rung, so
        a guarded countsketch/graph solve escalates within its own family
        (``kappa_bump`` rungs are inert there — global plans pin κ=M).
        """
        from repro.configs import flashsketch_paper         # lazy: no cycle
        from repro.core.blockperm import make_plan
        if k is None or attempt.action == "sampling_bump":
            k = flashsketch_paper.solver_sketch_rows(
                n, attempt.sampling_factor)
        return make_plan(d, k, kappa=attempt.kappa, s=s, seed=attempt.seed,
                         dtype=dtype, family=family)

    def record(self, attempt: Attempt) -> None:
        """Count the escalation action in the global registry."""
        if attempt.action != "initial":
            _report.record(f"policy.{attempt.action}",
                           detail=attempt.describe())
