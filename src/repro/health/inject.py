"""Deterministic fault injectors: make every guard fire ON PURPOSE.

A detection layer that is only ever exercised by accident is untested by
definition.  This module manufactures each failure class the guards exist
for, deterministically (seeded, no wall-clock, no platform randomness),
so the test suite and the ``fault-injection`` CI job can prove that every
detection path and every recovery rung actually runs:

  * ``inject_nan``             — NaN/Inf poisoning of an operand or output
                                 (→ ``guards.finite_guard``).
  * ``adversarial_input``      — a seeded input whose range contains a
                                 direction the CURRENT plan's draw
                                 annihilates exactly (a real bad-embedding
                                 event, not noise) — defeats draw #1, is
                                 fixed by a re-draw or a κ bump
                                 (→ ``guards.ose_probe`` + ``RedrawPolicy``).
  * ``corrupt_cache_file``     — truncated / garbage / malformed-row tuner
                                 cache JSON (→ hardened ``tune.load_cache``).
  * ``corrupt_replica``        — a zeroed / permuted / scaled per-device
                                 copy of a psum result, the silent-collective
                                 -corruption class
                                 (→ ``guards.replica_consistency_guard``).
  * ``vmem_overflow_request``  — a (plan, spec) whose working set cannot
                                 fit VMEM, forcing the lowering downgrade
                                 ladder (→ ``Lowering.downgrade`` +
                                 ``lowering.downgrade`` counter).

``python -m repro.health.inject --out HEALTH_counters.json`` runs the
whole catalogue through its guards (the CI ``fault-injection`` job) and
exits non-zero if any injected fault goes undetected or unrecovered.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.blockperm import BlockPermPlan, block_rows_signs, make_plan
from repro.health import guards, report
from repro.health.policy import RedrawPolicy


# ---------------------------------------------------------------------------
# NaN / Inf poisoning
# ---------------------------------------------------------------------------

def inject_nan(x, *, count: int = 4, seed: int = 0,
               value: float = float("nan")) -> np.ndarray:
    """Poison ``count`` deterministic positions of ``x`` with ``value``.

    Positions are drawn from a seeded generator, so the same (shape,
    seed) always corrupts the same entries — tests can pin them.
    """
    arr = np.array(x, dtype=np.float32, copy=True)
    if arr.size == 0:
        return arr
    rng = np.random.default_rng(seed)
    idx = rng.choice(arr.size, size=min(count, arr.size), replace=False)
    arr.reshape(-1)[idx] = value
    return arr


# ---------------------------------------------------------------------------
# Adversarially coherent input: defeat one specific draw, exactly.
# ---------------------------------------------------------------------------

def annihilated_direction(plan: BlockPermPlan) -> np.ndarray:
    """A unit vector x with ``S x = 0`` EXACTLY for this plan's draw.

    Construction (κ=1, s=1 plans): within one input block h, two columns
    u₁ ≠ u₂ whose single nonzero hashes to the SAME destination row
    collide; ``x = e_{u₁} − σ₁σ₂·e_{u₂}`` then cancels exactly in the
    one output block h feeds.  Such a pair exists by pigeonhole whenever
    ``B_c > B_r/s`` (more columns than destination rows), and the search
    over the plan's own hash stream is deterministic.

    This is the paper's δ-failure event made concrete: a direction of the
    input space on which THIS draw is not an embedding at all.  A fresh
    seed re-randomizes the hashes (the collision pattern moves), and a κ
    bump requires the pair to collide at every level simultaneously — so
    the escalation ladder repairs it by design.
    """
    if plan.kappa != 1 or plan.s != 1:
        raise ValueError(
            "annihilated_direction targets kappa=1, s=1 plans (higher κ·s "
            "needs a simultaneous collision at every level — that tail is "
            f"exactly what κ buys down); got kappa={plan.kappa}, s={plan.s}")
    for g in range(plan.M):
        h = plan.neighbors(g)[0]
        u = np.arange(plan.Bc, dtype=np.int32)
        rows, signs = block_rows_signs(plan, g, h, u, 0)
        rows = np.asarray(rows)
        signs = np.asarray(signs)
        seen: Dict[int, int] = {}
        for u2 in range(plan.Bc):
            coord2 = h * plan.Bc + u2
            if coord2 >= plan.d:          # padding region: not a real input
                continue
            r = int(rows[u2])
            if r in seen:
                u1 = seen[r]
                x = np.zeros(plan.d, np.float32)
                x[h * plan.Bc + u1] = 1.0
                x[coord2] = -float(signs[u1]) * float(signs[u2])
                return x / np.linalg.norm(x)
            seen[r] = u2
    raise ValueError(
        f"no colliding column pair for {plan.describe()} — need "
        f"B_c > B_r/s with real (non-padding) columns in some block")


def adversarial_input(plan: BlockPermPlan, n: int, *, noise: float = 1e-3,
                      seed: int = 0) -> np.ndarray:
    """A (d, n) operand whose range defeats THIS plan's draw.

    Column 0 is an exactly-annihilated unit direction (``S A e₀ = 0``);
    the remaining columns are small seeded noise, so A is full rank and
    the least-squares problem stays well-posed — only the SKETCH of it is
    broken.  The OSE probe on draw #1 fails (σ_min(SU) ≈ 0), the isometry
    and R-condition guards fail with it, and the redraw ladder recovers.
    """
    x = annihilated_direction(plan)
    rng = np.random.default_rng(seed)
    A = noise * rng.standard_normal((plan.d, n)).astype(np.float32)
    A[:, 0] = x
    return A


# ---------------------------------------------------------------------------
# Tuner-cache corruption
# ---------------------------------------------------------------------------

_CACHE_MODES = ("truncate", "garbage", "bad_entry")


def corrupt_cache_file(path: str, mode: str = "truncate") -> str:
    """Corrupt a tuner-cache JSON file in place; returns the path.

    Modes: ``"truncate"`` (a half-written file — the crash-mid-write
    case atomic persistence prevents), ``"garbage"`` (not JSON at all),
    ``"bad_entry"`` (valid JSON, rows that do not parse as cache
    entries).
    """
    if mode == "truncate":
        with open(path, "rb") as f:
            data = f.read()
        with open(path, "wb") as f:
            f.write(data[: max(1, len(data) // 2)])
    elif mode == "garbage":
        with open(path, "w") as f:
            f.write("this is not JSON {{{")
    elif mode == "bad_entry":
        with open(path, "w") as f:
            json.dump({"not a key tuple": {"no_tn_field": True},
                       "[1, 2": {"tn": 64}}, f)
    else:
        raise ValueError(f"mode must be one of {_CACHE_MODES}, got {mode!r}")
    return path


# ---------------------------------------------------------------------------
# Corrupted collective contribution (replica divergence)
# ---------------------------------------------------------------------------

_REPLICA_MODES = ("zero", "permute", "scale")


def corrupt_replica(replicas, slot: int = 1, mode: str = "zero",
                    seed: int = 0):
    """Corrupt replica ``slot`` of a replicated result, deterministically.

    Models the silent-collective-corruption class: one participant's psum
    contribution zeroed (``"zero"``), rows delivered out of order
    (``"permute"``), or scaled (``"scale"`` — e.g. a double-counted
    partial).  Returns a new list; the input arrays are not modified.
    """
    out = [np.array(r, copy=True) for r in replicas]
    slot = slot % len(out)
    bad = out[slot]
    if mode == "zero":
        bad[...] = 0.0
    elif mode == "permute":
        rng = np.random.default_rng(seed)
        perm = rng.permutation(bad.shape[0])
        out[slot] = np.ascontiguousarray(bad[perm])
    elif mode == "scale":
        bad *= 2.0
    else:
        raise ValueError(
            f"mode must be one of {_REPLICA_MODES}, got {mode!r}")
    return out


# ---------------------------------------------------------------------------
# Forced VMEM overflow (the lowering downgrade ladder)
# ---------------------------------------------------------------------------

def vmem_overflow_request(op: str = "fwd", *, gather: bool = False,
                          shard: str = "none", devices: int = 1
                          ) -> Tuple[BlockPermPlan, object]:
    """A (plan, LaunchSpec) whose requested kernel CANNOT fit VMEM.

    The pinned ``block_rows=256`` grid at d=65536 gives a stacked Φ
    scratch over the budget at any tile width, so ``lower()`` must take a
    downgrade rung (gather-materialize / v2→v1 / partial→oracle, per the
    ladder in ``kernels/lowering.py``) and record it.
    """
    from repro.kernels import lowering
    plan = make_plan(65_536, 1024, kappa=4, s=2, block_rows=256)
    spec = lowering.LaunchSpec(op=op, n=64, impl="pallas", gather=gather,
                               shard=shard, devices=devices)
    return plan, spec


# ---------------------------------------------------------------------------
# The injector suite: every fault detected, every recovery taken.
# ---------------------------------------------------------------------------

def run_injector_suite(out: Optional[str] = None,
                       verbose: bool = True) -> int:
    """Run every injector through its guard; write the counters JSON.

    Returns 0 iff every injected fault was detected AND the documented
    recovery ran.  The counters JSON (``--out``) is written even on
    failure — it is the debugging artifact for exactly the failing case.
    """
    import os
    import tempfile
    import warnings

    from repro.kernels import lowering, tune
    from repro.solvers import sketch_precondition as sp

    report.reset_counters()
    results: Dict[str, bool] = {}

    def check(name: str, ok: bool, msg: str = "") -> None:
        results[name] = bool(ok)
        if verbose:
            print(f"  [{'ok' if ok else 'FAIL'}] {name}" +
                  (f" — {msg}" if msg else ""))

    if verbose:
        print("fault-injection suite (deterministic):")

    # 1. NaN operand / output → finite sentinel.
    clean = np.linspace(-1.0, 1.0, 64, dtype=np.float32).reshape(8, 8)
    f = guards.finite_guard(inject_nan(clean, count=3, seed=7), "operand")
    check("nan_operand_detected", f is not None and f.status == report.FAILED,
          f.describe() if f else "guard skipped")
    f = guards.finite_guard(inject_nan(clean, count=1, seed=9,
                                       value=float("inf")), "output")
    check("inf_output_detected", f is not None and f.status == report.FAILED)

    # 2. Adversarially coherent input → bad draw detected, ladder recovers.
    plan = make_plan(512, 64, kappa=1, s=1, seed=0)
    A = adversarial_input(plan, 8, seed=0)
    probe = guards.ose_probe(plan, A, impl="xla")
    check("bad_draw_detected",
          probe is not None and probe.status == report.FAILED,
          probe.describe() if probe else "probe skipped")
    b = (A @ np.ones(A.shape[1], np.float32)).astype(np.float32)
    res = sp.sketch_precondition_lstsq(
        A, b, k=plan.k_req, kappa=1, s=1, seed=0, impl="xla",
        guard=True, policy=RedrawPolicy())
    check("bad_draw_recovered",
          res.health is not None and res.health.attempts > 1
          and res.health.status != report.FAILED and res.converged,
          f"attempts={res.health.attempts if res.health else '?'}, "
          f"relres={res.relres:.2e}")

    # 3. Corrupted tuner cache → warn + heuristic fallback, never a raise.
    cache_ok = True
    with tempfile.TemporaryDirectory() as td:
        for mode in _CACHE_MODES:
            path = os.path.join(td, f"cache_{mode}.json")
            tune.clear_cache()
            tune.autotune(make_plan(256, 64, kappa=2, s=2), 32,
                          tns=(32,), warmup=0, iters=1)
            tune.save_cache(path)
            corrupt_cache_file(path, mode)
            tune.clear_cache()
            try:
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore")
                    tune.load_cache(path)
            except Exception as e:     # hardening promise: warn, never raise
                cache_ok = False
                if verbose:
                    print(f"    load_cache({mode}) raised {e!r}")
        tune.clear_cache()
    snap = report.counters()
    check("corrupt_cache_recovered",
          cache_ok and snap.get("tune.cache_corrupt", 0) >= 1,
          f"tune.cache_corrupt={snap.get('tune.cache_corrupt', 0)}")

    # 4. Corrupted psum contribution → replica-consistency guard.
    base = np.arange(24, dtype=np.float32).reshape(6, 4)
    good = [base.copy() for _ in range(4)]
    ok = guards.replica_consistency_guard(good, "R")
    psum_ok = ok is not None and ok.status == report.HEALTHY
    for mode in _REPLICA_MODES:
        fnd = guards.replica_consistency_guard(
            corrupt_replica(good, slot=2, mode=mode, seed=3), "R")
        psum_ok = psum_ok and fnd is not None and fnd.status == report.FAILED
    check("psum_corruption_detected", psum_ok)

    # 5. Forced VMEM overflow → the lowering downgrade ladder fires.
    vmem_ok = True
    for op, gather, shard, dev in (("fwd", False, "none", 1),
                                   ("fwd", True, "none", 1),
                                   ("fwd", False, "row", 4)):
        p, spec = vmem_overflow_request(op, gather=gather, shard=shard,
                                        devices=dev)
        lw = lowering.lower(p, spec)
        vmem_ok = vmem_ok and bool(lw.downgrade)
    snap = report.counters()
    check("vmem_overflow_downgraded",
          vmem_ok and snap.get("lowering.downgrade", 0) >= 1,
          f"lowering.downgrade={snap.get('lowering.downgrade', 0)}")

    payload = {
        "suite": "repro.health.inject",
        "injectors": {k: ("detected" if v else "MISSED")
                      for k, v in results.items()},
        "counters": report.counters(),
        "ok": all(results.values()),
    }
    if out:
        with open(out, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        if verbose:
            print(f"wrote {out}")
    if verbose:
        print("counters: " + report.summarize_counters(max_items=100))
    return 0 if all(results.values()) else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="FlashSketch fault-injection suite: prove every guard "
                    "fires and every recovery rung runs")
    ap.add_argument("--out", default=None,
                    help="write the health-counters JSON here (the CI "
                         "artifact)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    return run_injector_suite(out=args.out, verbose=not args.quiet)


if __name__ == "__main__":
    sys.exit(main())
