"""Structured health reporting for guarded sketch execution.

BlockPerm-SJLT is an oblivious subspace embedding *with failure
probability δ* — the κ / sampling-factor analysis explicitly trades GPU
efficiency against the chance that one draw is a bad embedding.  The
production response to that tail is detect → discard → re-draw, and this
module is the vocabulary for the "detect" half:

  * ``GuardFinding`` — one guard's verdict on one artifact (a sketch, a
    triangular factor, a psum'd replica): ``healthy`` / ``degraded`` /
    ``failed`` plus the measured value and threshold.
  * ``HealthReport`` — the findings of one guarded operation (a solve, a
    distributed sketch, a featurize pass), with the escalation actions
    taken (re-draws, κ bumps, sampling bumps) and quarantine counts.
    Attached to ``solvers.SolveResult.health`` and printable via
    ``describe()`` / serializable via ``to_json()``.
  * a process-global **event counter registry** — every guard records
    pass/fail events here (``record``), so long-running jobs can export
    one counters JSON (``counters_json``) and ``engine.explain`` can show
    the guard activity of the process alongside the lowering trace.

This module is dependency-free (no jax, no repro.kernels) so low layers
(``kernels.lowering``, ``kernels.ops``, ``kernels.tune``) can import it
without cycles.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Dict, List, Optional, Tuple

# Guard verdicts, ordered by severity (index = badness).
HEALTHY = "healthy"
DEGRADED = "degraded"
FAILED = "failed"
STATUS_ORDER = (HEALTHY, DEGRADED, FAILED)


def worst_status(*statuses: str) -> str:
    """The most severe of the given verdicts (``healthy`` if none)."""
    worst = 0
    for s in statuses:
        if s not in STATUS_ORDER:
            raise ValueError(
                f"status must be one of {STATUS_ORDER}, got {s!r}")
        worst = max(worst, STATUS_ORDER.index(s))
    return STATUS_ORDER[worst]


@dataclasses.dataclass(frozen=True)
class GuardFinding:
    """One guard's verdict on one artifact.

    Attributes:
      guard:  guard name (``"finite"``, ``"isometry"``, ``"r_condition"``,
              ``"replica_consistency"``, ``"ose_probe"``, …).
      target: what was checked (``"SA"``, ``"R"``, ``"operand"``, …).
      status: ``"healthy" | "degraded" | "failed"``.
      value:  the measured quantity (non-finite count, Frobenius ratio,
              condition estimate, max replica deviation), ``None`` when
              the guard could not measure (e.g. under a jax tracer).
      threshold: the bound the value was judged against (``None`` when
              not applicable).
      detail: human-readable one-liner for logs / ``explain``.
    """

    guard: str
    target: str
    status: str
    value: Optional[float] = None
    threshold: Optional[float] = None
    detail: str = ""

    def describe(self) -> str:
        bits = [f"{self.guard}[{self.target}]: {self.status}"]
        if self.value is not None:
            v = f"{self.value:.3g}"
            if self.threshold is not None:
                v += f" (threshold {self.threshold:.3g})"
            bits.append(v)
        if self.detail:
            bits.append(self.detail)
        return " ".join(bits)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class HealthReport:
    """Findings + recovery actions of one guarded operation.

    Attributes:
      op:        what was guarded (``"sketch_precondition_lstsq"``,
                 ``"dist_sketch_precondition_lstsq"``, ``"featurize"``).
      findings:  every ``GuardFinding`` recorded, in order.
      actions:   escalation-ladder actions actually taken, in order —
                 entries like ``"redraw(seed=123)"``, ``"kappa_bump(2->4)"``,
                 ``"sampling_bump(4.0->8.0)"``, ``"resketch_restart"``,
                 ``"chol->qr"``, ``"quarantine(rows=3)"``.
      attempts:  sketch draws consumed (1 = first draw was accepted).
      quarantined: data items (e.g. featurize rows) zeroed out.
    """

    op: str = ""
    findings: List[GuardFinding] = dataclasses.field(default_factory=list)
    actions: List[str] = dataclasses.field(default_factory=list)
    attempts: int = 0
    quarantined: int = 0

    @property
    def status(self) -> str:
        """Worst verdict across all findings of the *accepted* state.

        A finding that triggered a successful recovery is superseded by
        the later finding on the recovered artifact, so the property
        reports the worst of the LAST finding per (guard, target) pair —
        a solve that re-drew its way back to a healthy factor is healthy,
        with the bad draw visible in ``findings``/``actions``.
        """
        last: Dict[Tuple[str, str], str] = {}
        for f in self.findings:
            last[(f.guard, f.target)] = f.status
        return worst_status(*last.values()) if last else HEALTHY

    def add(self, finding: GuardFinding) -> GuardFinding:
        self.findings.append(finding)
        return finding

    def act(self, action: str) -> None:
        self.actions.append(action)

    def counters(self) -> Dict[str, int]:
        """Per-guard pass/fail counts of THIS report (not the globals)."""
        out: Dict[str, int] = {}
        for f in self.findings:
            key = f"{f.guard}.{f.status}"
            out[key] = out.get(key, 0) + 1
        if self.quarantined:
            out["quarantined"] = self.quarantined
        if self.attempts:
            out["attempts"] = self.attempts
        return out

    def describe(self) -> str:
        lines = [f"HealthReport(op={self.op or '?'}, status={self.status}, "
                 f"attempts={self.attempts}, quarantined={self.quarantined})"]
        for f in self.findings:
            lines.append("  " + f.describe())
        for a in self.actions:
            lines.append("  action: " + a)
        return "\n".join(lines)

    def to_json(self) -> Dict:
        return {
            "op": self.op,
            "status": self.status,
            "attempts": self.attempts,
            "quarantined": self.quarantined,
            "counters": self.counters(),
            "findings": [f.to_json() for f in self.findings],
            "actions": list(self.actions),
        }


# ---------------------------------------------------------------------------
# Process-global guard-event counters.
# ---------------------------------------------------------------------------

_LOCK = threading.Lock()
_COUNTERS: Dict[str, int] = {}
_RECENT_MAX = 64
_RECENT: List[Tuple[str, str]] = []   # (event, detail) ring for diagnostics


def record(event: str, n: int = 1, detail: Optional[str] = None) -> None:
    """Count one guard/recovery event process-wide.

    Event names are dotted paths: ``guard.<name>.<status>`` for guard
    verdicts, ``policy.<action>`` for escalation-ladder rungs,
    ``tune.cache_corrupt`` / ``factor.chol_downgrade`` / ``grass.quarantined``
    for layer-specific recoveries.
    """
    with _LOCK:
        _COUNTERS[event] = _COUNTERS.get(event, 0) + n
        if detail:
            _RECENT.append((event, detail))
            del _RECENT[:-_RECENT_MAX]


def counters() -> Dict[str, int]:
    """Snapshot of the process-wide guard-event counters."""
    with _LOCK:
        return dict(_COUNTERS)


def recent_events(limit: int = 10) -> List[Tuple[str, str]]:
    """The most recent (event, detail) pairs that carried a detail string."""
    with _LOCK:
        return list(_RECENT[-limit:])


def reset_counters() -> None:
    """Clear the global registry (tests and fresh CI runs)."""
    with _LOCK:
        _COUNTERS.clear()
        del _RECENT[:]


def counters_json(indent: int = 2) -> str:
    """The counters as a JSON document (the CI artifact payload)."""
    return json.dumps(counters(), indent=indent, sort_keys=True)


def summarize_counters(max_items: int = 8) -> str:
    """One-line counter summary for ``engine.explain`` output."""
    snap = counters()
    if not snap:
        return "no guard events recorded"
    items = sorted(snap.items())
    shown = ", ".join(f"{k}={v}" for k, v in items[:max_items])
    if len(items) > max_items:
        shown += f", … +{len(items) - max_items} more"
    return shown
