"""Guarded sketch execution: detection, re-draw escalation, fault injection.

BlockPerm-SJLT is an oblivious subspace embedding *with failure
probability δ*; this package is the production response to that tail —
detect (``guards``), discard and re-draw (``policy``), and prove the
whole loop works by injecting every failure class on purpose
(``inject``).  See ``docs/robustness.md``.

Only :mod:`repro.health.report` is imported eagerly: it is
dependency-free, so low layers (``kernels.lowering``, ``kernels.ops``,
``kernels.tune``) can record events through this package without import
cycles.  ``guards`` / ``policy`` / ``inject`` load lazily on first
attribute access.
"""
from __future__ import annotations

from repro.health import report
from repro.health.report import (DEGRADED, FAILED, HEALTHY, GuardFinding,
                                 HealthReport, worst_status)

_LAZY = ("guards", "policy", "inject")

__all__ = ["report", "guards", "policy", "inject",
           "GuardFinding", "HealthReport", "RedrawPolicy",
           "HEALTHY", "DEGRADED", "FAILED", "worst_status"]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f"repro.health.{name}")
        globals()[name] = mod
        return mod
    if name == "RedrawPolicy":
        from repro.health.policy import RedrawPolicy
        globals()["RedrawPolicy"] = RedrawPolicy
        return RedrawPolicy
    raise AttributeError(f"module 'repro.health' has no attribute {name!r}")
