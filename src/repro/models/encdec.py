"""Encoder-decoder model (seamless-m4t backbone; audio frontend stubbed).

Encoder: bidirectional self-attn + SwiGLU over precomputed frame embeddings
(the assignment's modality-frontend stub).  Decoder: causal self-attn +
cross-attn over encoder memory + SwiGLU.  Decode path caches self K/V and
the (fixed) cross K/V per layer.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers
from repro.sharding import partition as pt


def _init_enc_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.ones_init(cfg.d_model),
        "attn": attn.init_attention(k1, cfg, dtype),
        "ln2": layers.ones_init(cfg.d_model),
        "ffn": layers.init_ffn(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_block(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.ones_init(cfg.d_model),
        "self_attn": attn.init_attention(k1, cfg, dtype),
        "ln_x": layers.ones_init(cfg.d_model),
        "xattn": attn.init_attention(k2, cfg, dtype, cross=True),
        "ln2": layers.ones_init(cfg.d_model),
        "ffn": layers.init_ffn(k3, cfg.d_model, cfg.d_ff, dtype),
    }


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = layers.dtype_of(cfg.param_dtype)

    def init(self, key) -> Dict[str, Any]:
        cfg, dtype = self.cfg, self.dtype
        k = jax.random.split(key, 6)
        enc = jax.vmap(lambda kk: _init_enc_block(kk, cfg, dtype))(
            jax.random.split(k[0], cfg.encoder_layers))
        dec = jax.vmap(lambda kk: _init_dec_block(kk, cfg, dtype))(
            jax.random.split(k[1], cfg.n_layers))
        return {
            "embed": layers.embed_init(k[2], cfg.vocab_padded, cfg.d_model, dtype),
            "enc_blocks": enc,
            "enc_norm": layers.ones_init(cfg.d_model),
            "dec_blocks": dec,
            "final_norm": layers.ones_init(cfg.d_model),
            "lm_head": layers.embed_init(k[3], cfg.vocab_padded, cfg.d_model, dtype),
        }

    # -------------------------------------------------------------- encoder
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """frames: (B, T_enc, D) stub embeddings -> encoder memory."""
        cfg = self.cfg
        x = pt.shard_residual(frames.astype(self.dtype))

        def body(p, xx):
            h = layers.rms_norm(xx, p["ln1"])
            h = attn.attention_apply(p["attn"], cfg, h, causal=False)
            xx = pt.shard_residual(xx + h)
            h2 = layers.ffn_apply(p["ffn"], layers.rms_norm(xx, p["ln2"]))
            return pt.shard_residual(xx + h2)

        f = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(lambda c, p: (f(p, c), None), x, params["enc_blocks"])
        return layers.rms_norm(x, params["enc_norm"])

    # -------------------------------------------------------------- decoder
    def hidden(self, params, tokens: jnp.ndarray,
               extra: Optional[Dict[str, jnp.ndarray]] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        memory = self.encode(params, extra["encoder_frames"])
        B, S = tokens.shape
        x = pt.shard_residual(params["embed"][tokens])
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]

        def body(p, xx):
            h = layers.rms_norm(xx, p["ln1"])
            h = attn.attention_apply(p["self_attn"], cfg, h, positions=positions)
            xx = pt.shard_residual(xx + h)
            h = layers.rms_norm(xx, p["ln_x"])
            h = attn.attention_apply(p["xattn"], cfg, h, kv_src=memory, causal=False)
            xx = pt.shard_residual(xx + h)
            h2 = layers.ffn_apply(p["ffn"], layers.rms_norm(xx, p["ln2"]))
            return pt.shard_residual(xx + h2)

        f = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(lambda c, p: (f(p, c), None), x, params["dec_blocks"])
        return layers.rms_norm(x, params["final_norm"]), jnp.float32(0.0)

    def apply(self, params, tokens: jnp.ndarray,
              extra: Optional[Dict[str, jnp.ndarray]] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        x, aux = self.hidden(params, tokens, extra)
        logits = layers.unembed_logits(x, params["lm_head"])
        return pt.shard_logits(logits), aux

    def prefill(self, params, tokens: jnp.ndarray,
                extra: Optional[Dict[str, jnp.ndarray]] = None):
        x, _ = self.hidden(params, tokens, extra)
        return layers.unembed_logits(x[:, -1:, :], params["lm_head"])[:, 0, :]

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        x, aux = self.hidden(params, batch["tokens"],
                             {"encoder_frames": batch["encoder_frames"]})
        ce = layers.softmax_xent_chunked(x, params["lm_head"], batch["labels"])
        return ce, {"ce": ce, "aux": aux}

    # --------------------------------------------------------------- decode
    def init_decode_state(self, params, batch: int, max_seq: int,
                          extra: Optional[Dict[str, jnp.ndarray]] = None):
        cfg, dtype = self.cfg, self.dtype
        memory = self.encode(params, extra["encoder_frames"])
        hd = cfg.resolved_head_dim

        def cross_kv(p):
            k = (memory @ p["xattn"]["wk"]).reshape(batch, -1, cfg.n_kv_heads, hd)
            v = (memory @ p["xattn"]["wv"]).reshape(batch, -1, cfg.n_kv_heads, hd)
            return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)

        ck, cv = jax.vmap(cross_kv)(params["dec_blocks"])
        shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_seq, hd)
        return {
            "kv": attn.KVCache(k=pt.shard_kv(jnp.zeros(shape, dtype)),
                               v=pt.shard_kv(jnp.zeros(shape, dtype))),
            "cross_kv": (ck, cv),
        }

    def decode_step(self, params, state, tokens: jnp.ndarray, pos):
        cfg = self.cfg
        x = params["embed"][tokens]
        ck, cv = state["cross_kv"]
        hd = cfg.resolved_head_dim

        def body(xx, inp):
            p, kv, ckk, cvv = inp
            h = layers.rms_norm(xx, p["ln1"])
            h, kv_new = attn.decode_attention(p["self_attn"], cfg, h, kv, pos)
            xx = xx + h
            # cross attention against fixed memory K/V
            h = layers.rms_norm(xx, p["ln_x"])
            B = h.shape[0]
            q = (h @ p["xattn"]["wq"]).reshape(B, 1, cfg.n_heads, hd)
            Hkv = cfg.n_kv_heads
            G = cfg.n_heads // Hkv
            qh = q.reshape(B, 1, Hkv, G, hd)
            sc = jnp.einsum("bshgd,bhtd->bhgst", qh, ckk).astype(jnp.float32)
            pr = jax.nn.softmax(sc / jnp.sqrt(jnp.float32(hd)), -1).astype(cvv.dtype)
            o = jnp.einsum("bhgst,bhtd->bshgd", pr, cvv)
            o = o.reshape(B, 1, cfg.n_heads * hd) @ p["xattn"]["wo"]
            xx = xx + o
            h2 = layers.ffn_apply(p["ffn"], layers.rms_norm(xx, p["ln2"]))
            return xx + h2, kv_new

        x, kv_new = jax.lax.scan(body, x, (params["dec_blocks"], state["kv"], ck, cv))
        x = layers.rms_norm(x, params["final_norm"])
        logits = layers.unembed_logits(x, params["lm_head"])
        return logits, {"kv": kv_new, "cross_kv": (ck, cv)}
