"""Unified decoder LM covering the dense / moe / ssm / hybrid / vlm families.

Layer stacks are stacked-on-leading-axis pytrees driven by ``jax.lax.scan``
(+ optional ``jax.checkpoint`` remat), so lowered HLO size is O(1) in depth.
Activation sharding constraints come from ``repro.sharding.partition``
(no-ops outside a mesh context, so CPU smoke tests run unchanged).

Families:
  dense   — [ln→GQA-attn] + [ln→SwiGLU]
  moe     — [ln→GQA-attn] + [ln→MoE (+ optional dense residual branch)]
  ssm     — RWKV6 blocks (time-mix + channel-mix)
  hybrid  — Mamba2 stack with a *shared* (weight-tied) attention+FFN block
            applied after every ``attn_every`` SSM layers (zamba2)
  vlm     — dense stack with cross-attention image layers every Nth layer
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers, moe, ssm
from repro.sharding import partition as pt


def _split_keys(key, n):
    return jax.random.split(key, n)


# ===========================================================================
# per-layer init (vmapped over the stack)
# ===========================================================================

def _init_dense_block(key, cfg: ModelConfig, dtype):
    k1, k2 = _split_keys(key, 2)
    return {
        "ln1": layers.ones_init(cfg.d_model),
        "attn": attn.init_attention(k1, cfg, dtype),
        "ln2": layers.ones_init(cfg.d_model),
        "ffn": layers.init_ffn(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_moe_block(key, cfg: ModelConfig, dtype):
    k1, k2 = _split_keys(key, 2)
    return {
        "ln1": layers.ones_init(cfg.d_model),
        "attn": attn.init_attention(k1, cfg, dtype),
        "ln2": layers.ones_init(cfg.d_model),
        "moe": moe.init_moe(k2, cfg, dtype),
    }


def _init_rwkv_block(key, cfg: ModelConfig, dtype):
    return {
        "ln1": layers.ones_init(cfg.d_model),
        "rwkv": ssm.init_rwkv6(key, cfg, dtype),
        "ln2": layers.ones_init(cfg.d_model),
    }


def _init_mamba_block(key, cfg: ModelConfig, dtype):
    return {
        "ln1": layers.ones_init(cfg.d_model),
        "mamba": ssm.init_mamba2(key, cfg, dtype),
    }


def _init_cross_block(key, cfg: ModelConfig, dtype):
    k1, k2 = _split_keys(key, 2)
    return {
        "ln1": layers.ones_init(cfg.d_model),
        "xattn": attn.init_attention(k1, cfg, dtype, cross=True),
        "ln2": layers.ones_init(cfg.d_model),
        "ffn": layers.init_ffn(k2, cfg.d_model, cfg.d_ff, dtype),
    }


# ===========================================================================
# block applies (train/prefill)
# ===========================================================================

def _dense_block_apply(p, cfg, x, positions):
    h = layers.rms_norm(x, p["ln1"])
    h = attn.attention_apply(p["attn"], cfg, h, positions=positions)
    x = pt.shard_residual(x + h)
    h2 = layers.ffn_apply(p["ffn"], layers.rms_norm(x, p["ln2"]))
    return pt.shard_residual(x + h2), jnp.float32(0.0)


def _moe_block_apply(p, cfg, x, positions):
    h = layers.rms_norm(x, p["ln1"])
    h = attn.attention_apply(p["attn"], cfg, h, positions=positions)
    x = pt.shard_residual(x + h)
    h2, aux = moe.moe_apply(p["moe"], cfg, layers.rms_norm(x, p["ln2"]))
    return pt.shard_residual(x + h2), aux


def _rwkv_block_apply(p, cfg, x, positions):
    h, _ = ssm.rwkv6_time_mix(p["rwkv"], cfg, layers.rms_norm(x, p["ln1"]))
    x = pt.shard_residual(x + h)
    h2, _ = ssm.rwkv6_channel_mix(p["rwkv"], cfg, layers.rms_norm(x, p["ln2"]))
    return pt.shard_residual(x + h2), jnp.float32(0.0)


def _mamba_block_apply(p, cfg, x):
    h = ssm.mamba2_apply(p["mamba"], cfg, layers.rms_norm(x, p["ln1"]))
    return pt.shard_residual(x + h), jnp.float32(0.0)


def _shared_attn_apply(p, cfg, x, positions):
    h = layers.rms_norm(x, p["ln1"])
    h = attn.attention_apply(p["attn"], cfg, h, positions=positions)
    x = pt.shard_residual(x + h)
    h2 = layers.ffn_apply(p["ffn"], layers.rms_norm(x, p["ln2"]))
    return pt.shard_residual(x + h2)


def _cross_block_apply(p, cfg, x, img):
    h = layers.rms_norm(x, p["ln1"])
    h = attn.attention_apply(p["xattn"], cfg, h, kv_src=img, causal=False)
    x = pt.shard_residual(x + h)
    h2 = layers.ffn_apply(p["ffn"], layers.rms_norm(x, p["ln2"]))
    return pt.shard_residual(x + h2)


# ===========================================================================
# model
# ===========================================================================

class DecoderLM:
    """Family-dispatching decoder LM (see module docstring)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = layers.dtype_of(cfg.param_dtype)

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        cfg, dtype = self.cfg, self.dtype
        keys = _split_keys(key, 8)
        params: Dict[str, Any] = {
            "embed": layers.embed_init(keys[0], cfg.vocab_padded, cfg.d_model, dtype),
            "final_norm": layers.ones_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.embed_init(
                keys[1], cfg.vocab_padded, cfg.d_model, dtype)

        def stack(init_fn, key, n):
            return jax.vmap(lambda k: init_fn(k, cfg, dtype))(_split_keys(key, n))

        fam = cfg.family
        if fam in ("dense", "moe"):
            fn = _init_moe_block if fam == "moe" else _init_dense_block
            params["blocks"] = stack(fn, keys[2], cfg.n_layers)
        elif fam == "ssm":
            params["blocks"] = stack(_init_rwkv_block, keys[2], cfg.n_layers)
        elif fam == "hybrid":
            n_super = cfg.n_layers // cfg.attn_every
            tail = cfg.n_layers - n_super * cfg.attn_every
            inner = stack(_init_mamba_block, keys[2], n_super * cfg.attn_every)
            params["blocks"] = jax.tree.map(
                lambda a: a.reshape(n_super, cfg.attn_every, *a.shape[1:]), inner)
            if tail:
                params["tail_blocks"] = stack(_init_mamba_block, keys[3], tail)
            params["shared_attn"] = {
                "ln1": layers.ones_init(cfg.d_model),
                "attn": attn.init_attention(keys[4], cfg, dtype),
                "ln2": layers.ones_init(cfg.d_model),
                "ffn": layers.init_ffn(keys[5], cfg.d_model, cfg.d_ff, dtype),
            }
        elif fam == "vlm":
            per = cfg.cross_attn_every
            n_super = cfg.n_layers // per
            selfs = stack(_init_dense_block, keys[2], n_super * (per - 1))
            params["blocks"] = jax.tree.map(
                lambda a: a.reshape(n_super, per - 1, *a.shape[1:]), selfs)
            params["cross_blocks"] = stack(_init_cross_block, keys[3], n_super)
        else:
            raise ValueError(f"family {fam} handled by a different model class")
        return params

    # ------------------------------------------------------------- backbone
    def _backbone(self, params, x, positions, extra) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(B,S,D) -> (B,S,D), aux_loss."""
        cfg = self.cfg
        fam = cfg.family
        remat = cfg.remat

        def scan_blocks(body, x, blocks):
            f = jax.checkpoint(body) if remat else body

            def step(carry, p):
                xx, aux = carry
                xx, a = f(p, xx)
                return (xx, aux + a), None

            (x, aux), _ = jax.lax.scan(step, (x, jnp.float32(0.0)), blocks)
            return x, aux

        if fam in ("dense", "moe"):
            apply_fn = _moe_block_apply if fam == "moe" else _dense_block_apply
            body = lambda p, xx: apply_fn(p, cfg, xx, positions)
            return scan_blocks(body, x, params["blocks"])

        if fam == "ssm":
            body = lambda p, xx: _rwkv_block_apply(p, cfg, xx, positions)
            return scan_blocks(body, x, params["blocks"])

        if fam == "hybrid":
            shared = params["shared_attn"]

            def super_body(p_group, xx):
                def inner(pp, xxx):
                    return _mamba_block_apply(pp, cfg, xxx)
                xx, aux = scan_blocks(inner, xx, p_group)
                xx = _shared_attn_apply(shared, cfg, xx, positions)
                return xx, aux

            f = jax.checkpoint(super_body) if remat else super_body

            def step(carry, p_group):
                xx, aux = carry
                xx, a = f(p_group, xx)
                return (xx, aux + a), None

            (x, aux), _ = jax.lax.scan(step, (x, jnp.float32(0.0)), params["blocks"])
            if "tail_blocks" in params:
                x, a2 = scan_blocks(
                    lambda pp, xxx: _mamba_block_apply(pp, cfg, xxx),
                    x, params["tail_blocks"])
                aux = aux + a2
            return x, aux

        if fam == "vlm":
            img = extra["image_embeds"].astype(x.dtype)

            def super_body(ps, xx):
                p_self, p_cross = ps

                def inner(pp, xxx):
                    return _dense_block_apply(pp, cfg, xxx, positions)
                xx, aux = scan_blocks(inner, xx, p_self)
                xx = _cross_block_apply(p_cross, cfg, xx, img)
                return xx, aux

            f = jax.checkpoint(super_body) if remat else super_body

            def step(carry, ps):
                xx, aux = carry
                xx, a = f(ps, xx)
                return (xx, aux + a), None

            (x, aux), _ = jax.lax.scan(
                step, (x, jnp.float32(0.0)),
                (params["blocks"], params["cross_blocks"]))
            return x, aux

        raise ValueError(fam)

    # ---------------------------------------------------------------- apply
    def hidden(self, params, tokens: jnp.ndarray,
               extra: Optional[Dict[str, jnp.ndarray]] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """tokens (B,S) -> final-norm hidden (B,S,D), aux loss."""
        B, S = tokens.shape
        x = params["embed"][tokens]                    # (B,S,D)
        x = pt.shard_residual(x)
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        x, aux = self._backbone(params, x, positions, extra or {})
        return layers.rms_norm(x, params["final_norm"]), aux

    def _head(self, params):
        return params["embed"] if self.cfg.tie_embeddings else params["lm_head"]

    def apply(self, params, tokens: jnp.ndarray,
              extra: Optional[Dict[str, jnp.ndarray]] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """tokens (B,S) -> logits (B,S,V_pad) f32, aux loss.  (Tests / small
        shapes only — training uses the chunked CE that never materializes
        full logits.)"""
        x, aux = self.hidden(params, tokens, extra)
        logits = layers.unembed_logits(x, self._head(params))
        return pt.shard_logits(logits), aux

    def prefill(self, params, tokens: jnp.ndarray,
                extra: Optional[Dict[str, jnp.ndarray]] = None):
        """Prefill step: last-position logits only (B,V)."""
        x, _ = self.hidden(params, tokens, extra)
        last = x[:, -1:, :]
        return layers.unembed_logits(last, self._head(params))[:, 0, :]

    def loss(self, params, batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict]:
        x, aux = self.hidden(params, batch["tokens"],
                             {k: v for k, v in batch.items()
                              if k not in ("tokens", "labels")})
        ce = layers.softmax_xent_chunked(x, self._head(params), batch["labels"])
        return ce + aux, {"ce": ce, "aux": aux}

    # --------------------------------------------------------------- decode
    def init_decode_state(self, params, batch: int, max_seq: int,
                          extra: Optional[Dict[str, jnp.ndarray]] = None):
        cfg, dtype = self.cfg, self.dtype
        fam = cfg.family
        if fam in ("dense", "moe"):
            return {"kv": self._stacked_kv(cfg.n_layers, batch, max_seq)}
        if fam == "ssm":
            mk = lambda _: ssm.init_rwkv6_state(cfg, batch, dtype)
            states = [mk(i) for i in range(cfg.n_layers)]
            return {"rwkv": jax.tree.map(lambda *xs: jnp.stack(xs), *states)}
        if fam == "hybrid":
            n_super = cfg.n_layers // cfg.attn_every
            tail = cfg.n_layers - n_super * cfg.attn_every
            mstates = [ssm.init_mamba2_state(cfg, batch, dtype)
                       for _ in range(n_super * cfg.attn_every)]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *mstates)
            stacked = jax.tree.map(
                lambda a: a.reshape(n_super, cfg.attn_every, *a.shape[1:]), stacked)
            st = {"mamba": stacked,
                  "attn_kv": self._stacked_kv(n_super, batch, max_seq)}
            if tail:
                tstates = [ssm.init_mamba2_state(cfg, batch, dtype)
                           for _ in range(tail)]
                st["mamba_tail"] = jax.tree.map(lambda *xs: jnp.stack(xs), *tstates)
            return st
        if fam == "vlm":
            per = cfg.cross_attn_every
            n_super = cfg.n_layers // per
            img = extra["image_embeds"].astype(dtype)
            # precompute cross K/V once per cross layer
            def cross_kv(p):
                hd = cfg.resolved_head_dim
                k = (img @ p["xattn"]["wk"]).reshape(batch, -1, cfg.n_kv_heads, hd)
                v = (img @ p["xattn"]["wv"]).reshape(batch, -1, cfg.n_kv_heads, hd)
                return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
            ck, cv = jax.vmap(cross_kv)(params["cross_blocks"])
            return {
                "kv": self._stacked_kv(n_super * (per - 1), batch, max_seq,
                                       reshape=(n_super, per - 1)),
                "cross_kv": (ck, cv),
            }
        raise ValueError(fam)

    def _stacked_kv(self, n: int, batch: int, max_seq: int, reshape=None):
        cfg, dtype = self.cfg, self.dtype
        hd = cfg.resolved_head_dim
        shape = (n, batch, cfg.n_kv_heads, max_seq, hd)
        if reshape:
            shape = (*reshape, batch, cfg.n_kv_heads, max_seq, hd)
        return attn.KVCache(k=pt.shard_kv(jnp.zeros(shape, dtype)),
                            v=pt.shard_kv(jnp.zeros(shape, dtype)))

    def decode_step(self, params, state, tokens: jnp.ndarray, pos):
        """tokens (B,1) int32; pos scalar int32. -> (logits (B,1,V), new state)."""
        cfg = self.cfg
        fam = cfg.family
        x = params["embed"][tokens]
        if fam in ("dense", "moe"):
            def body(xx, inp):
                p, kv = inp
                h = layers.rms_norm(xx, p["ln1"])
                h, kv_new = attn.decode_attention(p["attn"], cfg, h, kv, pos)
                xx = xx + h
                h2 = layers.rms_norm(xx, p["ln2"])
                if fam == "moe":
                    h2 = moe.moe_decode(p["moe"], cfg, h2)
                else:
                    h2 = layers.ffn_apply(p["ffn"], h2)
                return xx + h2, kv_new

            x, kv_new = jax.lax.scan(body, x, (params["blocks"], state["kv"]))
            new_state = {"kv": kv_new}
        elif fam == "ssm":
            def body(xx, inp):
                p, st = inp
                h, st = ssm.rwkv6_decode(p["rwkv"], cfg,
                                         layers.rms_norm(xx, p["ln1"]), st)
                xx = xx + h
                h2, st = ssm.rwkv6_channel_mix_decode(
                    p["rwkv"], cfg, layers.rms_norm(xx, p["ln2"]), st)
                return xx + h2, st

            x, st_new = jax.lax.scan(body, x, (params["blocks"], state["rwkv"]))
            new_state = {"rwkv": st_new}
        elif fam == "hybrid":
            shared = params["shared_attn"]

            def mamba_body(xx, inp):
                p, st = inp
                h, st = ssm.mamba2_decode(p["mamba"], cfg,
                                          layers.rms_norm(xx, p["ln1"]), st)
                return xx + h, st

            def super_body(xx, inp):
                p_group, st_group, kv = inp
                xx, st_new = jax.lax.scan(mamba_body, xx, (p_group, st_group))
                h = layers.rms_norm(xx, shared["ln1"])
                h, kv_new = attn.decode_attention(shared["attn"], cfg, h, kv, pos)
                xx = xx + h
                h2 = layers.ffn_apply(shared["ffn"],
                                      layers.rms_norm(xx, shared["ln2"]))
                return xx + h2, (st_new, kv_new)

            x, (m_new, kv_new) = jax.lax.scan(
                super_body, x, (params["blocks"], state["mamba"], state["attn_kv"]))
            new_state = {"mamba": m_new, "attn_kv": kv_new}
            if "tail_blocks" in params:
                x, t_new = jax.lax.scan(
                    mamba_body, x, (params["tail_blocks"], state["mamba_tail"]))
                new_state["mamba_tail"] = t_new
        elif fam == "vlm":
            ck, cv = state["cross_kv"]

            def self_body(xx, inp):
                p, kv = inp
                h = layers.rms_norm(xx, p["ln1"])
                h, kv_new = attn.decode_attention(p["attn"], cfg, h, kv, pos)
                xx = xx + h
                h2 = layers.ffn_apply(p["ffn"], layers.rms_norm(xx, p["ln2"]))
                return xx + h2, kv_new

            def super_body(xx, inp):
                p_self, kv, p_cross, ckk, cvv = inp
                xx, kv_new = jax.lax.scan(self_body, xx, (p_self, kv))
                h = layers.rms_norm(xx, p_cross["ln1"])
                # cross attention against fixed image K/V
                B = h.shape[0]
                hd = cfg.resolved_head_dim
                q = (h @ p_cross["xattn"]["wq"]).reshape(B, 1, cfg.n_heads, hd)
                Hkv = cfg.n_kv_heads
                G = cfg.n_heads // Hkv
                qh = q.reshape(B, 1, Hkv, G, hd)
                sc = jnp.einsum("bshgd,bhtd->bhgst", qh, ckk).astype(jnp.float32)
                pr = jax.nn.softmax(sc / jnp.sqrt(jnp.float32(hd)), -1).astype(cvv.dtype)
                o = jnp.einsum("bhgst,bhtd->bshgd", pr, cvv)
                o = o.reshape(B, 1, cfg.n_heads * hd) @ p_cross["xattn"]["wo"]
                xx = xx + o
                h2 = layers.ffn_apply(p_cross["ffn"],
                                      layers.rms_norm(xx, p_cross["ln2"]))
                return xx + h2, kv_new

            x, kv_new = jax.lax.scan(
                super_body, x,
                (params["blocks"], state["kv"], params["cross_blocks"], ck, cv))
            new_state = {"kv": kv_new, "cross_kv": (ck, cv)}
        else:
            raise ValueError(fam)

        x = layers.rms_norm(x, params["final_norm"])
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = layers.unembed_logits(x, head)
        return logits, new_state
