"""Shared model layers: norms, RoPE, SwiGLU, initializers.

Models are pure-functional: params are pytrees of jnp arrays, produced by
``init_*`` functions and consumed by ``apply``-style functions.  Layer stacks
are *stacked on a leading axis* and driven by ``jax.lax.scan`` so the lowered
HLO is O(1) in depth (critical for the 81-layer / 64-layer dry-runs).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (LLM standard)."""
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    w = jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim), jnp.float32)
    return (w * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    w = jax.random.truncated_normal(key, -2.0, 2.0, (vocab, dim), jnp.float32)
    return (w * 0.02).astype(dtype)


def ones_init(dim, dtype=jnp.float32):
    return jnp.ones((dim,), dtype)


def zeros_init(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# RMSNorm (norm math always in f32)
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------

def init_ffn(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d_model, d_ff, dtype),
        "wi_up": dense_init(k2, d_model, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d_model, dtype),
    }


def ffn_apply(params, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(x @ params["wi_gate"])
    up = x @ params["wi_up"]
    return (gate * up) @ params["wo"]


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def unembed_logits(x: jnp.ndarray, embedding: jnp.ndarray) -> jnp.ndarray:
    """Tied-embedding logits: (B,S,D) @ (V,D)^T -> (B,S,V), f32."""
    return jnp.einsum(
        "bsd,vd->bsv", x.astype(jnp.float32), embedding.astype(jnp.float32)
    )


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token cross-entropy; logits (B,S,V) f32, labels (B,S) int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def softmax_xent_chunked(x: jnp.ndarray, head: jnp.ndarray,
                         labels: jnp.ndarray, chunk: int = 256) -> jnp.ndarray:
    """Memory-efficient CE for huge vocabularies (TP-safe).

    Never materializes the full (B,S,V) logits: scans over sequence chunks,
    computing (B,chunk,V) logits transiently.  The gold logit is extracted
    with a one-hot contraction (a sharded-V-friendly einsum that lowers to a
    partial sum + small all-reduce under TP, instead of a cross-shard gather).

    x: (B,S,D) final hidden; head: (V,D); labels: (B,S).
    """
    B, S, D = x.shape
    V = head.shape[0]
    chunk = min(chunk, S)
    while S % chunk != 0:
        chunk //= 2
    chunk = max(chunk, 1)
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)       # (nc,B,c,D)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)        # (nc,B,c)

    # remat the chunk: without it, scan-AD saves every chunk's (B,c,V) f32
    # logits for backward — i.e. the full logits tensor we chunked to avoid
    # (§Perf iteration 1; recompute costs one extra (B,c,D)×(D,V) matmul).
    @jax.checkpoint
    def body(acc, inp):
        xx, ll = inp
        logits = jnp.einsum("bcd,vd->bcv", xx.astype(jnp.float32),
                            head.astype(jnp.float32))
        logz = jax.nn.logsumexp(logits, axis=-1)                # (B,c)
        onehot = jax.nn.one_hot(ll, V, dtype=jnp.float32)
        gold = jnp.einsum("bcv,bcv->bc", logits, onehot)
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (xc, lc))
    return total / (B * S)
