"""Model factory + input_specs (ShapeDtypeStruct stand-ins for the dry-run)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.encdec import EncDecLM
from repro.models.lm import DecoderLM


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return DecoderLM(cfg)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one global training batch."""
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "encdec":
        specs["encoder_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.image_tokens, cfg.d_model), jnp.float32)
    return specs


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def extra_inputs_concrete(cfg: ModelConfig, batch: int, seq: int, key=None):
    """Concrete (small) modality-stub inputs for smoke tests."""
    key = key if key is not None else jax.random.PRNGKey(0)
    extra = {}
    if cfg.family == "encdec":
        extra["encoder_frames"] = jax.random.normal(
            key, (batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        extra["image_embeds"] = jax.random.normal(
            key, (batch, cfg.image_tokens, cfg.d_model), jnp.float32)
    return extra


def make_train_batch(cfg: ModelConfig, batch: int, seq: int, key):
    """Concrete random batch for smoke tests / examples."""
    k1, k2, k3 = jax.random.split(key, 3)
    out = {
        "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size, jnp.int32),
    }
    out.update(extra_inputs_concrete(cfg, batch, seq, k3))
    return out
