"""State-space layers: Mamba2 (chunked SSD) and RWKV6 (Finch).

Mamba2 uses the chunked state-space-duality form: intra-chunk quadratic
(attention-like, MXU-friendly) + inter-chunk state passing via a scan over
chunks — O(S·Q) compute with O(S/Q) sequential steps.  RWKV6 training uses a
time scan (its data-dependent per-channel decay makes the stable chunked form
a kernel-level project; noted in DESIGN.md — candidate for a Pallas kernel).

Both expose a decode path carrying a recurrent state, which is what makes the
``long_500k`` cell runnable for the ssm/hybrid archs.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers


# ===========================================================================
# Mamba2
# ===========================================================================

class Mamba2State(NamedTuple):
    h: jnp.ndarray          # (B, H, P, N) SSM state
    conv: jnp.ndarray       # (B, K-1, conv_dim) causal-conv tail


def mamba2_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_inner, H, P, N = mamba2_dims(cfg)
    conv_dim = d_inner + 2 * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # in_proj -> [z, x, B, C, dt]
        "in_proj": layers.dense_init(k1, d, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_kernel, conv_dim), jnp.float32)
                   * 0.1).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_w": layers.ones_init(d_inner),
        "out_proj": layers.dense_init(k3, d_inner, d, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv: x (B,S,C), w (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out


def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    d_inner, H, P, N = mamba2_dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xBC, dt


def mamba2_apply(params, cfg: ModelConfig, x: jnp.ndarray,
                 chunk: int = 64) -> jnp.ndarray:
    """Training/prefill forward. x: (B,S,D) -> (B,S,D). Chunked SSD."""
    B, S, _ = x.shape
    d_inner, H, P, N = mamba2_dims(cfg)
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    proj = x @ params["in_proj"]
    z, xBC, dt_raw = _split_proj(cfg, proj)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"]))
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    A = -jnp.exp(params["A_log"])                                          # (H,)
    log_a = (dt * A).astype(jnp.float32)                                   # (B,S,H) ≤ 0

    # chunked views
    xs = xs.reshape(B, nc, Q, H, P).astype(jnp.float32)
    Bm = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    Cm = Cm.reshape(B, nc, Q, N).astype(jnp.float32)
    dt_c = dt.reshape(B, nc, Q, H)
    la = log_a.reshape(B, nc, Q, H)
    l_cum = jnp.cumsum(la, axis=2)                                         # (B,nc,Q,H)
    l_tot = l_cum[:, :, -1, :]                                             # (B,nc,H)

    xw = xs * dt_c[..., None]                                              # Δ·x
    bf = jnp.bfloat16

    # ---- intra-chunk (quadratic, masked) ----
    CB = jnp.einsum("bnqk,bnsk->bnqs", Cm.astype(bf), Bm.astype(bf),
                    preferred_element_type=jnp.float32)                    # (B,nc,Q,Q)
    # decay(q,s) = exp(l_q - l_s) for s ≤ q.  Mask INSIDE the exp: for s > q
    # ldiff > 0 would overflow and poison gradients through the where.
    ldiff = l_cum[:, :, :, None, :] - l_cum[:, :, None, :, :]              # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.exp(jnp.where(mask[None, None, :, :, None], ldiff, -1e9))
    # bf16 operands for the MXU contraction (decay ≤ 1 and Δx are tame);
    # accumulation stays f32 — halves the dominant (B,nc,Q,Q,H) traffic.
    M = (CB[..., None] * decay).astype(bf)                                 # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bnqsh,bnshp->bnqhp", M, xw.astype(bf),
                         preferred_element_type=jnp.float32)

    # ---- chunk summaries and inter-chunk scan ----
    w_end = jnp.exp(l_tot[:, :, None, :] - l_cum)                          # (B,nc,Q,H)
    S_c = jnp.einsum("bnqh,bnqhp,bnqk->bnhpk",
                     w_end.astype(bf), xw.astype(bf), Bm.astype(bf),
                     preferred_element_type=jnp.float32)                   # (B,nc,H,P,N)

    # NOTE (§Perf, refuted experiment): folding the y_inter einsum into the
    # scan body (to avoid stacking h_prevs) measured WORSE (90.5 → 109.7 s):
    # scan-AD saves the state carries either way, and the fold added
    # per-iteration reads of the C/l_cum chunks.  Kept the stacked form.
    def step(h_prev, inputs):
        s_c, ltot = inputs                                                 # (B,H,P,N),(B,H)
        h_new = h_prev * jnp.exp(ltot)[:, :, None, None] + s_c
        return h_new, h_prev

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, h_prevs = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(l_tot, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                                  # (B,nc,H,P,N)

    y_inter = jnp.einsum("bnqk,bnqh,bnhpk->bnqhp",
                         Cm.astype(bf), jnp.exp(l_cum).astype(bf),
                         h_prevs.astype(bf),
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + params["D"][None, None, :, None] * xs.reshape(B, S, H, P)
    y = y.reshape(B, S, d_inner)
    y = layers.rms_norm(y.astype(x.dtype), params["norm_w"])
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"]


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype) -> Mamba2State:
    d_inner, H, P, N = mamba2_dims(cfg)
    conv_dim = d_inner + 2 * N
    return Mamba2State(
        h=jnp.zeros((batch, H, P, N), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
    )


def mamba2_decode(params, cfg: ModelConfig, x: jnp.ndarray,
                  state: Mamba2State):
    """One-token decode. x: (B,1,D) -> (B,1,D), new state."""
    B = x.shape[0]
    d_inner, H, P, N = mamba2_dims(cfg)
    proj = x @ params["in_proj"]
    z, xBC, dt_raw = _split_proj(cfg, proj)
    # conv over [tail, new]
    window = jnp.concatenate([state.conv, xBC], axis=1)       # (B, K, conv)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))[:, None, :]
    xBC = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = window[:, 1:, :]
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)                                       # (B,H)
    xs = xs.reshape(B, H, P).astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)                         # (B,N)
    Cv = Cm[:, 0].astype(jnp.float32)
    xw = xs * dt[..., None]
    h_new = state.h * a[..., None, None] + jnp.einsum("bhp,bk->bhpk", xw, Bv)
    y = jnp.einsum("bhpk,bk->bhp", h_new, Cv) + params["D"][None, :, None] * xs
    y = y.reshape(B, 1, d_inner)
    y = layers.rms_norm(y.astype(x.dtype), params["norm_w"])
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"], Mamba2State(h=h_new, conv=new_conv)


# ===========================================================================
# RWKV6 (Finch)
# ===========================================================================

class RWKV6State(NamedTuple):
    wkv: jnp.ndarray        # (B, H, C, C) per-head state (key dim × value dim)
    shift: jnp.ndarray      # (B, D) previous token embedding (token-shift)
    ffn_shift: jnp.ndarray  # (B, D) token-shift for channel-mix


LORA_DIM = 64


def init_rwkv6(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    C = cfg.ssm_head_dim
    H = d // C
    return {
        # token-shift interpolation weights per projection
        "mu": (0.5 * jnp.ones((5, d), jnp.float32)).astype(dtype),  # r,k,v,w,g
        "wr": layers.dense_init(ks[0], d, d, dtype),
        "wk": layers.dense_init(ks[1], d, d, dtype),
        "wv": layers.dense_init(ks[2], d, d, dtype),
        "wg": layers.dense_init(ks[3], d, d, dtype),
        # data-dependent decay LoRA:  w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "wA": layers.dense_init(ks[4], d, LORA_DIM, dtype),
        "wB": layers.dense_init(ks[5], LORA_DIM, d, dtype, scale=0.01),
        "u": (0.5 * jnp.ones((H, C), jnp.float32)),            # bonus
        "wo": layers.dense_init(ks[6], d, d, dtype),
        "ln_w": layers.ones_init(d),                            # per-head group norm
        # channel-mix
        "mu_ffn": (0.5 * jnp.ones((2, d), jnp.float32)).astype(dtype),
        "ck": layers.dense_init(ks[7], d, cfg.d_ff, dtype),
        "cv": layers.dense_init(ks[8], cfg.d_ff, d, dtype),
        "cr": layers.dense_init(ks[9], d, d, dtype),
    }


def _rwkv_proj(params, cfg, x, x_prev):
    """Token-shifted projections. x,(B,S,D); x_prev (B,S,D) = x shifted by 1."""
    xx = x_prev - x
    mu = params["mu"].astype(x.dtype)
    xr = x + xx * mu[0]
    xk = x + xx * mu[1]
    xv = x + xx * mu[2]
    xw = x + xx * mu[3]
    xg = x + xx * mu[4]
    r = xr @ params["wr"]
    k = xk @ params["wk"]
    v = xv @ params["wv"]
    g = jax.nn.silu(xg @ params["wg"])
    logw = -jnp.exp(
        params["w0"]
        + (jnp.tanh(xw @ params["wA"]) @ params["wB"]).astype(jnp.float32)
    )                                                          # (B,S,D) ≤ 0
    # Clamp per-step decay: bounds the intra-chunk exponent range of the
    # chunked-parallel form (Q·|logw| must stay < exp range).  e^-2.5 per
    # step is already a ~92% forget; over a 32-step chunk it is total.
    logw = jnp.maximum(logw, -2.5)
    return r, k, v, g, logw


def _rwkv_heads(x, H, C):
    B, S, _ = x.shape
    return x.reshape(B, S, H, C)


RWKV_CHUNK = 32          # intra-chunk length Q (exponent range Q·2.5 = 80 < 88)
# chunks per remat group (nested remat bounds AD memory).  Plain scan
# (grp=0) was tried and REFUTED: 148 s vs 30 s — scan-AD stacks every
# chunk's carries+inputs through the layer backward (§Perf iteration 2c).
RWKV_INNER_GROUP = 8


def _wkv_chunk(u, S0, r, k, v, logw):
    """One chunk of the wkv recurrence in closed (parallel) form.

    All (B,H,Q,C).  S0: (B,H,C,C) state *before* the chunk.  Returns
    (out (B,H,Q,C_v), S_end).  Factored log-space form:

      out_t = r_t·S_{t-1} + u·(r_t·k_t)·v_t
      r_t·S_{t-1} = Σ_{s<t} (r_t e^{L_{t-1}}) · (k_s e^{-L_s}) v_s
                    + (r_t e^{L_{t-1}}) · S0
      S_end = e^{L_Q}·S0 + e^{L_Q} Σ_s (k_s e^{-L_s}) v_s

    with L_t = Σ_{i≤t} log w_i.  exponents are bounded by Q·|logw|_max
    (≤ 64 with Q=16, clamp −4) so every factor is f32-representable, and
    every contraction is a plain MXU einsum — no (Q,Q,C) tensor, no
    per-step HBM round-trip of the (C,C) state.
    """
    B, H, Q, C = r.shape
    L = jnp.cumsum(logw, axis=2)                       # (B,H,Q,C), ≤ 0
    L_prev = L - logw                                  # L_{t-1} (L_0 = 0)
    # bf16 operands for the MXU contractions: bf16 shares f32's 8-bit
    # exponent, so the e^{±80} decay factors stay representable; products
    # accumulate in f32 (preferred_element_type).  Halves chunk traffic.
    bf = jnp.bfloat16
    r_dec = (r * jnp.exp(L_prev)).astype(bf)           # r_t e^{L_{t-1}}
    k_dec = (k * jnp.exp(-L)).astype(bf)               # k_s e^{-L_s}
    v_bf = v.astype(bf)
    # strict-lower-triangular attention-like scores
    scores = jnp.einsum("bhqc,bhsc->bhqs", r_dec, k_dec,
                        preferred_element_type=jnp.float32)
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    scores = jnp.where(mask[None, None], scores, 0.0).astype(bf)
    out = jnp.einsum("bhqs,bhsd->bhqd", scores, v_bf,
                     preferred_element_type=jnp.float32)
    out = out + jnp.einsum("bhqc,bhcd->bhqd", r_dec, S0.astype(bf),
                           preferred_element_type=jnp.float32)
    bonus = jnp.einsum("bhqc,hc,bhqc->bhq", r, u, k)
    out = out + bonus[..., None] * v
    eLQ = jnp.exp(L[:, :, -1, :])                      # (B,H,C)
    S_acc = jnp.einsum("bhqc,bhqd->bhcd", k_dec, v_bf,
                       preferred_element_type=jnp.float32)
    S_end = eLQ[..., None] * (S0 + S_acc)
    return out, S_end


def rwkv6_time_mix(params, cfg: ModelConfig, x: jnp.ndarray,
                   state: RWKV6State | None = None, chunk: int = RWKV_CHUNK):
    """Training/prefill time-mixing.  x: (B,S,D).

    §Perf iteration 2: chunked-PARALLEL wkv.  The baseline per-step scan
    moved the (B,H,C,C) state (plus outer-product temporaries) through HBM
    every token — 1572 s of memory term on train_4k.  The closed-form chunk
    (``_wkv_chunk``) touches the state once per Q=16 tokens and turns the
    inner work into MXU einsums.  Chunks are scanned with nested remat
    grouping to bound AD memory.
    """
    B, S, D = x.shape
    C = cfg.ssm_head_dim
    H = D // C
    if state is None:
        shift0 = jnp.zeros((B, D), x.dtype)
        wkv0 = jnp.zeros((B, H, C, C), jnp.float32)
    else:
        shift0, wkv0 = state.shift, state.wkv
    x_prev = jnp.concatenate([shift0[:, None, :], x[:, :-1, :]], axis=1)
    r, k, v, g, logw = _rwkv_proj(params, cfg, x, x_prev)
    u = params["u"]

    def heads_t(t):        # (B,S,D) -> (B,H,S,C)
        return t.reshape(B, S, H, C).transpose(0, 2, 1, 3).astype(jnp.float32)

    rh, kh, vh = heads_t(r), heads_t(k), heads_t(v)
    lw = heads_t(logw)

    Q = min(chunk, S)
    if S % Q == 0 and S > 1:
        nc = S // Q

        def to_chunks(t):  # (B,H,S,C) -> (nc,B,H,Q,C)
            return t.reshape(B, H, nc, Q, C).transpose(2, 0, 1, 3, 4)

        xs = tuple(to_chunks(t) for t in (rh, kh, vh, lw))

        def chunk_step(s, ci):
            rc, kc, vc, lc = ci
            out, s_new = _wkv_chunk(u, s, rc, kc, vc, lc)
            return s_new, out

        grp = RWKV_INNER_GROUP
        if grp and nc % grp == 0 and nc > grp:
            xs_g = tuple(t.reshape(nc // grp, grp, *t.shape[1:]) for t in xs)

            @jax.checkpoint
            def group_step(s, cg):
                return jax.lax.scan(chunk_step, s, cg)

            s_fin, outs = jax.lax.scan(group_step, wkv0, xs_g)
            outs = outs.reshape(nc, B, H, Q, C)
        else:
            s_fin, outs = jax.lax.scan(chunk_step, wkv0, xs)
        # (nc,B,H,Q,C) -> (B,S,H,C)
        out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, C)
    else:
        out, s_fin = _wkv_chunk(u, wkv0, rh, kh, vh, lw)
        out = out.transpose(0, 2, 1, 3).reshape(B, S, H, C)
    out = out.reshape(B, S, D)
    out = layers.rms_norm(out.astype(x.dtype), params["ln_w"])
    out = (out * g) @ params["wo"]
    new_state = (s_fin, x[:, -1, :])
    return out, new_state


def rwkv6_channel_mix(params, cfg: ModelConfig, x: jnp.ndarray,
                      shift0: jnp.ndarray | None = None):
    B, S, D = x.shape
    if shift0 is None:
        shift0 = jnp.zeros((B, D), x.dtype)
    x_prev = jnp.concatenate([shift0[:, None, :], x[:, :-1, :]], axis=1)
    xx = x_prev - x
    mu = params["mu_ffn"].astype(x.dtype)
    xk = x + xx * mu[0]
    xr = x + xx * mu[1]
    kk = jnp.square(jax.nn.relu(xk @ params["ck"]))
    out = jax.nn.sigmoid(xr @ params["cr"]) * (kk @ params["cv"])
    return out, x[:, -1, :]


def init_rwkv6_state(cfg: ModelConfig, batch: int, dtype) -> RWKV6State:
    d = cfg.d_model
    C = cfg.ssm_head_dim
    H = d // C
    return RWKV6State(
        wkv=jnp.zeros((batch, H, C, C), jnp.float32),
        shift=jnp.zeros((batch, d), dtype),
        ffn_shift=jnp.zeros((batch, d), dtype),
    )


def rwkv6_decode(params, cfg: ModelConfig, x: jnp.ndarray, state: RWKV6State):
    """One-token decode for a full RWKV6 block (time-mix + channel-mix).

    x: (B,1,D) post-norm input to time-mix; returns (tm_out, cm_fn, new_state)
    pieces handled by the caller model (which owns the residual adds/norms).
    """
    B, _, D = x.shape
    C = cfg.ssm_head_dim
    H = D // C
    x_prev = state.shift[:, None, :]
    r, k, v, g, logw = _rwkv_proj(params, cfg, x, x_prev)
    r = r.reshape(B, H, C).astype(jnp.float32)
    k = k.reshape(B, H, C).astype(jnp.float32)
    v = v.reshape(B, H, C).astype(jnp.float32)
    w = jnp.exp(logw.reshape(B, H, C))
    u = params["u"]
    kv = jnp.einsum("bhc,bhd->bhcd", k, v)
    out = jnp.einsum("bhc,bhcd->bhd", r, state.wkv + u[None, :, :, None] * kv)
    wkv_new = state.wkv * w[..., None] + kv
    out = out.reshape(B, 1, D)
    out = layers.rms_norm(out.astype(x.dtype), params["ln_w"])
    out = (out * g) @ params["wo"]
    new_state = RWKV6State(wkv=wkv_new, shift=x[:, -1, :],
                           ffn_shift=state.ffn_shift)
    return out, new_state


def rwkv6_channel_mix_decode(params, cfg: ModelConfig, x: jnp.ndarray,
                             state: RWKV6State):
    out, new_shift = rwkv6_channel_mix(params, cfg, x, state.ffn_shift)
    return out, RWKV6State(wkv=state.wkv, shift=state.shift, ffn_shift=new_shift)
