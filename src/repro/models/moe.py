"""Mixture-of-Experts FFN: group-local sort-based dispatch (GShard-style EP).

Design for scale (DESIGN.md §6):
  * tokens are routed *within their group* (group = one batch row), so all
    dispatch gathers have a batch dimension and never cross the data axis;
  * the (E, C) expert buffers are the only tensors resharded data->model
    (the all-to-all of expert parallelism, inserted by SPMD);
  * expert weights are stacked (E, ...) and sharded over 'model' (EP).

Capacity:  C = ceil(T·k·cf/E) per group — tokens over capacity are dropped
(their combine weight is 0), standard GShard semantics.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding import partition as pt


def moe_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, ((c + 7) // 8) * 8)


def init_moe(key, cfg: ModelConfig, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "router": layers.dense_init(k1, d, E, jnp.float32),
        "wi_gate": layers.dense_init(k2, d, ff, dtype).astype(dtype) * 1.0,
        "wi_up": layers.dense_init(k3, d, ff, dtype),
        "wo": layers.dense_init(k4, ff, d, dtype),
    }
    # expert-stacked weights (E, ...)
    kg = jax.random.split(key, 3 * E).reshape(3, E, 2)
    p["wi_gate"] = jax.vmap(lambda kk: layers.dense_init(kk, d, ff, dtype))(kg[0])
    p["wi_up"] = jax.vmap(lambda kk: layers.dense_init(kk, d, ff, dtype))(kg[1])
    p["wo"] = jax.vmap(lambda kk: layers.dense_init(kk, ff, d, dtype))(kg[2])
    if cfg.dense_residual_ff:
        kd = jax.random.fold_in(key, 7)
        p["dense_residual"] = layers.init_ffn(
            kd, d, cfg.dense_residual_ff, dtype)
    return p


def _route(params, cfg: ModelConfig, x: jnp.ndarray):
    """x: (G,T,D) -> top-k (ids (G,T,k) int32, gates (G,T,k) f32, aux loss)."""
    logits = (x.astype(jnp.float32) @ params["router"])          # (G,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)                 # (G,T,k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch/GShard): E * Σ_e f_e p_e
    E = cfg.n_experts
    sel = jax.nn.one_hot(ids[..., 0], E)                          # top-1 assignment
    f = jnp.mean(sel, axis=(0, 1))
    p = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f * p)
    return ids, gates.astype(jnp.float32), aux


def moe_apply(params, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (G, T, D) -> (out (G,T,D), aux_loss scalar)."""
    G, T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = moe_capacity(cfg, T)
    ids, gates, aux = _route(params, cfg, x)                      # (G,T,K)

    NK = T * K
    flat_ids = ids.reshape(G, NK)                                 # expert of rep
    order = jnp.argsort(flat_ids, axis=-1, stable=True)           # (G,NK)
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=-1)
    # expert segment starts via vectorized searchsorted per group
    starts = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E + 1), side="left")
    )(sorted_ids)                                                 # (G,E+1)

    # gather tokens into (G, E, C, D) buffers
    slot_src = starts[:, :E, None] + jnp.arange(C)[None, None, :]  # (G,E,C)
    valid = slot_src < starts[:, 1:, None]                         # within segment
    slot_src = jnp.minimum(slot_src, NK - 1)
    rep_idx = jnp.take_along_axis(order, slot_src.reshape(G, -1), axis=-1)
    tok_idx = (rep_idx // K).reshape(G, E, C)
    buf = jnp.take_along_axis(
        x, tok_idx.reshape(G, E * C)[..., None], axis=1
    ).reshape(G, E, C, D)
    buf = jnp.where(valid[..., None], buf, 0.0)
    if G > 1:                             # train/prefill: groups carry 'data'
        buf = pt.shard_moe_buf(buf)       # EP all-to-all: data -> expert shards

    # expert SwiGLU:  (G,E,C,D) x (E,D,F)
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["wi_gate"]))
    up = jnp.einsum("gecd,edf->gecf", buf, params["wi_up"])
    eout = jnp.einsum("gecf,efd->gecd", gate * up, params["wo"])   # (G,E,C,D)
    # combine-path all-to-all: expert shards -> group-local BEFORE the
    # un-dispatch gather (which indexes across E·C and must be local)
    if G > 1:
        eout = pt.gather_experts(eout)

    # un-dispatch: rank of each rep within its expert
    inv = jnp.argsort(order, axis=-1)                              # pos in sorted
    c_of_rep = inv - jnp.take_along_axis(starts[:, :E], flat_ids, axis=-1)
    rep_valid = c_of_rep < C
    flat_slot = flat_ids * C + jnp.clip(c_of_rep, 0, C - 1)        # (G,NK)
    out_rep = jnp.take_along_axis(
        eout.reshape(G, E * C, D), flat_slot[..., None], axis=1
    )                                                              # (G,NK,D)
    out_rep = jnp.where(rep_valid[..., None], out_rep, 0.0)
    out_rep = out_rep.reshape(G, T, K, D) * gates[..., None].astype(out_rep.dtype)
    out = jnp.sum(out_rep, axis=2).astype(x.dtype)

    if "dense_residual" in params:                                 # arctic branch
        out = out + layers.ffn_apply(params["dense_residual"], x)
    return out, aux * cfg.router_aux_coef


def moe_decode(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Decode-path MoE for (B, 1, D): route the B tokens as ONE group through
    the same sort-based dispatch as training.  Under EP this keeps expert
    weights resident on their shards (tokens move via all-to-all) instead of
    gathering K·(D·F) weight matrices per token — decode is memory-bound, so
    moving tokens (B·D bytes) beats moving experts (K·3·D·F bytes) by ~10³×.
    """
    B, S1, D = x.shape
    out, _aux = moe_apply(params, cfg, x.reshape(1, B, D))
    return out.reshape(B, S1, D)
