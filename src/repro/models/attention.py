"""GQA attention (self/causal, cross, and cached decode paths).

Sharding notes (see repro/sharding/partition.py):
  * q/k/v/o projections are Megatron-split over heads ('model' axis);
  * decode KV caches are laid out (B, kv_heads, S, head_dim) so either the
    kv_heads axis (TP) or the S axis (sequence parallelism for long_500k)
    can carry the 'model' axis.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding import partition as pt


class KVCache(NamedTuple):
    k: jnp.ndarray   # (B, kv_heads, S_max, head_dim)
    v: jnp.ndarray   # (B, kv_heads, S_max, head_dim)


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False):
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(kq, cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": layers.dense_init(kk, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": layers.dense_init(kv, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": layers.dense_init(ko, cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.ones_init(hd)
        p["k_norm"] = layers.ones_init(hd)
    return p


def _project_qkv(params, cfg: ModelConfig, x, kv_src, positions, kv_positions,
                 use_rope: bool):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    x = pt.gather_seq(x)                  # SP→TP gather on the bf16 tensor
    if kv_src is not x:
        kv_src = pt.gather_seq(kv_src)
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, hd)
    Skv = kv_src.shape[1]
    k = (kv_src @ params["wk"]).reshape(B, Skv, cfg.n_kv_heads, hd)
    v = (kv_src @ params["wv"]).reshape(B, Skv, cfg.n_kv_heads, hd)
    # SP→TP transition: heads sharded, seq gathered (see pt.shard_heads)
    q = pt.shard_heads(q)
    k = pt.shard_heads(k)
    v = pt.shard_heads(v)
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"])
        k = layers.rms_norm(k, params["k_norm"])
    if use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


Q_CHUNK = 1024   # q-block size for the chunked-softmax path


def _sdpa_dense(q, k, v, causal: bool, q_offset=0):
    """One q-block of grouped SDPA. q: (B,S,Hkv,G,hd); k/v: (B,Skv,Hkv,hd).

    Memory-lean score path (§Perf iteration 1):
      * the 1/√hd scale is folded into q (saves one full-scores pass);
      * softmax max/exp run in f32, but the *unnormalized* probabilities are
        cast to bf16 for the PV matmul and the denominator is applied to the
        (much smaller) output — the flash-attention trick, in XLA terms;
      * q_offset is static, so the causal mask is a compile-time iota fusion.
    """
    B, S, Hkv, G, hd = q.shape
    qs = (q.astype(jnp.float32) * (1.0 / np.sqrt(hd))).astype(q.dtype)
    scores = jnp.einsum("bshgd,bthd->bhgst", qs, k,
                        preferred_element_type=jnp.float32)
    if causal:
        qp = q_offset + jnp.arange(S)
        kp = jnp.arange(k.shape[1])
        mask = qp[:, None] >= kp[None, :]                        # (S, Skv)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    m = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    p_un = jnp.exp(scores - m)                                   # f32
    denom = jnp.sum(p_un, axis=-1)                               # (B,Hkv,G,S)
    # PV contraction and the output stream stay bf16: a f32 output here
    # makes every downstream (B,S,D) dot/collective f32 (fwd AND cotangents)
    # — measured +2× on the activation all-gather/reduce bytes (§Perf 1c).
    out = jnp.einsum("bhgst,bthd->bshgd", p_un.astype(v.dtype), v)
    inv = (1.0 / jnp.maximum(denom, 1e-30)).transpose(0, 3, 1, 2)[..., None]
    out = out * inv.astype(v.dtype)
    return out.astype(v.dtype).reshape(B, S, Hkv * G, hd)


def _sdpa(q, k, v, causal: bool, q_positions=None, kv_positions=None):
    """Grouped scaled-dot-product attention with q-block chunking.

    q: (B, S, H, hd); k/v: (B, Skv, Hkv, hd).  H = G * Hkv.

    For S > Q_CHUNK the q axis is processed in a *python-unrolled* loop of
    static blocks so that (a) the (S, Skv) score matrix never materializes,
    and (b) each causal q-block attends only to its static kv prefix
    ``kv[: off+Q]`` — dropping the ~2× masked-out score work that a scan
    with full-width kv would do.  (Unrolling is bounded: S/Q_CHUNK ≤ 32
    blocks even at 32k, inside a scan-over-layers body.)
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, hd)
    if S <= Q_CHUNK or S % Q_CHUNK != 0:
        return _sdpa_dense(qg, k, v, causal)
    nc = S // Q_CHUNK
    outs = []
    for c in range(nc):
        off = c * Q_CHUNK
        q_blk = qg[:, off:off + Q_CHUNK]
        if causal:
            k_blk = k[:, :off + Q_CHUNK]
            v_blk = v[:, :off + Q_CHUNK]
        else:
            k_blk, v_blk = k, v
        outs.append(_sdpa_dense(q_blk, k_blk, v_blk, causal, q_offset=off))
    return jnp.concatenate(outs, axis=1).reshape(B, S, H, hd)


def attention_apply(params, cfg: ModelConfig, x, *, positions=None,
                    causal: bool = True, kv_src=None, kv_positions=None,
                    use_rope: bool = True):
    """Training/prefill attention. x: (B, S, D) -> (B, S, D).

    ``kv_src`` != None => cross-attention (no causal mask, no rope on kv).
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    cross = kv_src is not None
    src = kv_src if cross else x
    if kv_positions is None:
        kv_positions = jnp.arange(src.shape[1])[None, :].astype(jnp.int32)
    q, k, v = _project_qkv(params, cfg, x, src, positions, kv_positions,
                           use_rope=use_rope and not cross)
    out = _sdpa(q, k, v, causal=causal and not cross)
    hd = cfg.resolved_head_dim
    return out.reshape(B, S, cfg.n_heads * hd) @ params["wo"]


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (batch, cfg.n_kv_heads, max_seq, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def decode_attention(params, cfg: ModelConfig, x, cache: KVCache, pos,
                     *, use_rope: bool = True):
    """One-token decode. x: (B, 1, D); pos: scalar int32 (current position).

    Returns (out (B,1,D), new_cache).  Attention runs over cache[:pos+1] via
    masking (static shapes — required under jit).
    """
    B, S1, _ = x.shape
    assert S1 == 1
    hd = cfg.resolved_head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = (x @ params["wq"]).reshape(B, 1, cfg.n_heads, hd)
    k_new = (x @ params["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
    v_new = (x @ params["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"])
        k_new = layers.rms_norm(k_new, params["k_norm"])
    if use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k_new = layers.apply_rope(k_new, positions, cfg.rope_theta)
    # insert at pos:  cache layout (B, Hkv, S, hd)
    k_cache = jax.lax.dynamic_update_slice(
        cache.k, k_new.transpose(0, 2, 1, 3).astype(cache.k.dtype),
        (0, 0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache.v, v_new.transpose(0, 2, 1, 3).astype(cache.v.dtype),
        (0, 0, pos, 0))
    Smax = k_cache.shape[2]
    Hkv = cfg.n_kv_heads
    G = cfg.n_heads // Hkv
    qh = q.reshape(B, 1, Hkv, G, hd)
    scores = jnp.einsum("bshgd,bhtd->bhgst", qh, k_cache).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    valid = (jnp.arange(Smax) <= pos)[None, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgst,bhtd->bshgd", probs, v_cache)
    out = out.reshape(B, 1, cfg.n_heads * hd) @ params["wo"]
    return out, KVCache(k=k_cache, v=v_cache)
