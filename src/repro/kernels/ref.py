"""Pure-jnp reference oracles for the FlashSketch / FlashBlockRow kernels.

These are the ground-truth semantics: the Pallas kernels in
``flashsketch.py`` / ``blockrow.py`` must match them bit-for-bit in the hash
stream and to float tolerance in the output (asserted in tests).

Shapes follow the paper: ``A ∈ R^{d×n}``, ``S ∈ R^{k×d}``, ``Y = S A ∈ R^{k×n}``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, wiring
from repro.core.blockperm import BlockPermPlan, global_rows_signs


def pad_input(plan: BlockPermPlan, A: jnp.ndarray) -> jnp.ndarray:
    """Zero-pad A from (d, n) to (d_pad, n)."""
    d, _ = A.shape
    if d == plan.d_pad:
        return A
    return jnp.pad(A, ((0, plan.d_pad - d), (0, 0)))


def _phi_all_blocks(plan: BlockPermPlan, h_of_g: jnp.ndarray) -> jnp.ndarray:
    """Φ for all output blocks at once: (M, Br, Bc), entries ±1/0 (unscaled).

    ``h_of_g``: (M,) int32, the input block feeding each output block for one
    permutation level ℓ.
    """
    g = jnp.arange(plan.M, dtype=jnp.int32)[:, None]      # (M, 1)
    u = jnp.arange(plan.Bc, dtype=jnp.int32)[None, :]     # (1, Bc)
    r_iota = jnp.arange(plan.Br, dtype=jnp.int32)         # (Br,)
    phi = jnp.zeros((plan.M, plan.Br, plan.Bc), jnp.float32)
    chunk = plan.chunk
    for i in range(plan.s):
        hsh = hashing.hash_words(
            np.uint32(plan.seed),
            g.astype(jnp.uint32),
            h_of_g[:, None].astype(jnp.uint32),
            u.astype(jnp.uint32),
            np.uint32(i),
        )                                                  # (M, Bc)
        rows = i * chunk + hashing.hash_mod(hsh, chunk)    # (M, Bc)
        signs = hashing.hash_to_unit_sign(hsh)             # (M, Bc)
        onehot = (r_iota[None, :, None] == rows[:, None, :]).astype(jnp.float32)
        phi = phi + onehot * signs[:, None, :]
    return phi


def _global_fwd_ref(plan: BlockPermPlan, A: jnp.ndarray) -> jnp.ndarray:
    """Y = S A for a GLOBAL family (countsketch/graph): scatter-add of each
    padded input row to its s hashed global output rows."""
    Ap = pad_input(plan, A).astype(jnp.float32)
    u = jnp.arange(plan.d_pad, dtype=jnp.int32)
    Y = jnp.zeros((plan.k_pad, Ap.shape[1]), jnp.float32)
    for i in range(plan.s):
        rows, signs = global_rows_signs(plan, u, i)
        Y = Y.at[rows].add(signs[:, None] * Ap)
    return Y[: plan.k] * plan.scale


def _global_transpose_ref(plan: BlockPermPlan, Y: jnp.ndarray) -> jnp.ndarray:
    """X = Sᵀ Y for a GLOBAL family: each padded input row gathers its s
    hashed output rows back."""
    Yp = Y
    if Y.shape[0] != plan.k_pad:
        Yp = jnp.pad(Y, ((0, plan.k_pad - Y.shape[0]), (0, 0)))
    Yp = Yp.astype(jnp.float32)
    u = jnp.arange(plan.d_pad, dtype=jnp.int32)
    X = jnp.zeros((plan.d_pad, Yp.shape[1]), jnp.float32)
    for i in range(plan.s):
        rows, signs = global_rows_signs(plan, u, i)
        X = X + signs[:, None] * Yp[rows]
    return X[: plan.d] * plan.scale


def flashsketch_ref(plan: BlockPermPlan, A: jnp.ndarray) -> jnp.ndarray:
    """Y = S A for S ~ plan (BLOCKPERM-SJLT or a global family).
    A: (d, n) -> Y: (k, n)."""
    if plan.is_global:
        return _global_fwd_ref(plan, A)
    n = A.shape[1]
    Ap = pad_input(plan, A).astype(jnp.float32)
    A_blocks = Ap.reshape(plan.M, plan.Bc, n)
    pi = wiring.wiring_jnp(plan.seed, plan.M, plan.kappa)   # (κ, M)
    Y_blocks = jnp.zeros((plan.M, plan.Br, n), jnp.float32)
    for ell in range(plan.kappa):
        h_of_g = pi[ell]                                    # (M,)
        gathered = A_blocks[h_of_g]                         # (M, Bc, n)
        phi = _phi_all_blocks(plan, h_of_g)                 # (M, Br, Bc)
        Y_blocks = Y_blocks + jnp.einsum(
            "gbc,gcn->gbn", phi, gathered, precision=jax.lax.Precision.HIGHEST
        )
    Y = Y_blocks.reshape(plan.k_pad, n) * plan.scale
    return Y[: plan.k]


def flashsketch_transpose_ref(plan: BlockPermPlan, Y: jnp.ndarray) -> jnp.ndarray:
    """X = Sᵀ Y.  Y: (k, n) -> X: (d, n).  (VJP of flashsketch_ref wrt A.)"""
    if plan.is_global:
        return _global_transpose_ref(plan, Y)
    n = Y.shape[1]
    Yp = Y
    if Y.shape[0] != plan.k_pad:
        Yp = jnp.pad(Y, ((0, plan.k_pad - Y.shape[0]), (0, 0)))
    Y_blocks = Yp.reshape(plan.M, plan.Br, n).astype(jnp.float32)
    pi = wiring.wiring_jnp(plan.seed, plan.M, plan.kappa)
    X_blocks = jnp.zeros((plan.M, plan.Bc, n), jnp.float32)
    for ell in range(plan.kappa):
        h_of_g = pi[ell]
        phi = _phi_all_blocks(plan, h_of_g)                 # (M, Br, Bc)
        contrib = jnp.einsum(
            "gbc,gbn->gcn", phi, Y_blocks, precision=jax.lax.Precision.HIGHEST
        )                                                   # (M, Bc, n)
        X_blocks = X_blocks.at[h_of_g].add(contrib)
    X = X_blocks.reshape(plan.d_pad, n) * plan.scale
    return X[: plan.d]


# ---------------------------------------------------------------------------
# FLASHBLOCKROW (paper App. C): fast-but-fragile gather variant.
# Wiring is iid block sampling per output block (collisions possible); the
# intra-block pattern has s nonzeros per *row* (not per column) => no
# column-regularity, no OSE guarantee. Extra √(d/k) scaling (Alg. 2).
# ---------------------------------------------------------------------------

def blockrow_wiring(plan: BlockPermPlan) -> jnp.ndarray:
    """(κ, M) iid input-block choices for FLASHBLOCKROW."""
    g = jnp.arange(plan.M, dtype=jnp.uint32)[None, :]
    ell = jnp.arange(plan.kappa, dtype=jnp.uint32)[:, None]
    hsh = hashing.hash_words(
        np.uint32(plan.seed), np.uint32(0xB10C), ell, g
    )
    return hashing.hash_mod(hsh, plan.M)                    # (κ, M) int32


def _phi_rows_all_blocks(plan: BlockPermPlan, h_of_g: jnp.ndarray) -> jnp.ndarray:
    """Per-row sampling pattern: (M, Br, Bc) with s ±1 entries per row."""
    g = jnp.arange(plan.M, dtype=jnp.int32)[:, None]        # (M, 1)
    r = jnp.arange(plan.Br, dtype=jnp.int32)[None, :]       # (1, Br)
    c_iota = jnp.arange(plan.Bc, dtype=jnp.int32)           # (Bc,)
    phi = jnp.zeros((plan.M, plan.Br, plan.Bc), jnp.float32)
    for t in range(plan.s):
        hsh = hashing.hash_words(
            np.uint32(plan.seed),
            np.uint32(0x5EED),
            g.astype(jnp.uint32),
            h_of_g[:, None].astype(jnp.uint32),
            r.astype(jnp.uint32),
            np.uint32(t),
        )                                                   # (M, Br)
        cols = hashing.hash_mod(hsh, plan.Bc)               # (M, Br)
        signs = hashing.hash_to_unit_sign(hsh)              # (M, Br)
        onehot = (c_iota[None, None, :] == cols[:, :, None]).astype(jnp.float32)
        phi = phi + onehot * signs[:, :, None]
    return phi


def blockrow_ref(plan: BlockPermPlan, A: jnp.ndarray) -> jnp.ndarray:
    """FLASHBLOCKROW forward: Y = S_row A with the Alg. 2 scaling."""
    n = A.shape[1]
    Ap = pad_input(plan, A).astype(jnp.float32)
    A_blocks = Ap.reshape(plan.M, plan.Bc, n)
    hh = blockrow_wiring(plan)                              # (κ, M)
    Y_blocks = jnp.zeros((plan.M, plan.Br, n), jnp.float32)
    for ell in range(plan.kappa):
        h_of_g = hh[ell]
        gathered = A_blocks[h_of_g]
        phi = _phi_rows_all_blocks(plan, h_of_g)
        Y_blocks = Y_blocks + jnp.einsum(
            "gbc,gcn->gbn", phi, gathered, precision=jax.lax.Precision.HIGHEST
        )
    scale = plan.scale * math.sqrt(plan.d_pad / plan.k_pad)
    Y = Y_blocks.reshape(plan.k_pad, n) * scale
    return Y[: plan.k]
