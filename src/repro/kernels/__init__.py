"""Pallas TPU kernels for the paper's compute hot-spot (the sketch apply).

  flashsketch.py — FLASHSKETCH v2 fused-κ single-write kernels (fwd/
                   transpose/blockrow) with VMEM Φ caching and a
                   mixed-precision streaming path; v1 grid-reduction
                   kernels kept as the equivalence/benchmark baseline
  lowering.py    — THE launch-decision layer: lower(plan, spec) resolves
                   impl/tile/dtype/gather/batch/shard into one frozen
                   Lowering record; execute() runs it; explain() prints
                   the decision trace (re-exported as repro.engine)
  ops.py         — jit'd public wrappers: thin custom_vjp shells around
                   lowering.lower + lowering.execute
  tune.py        — tile autotuner (tn and M/Br sweeps, shape-keyed cache;
                   one cache_key builder for all readers and writers)
  ref.py         — pure-jnp oracles (ground truth for tests)
"""
