"""Pallas TPU kernels for the paper's compute hot-spot (the sketch apply).

  flashsketch.py — FLASHSKETCH fwd/transpose + FLASHBLOCKROW pallas_call
  ops.py         — jit'd public wrappers with padding + custom_vjp
  ref.py         — pure-jnp oracles (ground truth for tests)
"""
