"""Pallas TPU kernels for the paper's compute hot-spot (the sketch apply).

  flashsketch.py — FLASHSKETCH v2 fused-κ single-write kernels (fwd/
                   transpose/blockrow) with VMEM Φ caching and a
                   mixed-precision streaming path; v1 grid-reduction
                   kernels kept as the equivalence/benchmark baseline
  ops.py         — jit'd public wrappers with padding, impl dispatch,
                   dtype knob + custom_vjp
  tune.py        — tile autotuner (tn and M/Br sweeps, shape-keyed cache)
  ref.py         — pure-jnp oracles (ground truth for tests)
"""
