"""The sketch lowering engine: every launch decision in ONE record.

The paper's sketch–kernel co-design means *how* a sketch launches — which
kernel generation, which tile, which precision, whether the row gather is
fused, whether the batch is folded, how a mesh shards it — IS the product.
This module is the single place those decisions are made:

  * ``lower(plan, spec) -> Lowering`` — resolve a ``LaunchSpec`` (the
    caller's request: op, n, impl/tn/dtype knobs, gather/batch/shard) into
    a frozen ``Lowering`` record holding every decision: the resolved
    impl (plus the reason for any downgrade), the tile width and where it
    came from (explicit / tuned / heuristic / v1 default), the effective
    streaming dtype, whether the gather stays fused, the per-device
    workload under sharding, the VMEM footprint, and the padding plan.
  * ``execute(lowering, operand, row_index=None)`` — run a single-device
    lowering.  ``kernels.ops`` entry points are thin ``custom_vjp`` shells
    around ``lower`` + ``execute``; ``repro.distributed`` lowers its
    per-device partial through the same ``lower`` and executes it inside
    ``shard_map``.
  * ``explain(plan, ...)`` — the human-readable decision trace (chosen
    tile, rejected candidates, downgrade reasons); also behind
    ``tools/explain_lowering.py``.
  * ``roofline.sketch_model.cost_of(lowering)`` — the modeled cost of the
    record *that launches*, so model/kernel drift is structural, not
    review-caught.

``lower`` is memoized process-wide, keyed like the tuner cache — the plan
(which carries the shape class: d_pad/k_pad/M/Br/Bc/κ/s/dtype), the full
spec, the backend tag, and ``tune.cache_generation()`` so freshly tuned
winners invalidate stale records.

The downgrade ladder (each step recorded in ``Lowering.downgrade``):

  1. ``pallas`` + gather, fused scratch over budget → materialize the
     gather, continue as the non-gather op (PR-3 semantics).
  2. ``pallas`` (v2), stacked Φ scratch over budget at the minimum tile →
     ``pallas_v1`` (the revisiting kernel's working set is per-pair).
  3. row-sharded partial, (B_r, B_c) Φ tile over budget at the minimum
     tile → the jnp oracle partial (there is no v1 partial formulation).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import precision as precision_mod
from repro.core.blockperm import (MIN_TILE_N, VMEM_BUDGET_BYTES,
                                  BlockPermPlan, fused_variant_bytes)
from repro.health import report as health_report
from repro.kernels import flashsketch as fsk
from repro.kernels import ref as kref
from repro.kernels import tune

OPS = ("fwd", "transpose", "blockrow")
SHARDS = ("none", "row", "col", "batch")
IMPLS = ("auto", "pallas", "pallas_v1", "xla")
GATHER_OPS = ("fwd", "blockrow")

_PALLAS_IMPLS = ("pallas", "pallas_v1")


@dataclasses.dataclass(frozen=True)
class LaunchSpec:
    """A caller's launch request, before any resolution.

    Attributes:
      op: ``"fwd"`` (``Y = S A``), ``"transpose"`` (``X = Sᵀ Y``) or
        ``"blockrow"`` (FLASHBLOCKROW forward).
      n: per-matrix logical column count of the operand.
      impl: requested dispatch — ``"auto" | "pallas" | "pallas_v1" |
        "xla"``.  ``auto`` resolves per backend; the rest may still be
        downgraded (recorded in ``Lowering.downgrade``).
      tn: requested column-tile width, or ``None`` to defer to the tuner
        cache / VMEM heuristic.
      dtype: streaming-precision POLICY override — any name registered
        in ``repro.core.precision`` (``"float32"``, ``"bfloat16"``, the
        fp8 policies, or an alias); ``None`` keeps the plan's knob.
      gather: fuse a per-row gather into the kernel load (``fwd`` /
        ``blockrow`` only — the ``row_index=`` paths).
      batch: batched-apply fold factor (a B-stack folded into the column
        axis: the launch sees ``n·batch`` effective columns, the tuner its
        batched shape class).
      shard: ``"none"`` (single device), ``"row"`` (psum'd per-ℓ partial
        kernel), ``"col"`` / ``"batch"`` (collective-free slabs).
      devices: shard degree P (ignored for ``shard="none"``).
    """

    op: str = "fwd"
    n: int = 1
    impl: str = "auto"
    tn: Optional[int] = None
    dtype: Optional[str] = None
    gather: bool = False
    batch: int = 1
    shard: str = "none"
    devices: int = 1


@dataclasses.dataclass(frozen=True)
class Lowering:
    """Every decision of one sketch launch, frozen.

    Field groups:

      * identity — ``plan`` (effective: the ``dtype`` override already
        applied), ``op``, ``dtype``.
      * dispatch — ``impl_requested`` → ``impl``, with ``downgrade``
        holding the human-readable reason for any forced change (``None``
        when the request ran as asked).
      * tiling — ``tn`` (``None`` for the xla oracle) and ``tn_source``
        (``"explicit" | "tuned" | "loaded" | "heuristic" | "v1_default"``),
        ``grid_cols`` = number of column tiles of the launch.
      * fusion — ``gather`` (requested) vs ``gather_fused`` (what runs:
        ``False`` means the gather is materialized first), ``batch``.
      * sharding — ``shard``, ``devices``, and the per-device workload
        ``n_loc``/``batch_loc``/``n_eff = n_loc·batch_loc`` that the
        kernel (and the cost model) actually sees.
      * footprint — ``vmem_bytes`` of the launched kernel's working set
        (``None`` for xla); ``pad_rows`` = zero rows added to the operand
        before launch, ``pad_cols`` = columns padded in HBM — ALWAYS 0:
        ragged column tails are handled in-kernel (masked edge tiles /
        clipped gather DMA), never by copying the operand.
    """

    plan: BlockPermPlan
    op: str
    impl: str
    impl_requested: str
    downgrade: Optional[str]
    tn: Optional[int]
    tn_source: str
    dtype: str
    gather: bool
    gather_fused: bool
    batch: int
    shard: str
    devices: int
    n: int
    n_loc: int
    batch_loc: int
    n_eff: int
    grid_cols: Optional[int]
    vmem_bytes: Optional[int]
    pad_rows: int
    pad_cols: int

    @property
    def variant(self) -> str:
        """Tuner/VMEM shape-class name of the kernel that runs."""
        return self.op + ("_gather" if self.gather_fused else "")

    @property
    def version(self) -> str:
        """Cost-model kernel generation of the launch (xla models v2)."""
        return "v1" if self.impl == "pallas_v1" else "v2"

    def describe(self) -> str:
        bits = [f"{self.op}", f"impl={self.impl}"]
        if self.impl != self.impl_requested:
            bits[-1] += f"(req {self.impl_requested})"
        bits.append(f"tn={self.tn}:{self.tn_source}")
        bits.append(f"dtype={self.dtype}")
        if self.gather:
            bits.append("gather=" + ("fused" if self.gather_fused
                                     else "materialized"))
        if self.batch > 1:
            bits.append(f"batch={self.batch}")
        if self.shard != "none":
            bits.append(f"shard={self.shard}x{self.devices}")
        bits.append(f"n={self.n}->eff{self.n_eff}")
        if self.vmem_bytes is not None:
            bits.append(f"vmem={self.vmem_bytes}B")
        if self.downgrade:
            bits.append(f"downgrade[{self.downgrade}]")
        return "Lowering(" + ", ".join(bits) + ")"

    def to_json(self) -> Dict:
        """Stable JSON form (the golden-snapshot serialization)."""
        p = self.plan
        return {
            "op": self.op,
            "impl": self.impl,
            "impl_requested": self.impl_requested,
            "downgrade": self.downgrade,
            "tn": self.tn,
            "tn_source": self.tn_source,
            "dtype": self.dtype,
            "gather": self.gather,
            "gather_fused": self.gather_fused,
            "batch": self.batch,
            "shard": self.shard,
            "devices": self.devices,
            "n": self.n,
            "n_loc": self.n_loc,
            "batch_loc": self.batch_loc,
            "n_eff": self.n_eff,
            "grid_cols": self.grid_cols,
            "vmem_bytes": self.vmem_bytes,
            "pad_rows": self.pad_rows,
            "pad_cols": self.pad_cols,
            "variant": self.variant,
            "version": self.version,
            "plan": {"d": p.d, "d_pad": p.d_pad, "k_pad": p.k_pad,
                     "M": p.M, "Br": p.Br, "Bc": p.Bc,
                     "kappa": p.kappa, "s": p.s, "dtype": p.dtype,
                     "family": p.family},
        }


# ---------------------------------------------------------------------------
# VMEM footprint models (single source; the sharded path re-exports its
# predicate from here so kernels and distributed share one budget model).
# ---------------------------------------------------------------------------

def v1_working_set_bytes(plan: BlockPermPlan, tn: int) -> int:
    """v1 revisiting kernel per-program working set: the materialized
    (Br, Bc) fp32 Φ tile plus a double-buffered block pair at width tn
    (the model ``tune.v1_default_tn`` shrinks against)."""
    return 4 * plan.Br * plan.Bc + 8 * (plan.Bc + plan.Br) * tn


def partial_vmem_bytes(plan: BlockPermPlan, tn: int) -> int:
    """Row-sharded partial kernel working set at tile width ``tn``: one
    (B_r, B_c) Φ scratch + one double-buffered pipelined input view + the
    output tile — exactly the κ=1 fused-fwd footprint (the per-ℓ grid
    carries ONE Φ tile and ONE input block per program, regardless of the
    plan's κ)."""
    return fused_variant_bytes(1, plan.Br, plan.Bc, tn,
                               plan.stream_itemsize, "fwd",
                               plan.precision.compute_itemsize)


def partial_fits_vmem(plan: BlockPermPlan, tn: int) -> bool:
    """Whether the partial kernel's working set fits the VMEM budget."""
    return partial_vmem_bytes(plan, tn) <= VMEM_BUDGET_BYTES


# ---------------------------------------------------------------------------
# lower(): spec -> Lowering
# ---------------------------------------------------------------------------

def _validate(plan: BlockPermPlan, spec: LaunchSpec) -> None:
    if spec.op not in OPS:
        raise ValueError(f"op must be one of {OPS}, got {spec.op!r}")
    if spec.impl not in IMPLS:
        raise ValueError(
            f"impl must be one of ('auto', 'pallas', 'pallas_v1', 'xla'), "
            f"got {spec.impl!r}")
    if spec.shard not in SHARDS:
        raise ValueError(f"shard must be one of {SHARDS}, got {spec.shard!r}")
    if spec.n < 1:
        raise ValueError(f"n must be >= 1, got {spec.n}")
    if spec.batch < 1:
        raise ValueError(f"batch must be >= 1, got {spec.batch}")
    if spec.tn is not None and spec.tn < 1:
        raise ValueError(f"tn must be >= 1, got {spec.tn}")
    if spec.gather and spec.op not in GATHER_OPS:
        raise ValueError(
            f"gather-fused loads exist for {GATHER_OPS} only, got "
            f"op={spec.op!r}")
    if plan.is_global and spec.op == "blockrow":
        raise ValueError(
            f"FLASHBLOCKROW is a blockperm-wiring construction; family "
            f"{plan.family!r} has no blockrow formulation")
    if spec.shard != "none":
        if spec.devices < 1:
            raise ValueError(f"devices must be >= 1, got {spec.devices}")
        if spec.shard == "row":
            if plan.is_global:
                raise ValueError(
                    f"row-sharding has no compact partial for global "
                    f"family {plan.family!r}: every input block feeds "
                    f"every output block, so a per-device block slab "
                    f"still touches the full output (shard the column "
                    f"or batch axis instead)")
            if spec.op == "transpose":
                raise ValueError(
                    "row-sharding has no partial transpose formulation")
            if spec.gather:
                raise ValueError(
                    "row-sharding does not compose with the fused gather "
                    "(shard the batch axis instead — see "
                    "distributed.sketch_apply_batched_sharded)")
            if spec.impl == "pallas_v1":
                raise ValueError(
                    "pallas_v1 has no partial formulation; row-sharded "
                    "impl must be 'auto', 'pallas' or 'xla'")
            if plan.M % spec.devices != 0:
                raise ValueError(
                    f"row-sharding needs the shard count to divide the "
                    f"block grid: P={spec.devices} does not divide "
                    f"M={plan.M} (rebuild the plan with block_rows= so "
                    f"that P | M)")
        elif spec.shard == "col" and spec.n % spec.devices != 0:
            raise ValueError(
                f"column sharding needs P | n: P={spec.devices}, "
                f"n={spec.n}")
        elif spec.shard == "batch" and spec.batch % spec.devices != 0:
            raise ValueError(
                f"batch sharding needs P | B: P={spec.devices}, "
                f"B={spec.batch}")


def _lower(plan: BlockPermPlan, spec: LaunchSpec,
           trace: Optional[List[str]]) -> Lowering:
    def t(line: str) -> None:
        if trace is not None:
            trace.append(line)

    _validate(plan, spec)
    eff = plan
    if spec.dtype is not None and spec.dtype != plan.dtype:
        eff = plan.with_dtype(spec.dtype)
        t(f"dtype: plan {plan.dtype!r} overridden -> {eff.dtype!r}")
    t(f"plan: {eff.describe()}")

    impl_req = spec.impl
    impl = impl_req
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
        t(f"impl: 'auto' -> {impl!r} (backend={jax.default_backend()!r})")
    else:
        t(f"impl: {impl!r} requested")

    # per-device workload under sharding
    n_loc, batch_loc = spec.n, spec.batch
    if spec.shard == "col":
        n_loc = spec.n // spec.devices
        t(f"shard=col x{spec.devices}: per-device columns n_loc={n_loc}")
    elif spec.shard == "batch":
        batch_loc = spec.batch // spec.devices
        t(f"shard=batch x{spec.devices}: per-device fold "
          f"batch_loc={batch_loc}")
    elif spec.shard == "row":
        t(f"shard=row x{spec.devices}: per-device block slab "
          f"M_loc={eff.M // spec.devices} of M={eff.M}")
    n_eff = n_loc * batch_loc

    downgrade: Optional[str] = None
    gather_fused = False
    tn: Optional[int] = spec.tn
    tn_source = "explicit" if spec.tn is not None else "n/a"
    vmem: Optional[int] = None
    pad_rows = 0

    if spec.shard == "row":
        # the psum'd-partials path: compact per-ℓ partial kernel (pallas)
        # or the jnp oracle partial (xla) — mirror of ops dispatch so
        # sharded and single-device runs use the same backend family.
        if impl == "pallas":
            tclass = "blockrow" if spec.op == "blockrow" else "fwd"
            if tn is None:
                hit = tune.lookup(eff, n_eff, tclass)
                if hit is not None:
                    tn, tn_source = hit.tn, hit.source
                    t(f"tn: {tn} ({tn_source} winner, class {tclass!r})")
                else:
                    tn = tune.heuristic_tn(eff, n_eff, tclass, trace=trace)
                    tn_source = "heuristic"
                    t(f"tn: {tn} (heuristic, class {tclass!r})")
            # the partial kernel's own (Br, Bc)-Φ working set may exceed
            # the budget even when the resolved class tile fits: shrink
            # the tile first, then fall back to the oracle — there is no
            # v1 partial formulation.
            tn_req = tn
            while tn > MIN_TILE_N and not partial_fits_vmem(eff, tn):
                t(f"tn={tn} rejected: partial working set "
                  f"{partial_vmem_bytes(eff, tn)} B > VMEM budget "
                  f"{VMEM_BUDGET_BYTES} B")
                tn //= 2
            if tn != tn_req:
                # the record must not claim the request ran as asked — an
                # explicit/tuned tile that was shrunk is a forced change
                tn_source = f"{tn_source}:vmem_shrunk"
                downgrade = (
                    f"vmem: partial working set over budget at "
                    f"tn={tn_req} — tile shrunk to {tn}")
                t(f"tn: {tn_req} -> {tn} (partial working set over "
                  f"budget; provenance {tn_source!r})")
            if not partial_fits_vmem(eff, tn):
                downgrade = (
                    "vmem: the (Br, Bc) Φ tile alone exceeds the VMEM "
                    "budget at the minimum tile width — no tile can save "
                    "the partial kernel; jnp oracle partial")
                t(f"impl: 'pallas' -> 'xla' ({downgrade})")
                impl, tn, tn_source = "xla", None, "n/a"
            else:
                vmem = partial_vmem_bytes(eff, tn)
        grid_cols = (None if tn is None else -(-n_eff // tn))
        return Lowering(
            plan=eff, op=spec.op, impl=impl, impl_requested=impl_req,
            downgrade=downgrade, tn=tn, tn_source=tn_source,
            dtype=eff.dtype, gather=False, gather_fused=False,
            batch=spec.batch, shard="row", devices=spec.devices,
            n=spec.n, n_loc=n_loc, batch_loc=batch_loc, n_eff=n_eff,
            grid_cols=grid_cols, vmem_bytes=vmem, pad_rows=0, pad_cols=0)

    if impl in _PALLAS_IMPLS:
        variant = spec.op + ("_gather" if spec.gather else "")
        if spec.gather:
            if impl == "pallas_v1":
                downgrade = (
                    "gather: pallas_v1 has no fused gather formulation — "
                    "the row gather is materialized, then the v1 kernel "
                    "runs on A[row_index]")
                t(f"gather: materialized ({downgrade})")
            elif not tune.fused_fits_vmem(eff, n_eff, variant):
                downgrade = (
                    f"vmem: the {variant!r} gather working set exceeds "
                    f"the budget at the minimum tile — gather "
                    f"materialized, then the regular dispatch runs on "
                    f"A[row_index]")
                t(f"gather: materialized ({downgrade})")
            else:
                gather_fused = True
                t("gather: fused in-kernel (row DMA from HBM)")
        if not gather_fused:
            variant = spec.op
            if impl == "pallas" and not tune.fused_fits_vmem(
                    eff, n_eff, variant):
                reason = (
                    f"vmem: stacked Φ (Br, κ·Bc) + pipelined blocks of "
                    f"{variant!r} exceed the budget at the minimum tile — "
                    f"v1 revisiting kernel")
                downgrade = (downgrade + "; " + reason) if downgrade \
                    else reason
                t(f"impl: 'pallas' -> 'pallas_v1' ({reason})")
                impl = "pallas_v1"

        if tn is None:
            if impl == "pallas_v1":
                tn = tune.v1_default_tn(eff, n_eff)
                tn_source = "v1_default"
                t(f"tn: {tn} (v1 default — block-pair working set)")
            else:
                hit = tune.lookup(eff, n_loc, variant, batch=batch_loc)
                if hit is not None:
                    tn, tn_source = hit.tn, hit.source
                    t(f"tn: {tn} ({tn_source} winner, class {variant!r}, "
                      f"batch={batch_loc})")
                else:
                    tn = tune.heuristic_tn(eff, n_loc, variant, batch_loc,
                                           trace=trace)
                    tn_source = "heuristic"
                    t(f"tn: {tn} (heuristic, class {variant!r}, "
                      f"batch={batch_loc})")
        else:
            t(f"tn: {tn} (explicit)")

        if impl == "pallas_v1":
            vmem = v1_working_set_bytes(eff, tn)
        else:
            vmem = fused_variant_bytes(eff.kappa, eff.Br, eff.Bc, tn,
                                       eff.stream_itemsize, variant,
                                       eff.precision.compute_itemsize)
        if not gather_fused:
            if spec.op == "transpose":
                pad_rows = 0                      # plan.k == plan.k_pad
            else:
                pad_rows = eff.d_pad - eff.d
        t(f"pad: rows +{pad_rows}, cols +0 (ragged column tail handled "
          f"in-kernel — the operand is never column-padded in HBM)")
        grid_cols = -(-n_eff // tn)
    else:
        assert impl == "xla", impl
        t("xla: pure-jnp oracle (no tiling, no VMEM)")
        tn, tn_source = None, "n/a"
        grid_cols = None

    if downgrade:
        # downgrades are health events: a request that could not run as
        # asked.  The counter makes forced rungs visible process-wide
        # (explain(), the fault-injection suite, long-running jobs).
        health_report.record("lowering.downgrade", detail=downgrade)
    return Lowering(
        plan=eff, op=spec.op, impl=impl, impl_requested=impl_req,
        downgrade=downgrade, tn=tn, tn_source=tn_source, dtype=eff.dtype,
        gather=spec.gather, gather_fused=gather_fused, batch=spec.batch,
        shard=spec.shard, devices=spec.devices if spec.shard != "none" else 1,
        n=spec.n, n_loc=n_loc, batch_loc=batch_loc, n_eff=n_eff,
        grid_cols=grid_cols, vmem_bytes=vmem, pad_rows=pad_rows, pad_cols=0)


_LOWERING_CACHE: Dict[Tuple, Lowering] = {}
# tuner-cache generation the memoized records were resolved against; a
# mismatch flushes the whole dict (the counter is monotone, so records
# from older generations can never be valid again — keeping them keyed
# by generation would only leak dead entries per tuner mutation).
_CACHE_GEN: int = -1
# Serializes the generation-check → flush → get/insert sequence: serving
# workers lower concurrently, and an unguarded flush racing an insert can
# resurrect a stale-tile record or die iterating a resizing dict.
_MEMO_LOCK = threading.RLock()


def lower(plan: BlockPermPlan, spec: LaunchSpec) -> Lowering:
    """Resolve a launch request into a frozen ``Lowering`` record.

    Pure trace-time python (no jax ops) — safe to call while tracing, like
    ``tune.resolve_tn``.  Memoized process-wide, keyed like the tuner
    cache (plan carries the shape class; plus the spec and backend tag);
    a freshly tuned/loaded winner bumps ``tune.cache_generation()``,
    which flushes the memo wholesale so stale tiles are never served.
    """
    global _CACHE_GEN
    with _MEMO_LOCK:
        gen = tune.cache_generation()
        if gen != _CACHE_GEN:
            _LOWERING_CACHE.clear()
            _CACHE_GEN = gen
        hit = _LOWERING_CACHE.get((plan, spec, tune._backend_tag()))
    if hit is not None:
        return hit
    hit = _lower(plan, spec, None)      # pure; safe outside the lock
    with _MEMO_LOCK:
        # only memoize against the generation we resolved under — if the
        # tuner mutated mid-resolve, serve the result but do not cache it
        if tune.cache_generation() == gen and _CACHE_GEN == gen:
            _LOWERING_CACHE[(plan, spec, tune._backend_tag())] = hit
    return hit


def clear_lowering_cache() -> None:
    with _MEMO_LOCK:
        _LOWERING_CACHE.clear()


def lowering_cache_size() -> int:
    with _MEMO_LOCK:
        return len(_LOWERING_CACHE)


def explain(plan: BlockPermPlan, spec: Optional[LaunchSpec] = None,
            **spec_kwargs) -> str:
    """Human-readable decision trace of one lowering.

    Pass a ``LaunchSpec`` or its keyword fields::

        print(lowering.explain(plan, n=512, dtype="bfloat16"))

    The trace lists the dtype/impl resolution, every rejected tile
    candidate (with its VMEM footprint), any downgrade and its reason, the
    padding plan, and the final record — plus the process-wide guard/health
    counters (``repro.health.report``), so one explain shows both how the
    launch resolves and what the guards have seen this process.
    """
    if spec is None:
        spec = LaunchSpec(**spec_kwargs)
    elif spec_kwargs:
        spec = dataclasses.replace(spec, **spec_kwargs)
    trace: List[str] = []
    lw = _lower(plan, spec, trace)
    head = (f"lower(op={spec.op!r}, n={spec.n}, impl={spec.impl!r}, "
            f"tn={spec.tn}, dtype={spec.dtype!r}, gather={spec.gather}, "
            f"batch={spec.batch}, shard={spec.shard!r}x{spec.devices})")
    lines = [head] + ["  " + ln for ln in trace] + ["=> " + lw.describe()]
    lines.append("health: " + health_report.summarize_counters())
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# execute(): run a single-device lowering.
# ---------------------------------------------------------------------------

def _emulate_stream(plan: BlockPermPlan, A: jnp.ndarray) -> jnp.ndarray:
    """Round through the streaming precision so the XLA oracle / fp32 v1
    kernels see the same input quantization the Pallas v2 path streams
    from HBM (including the seeded stochastic rounding of the ``*_sr``
    policies — value-keyed, so it matches the kernel cast bit-for-bit)."""
    if plan.dtype == "float32":
        return A
    return precision_mod.emulate_stream(A, plan.precision, seed=plan.seed)


def row_map_for(plan: BlockPermPlan, row_index: jnp.ndarray) -> jnp.ndarray:
    """(d_pad,) int32 source-row map.  Padding entries point at row 0 — a
    placeholder valid source; the gather kernel zeroes the corresponding
    scratch rows itself (rows ≥ ``plan.d``), so A is never copied just to
    host a zero row and padding still contributes exact zeros."""
    ri = jnp.asarray(row_index, jnp.int32).reshape(-1)
    pad = plan.d_pad - ri.shape[0]
    if pad == 0:
        return ri
    return jnp.concatenate([ri, jnp.zeros((pad,), jnp.int32)])


_ORACLES = {
    "fwd": kref.flashsketch_ref,
    "transpose": kref.flashsketch_transpose_ref,
    "blockrow": kref.blockrow_ref,
}

_V2_KERNELS = {
    "fwd": fsk.flashsketch_pallas,
    "transpose": fsk.flashsketch_transpose_pallas,
    "blockrow": fsk.blockrow_pallas,
}

_V1_KERNELS = {
    "fwd": fsk.flashsketch_pallas_v1,
    "transpose": fsk.flashsketch_transpose_pallas_v1,
    "blockrow": fsk.blockrow_pallas_v1,
}

_GATHER_KERNELS = {
    "fwd": fsk.flashsketch_pallas_gather,
    "blockrow": fsk.blockrow_pallas_gather,
}


def execute(lw: Lowering, operand: jnp.ndarray,
            row_index: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Run a single-device ``Lowering`` on its operand.

    Args:
      lw: the record from ``lower`` (must have ``shard == "none"`` — the
        sharded layouts are executed by ``repro.distributed`` inside
        ``shard_map``, from the same record).
      operand: ``(d, n)`` for ``fwd``/``blockrow`` (``(d_src, n)`` with a
        gather), ``(k, n)`` for ``transpose``.
      row_index: ``(plan.d,)`` int rows when ``lw.gather`` — required then,
        forbidden otherwise.

    Returns:
      ``(k, n)`` fp32 for the forwards, ``(d, n)`` for the transpose.
    """
    if lw.shard != "none":
        raise ValueError(
            f"execute() runs single-device lowerings; shard={lw.shard!r} "
            f"records are executed by repro.distributed inside shard_map")
    plan = lw.plan
    if lw.gather:
        if row_index is None:
            raise ValueError("gather lowering requires row_index")
        d_keep = row_index.shape[0]
        if d_keep != plan.d:
            raise ValueError(
                f"row_index has {d_keep} entries but plan.d == {plan.d}; "
                f"build the plan for the masked dim (make_plan(d_keep, k, "
                f"...))")
        if not lw.gather_fused:
            # materialize-then-dispatch fallback (v1 / VMEM overflow / xla)
            operand = operand[jnp.asarray(row_index)]
    elif row_index is not None:
        raise ValueError("row_index passed to a non-gather lowering")

    n = operand.shape[1]
    if lw.impl == "xla":
        return _ORACLES[lw.op](plan, _emulate_stream(plan, operand))

    if lw.gather_fused:
        rmap = row_map_for(plan, row_index)
        Y = _GATHER_KERNELS[lw.op](plan, operand, rmap, tn=lw.tn)
        return Y[: plan.k, :n]

    if lw.op == "transpose":
        if operand.shape[0] != plan.k_pad:
            operand = jnp.pad(
                operand, ((0, plan.k_pad - operand.shape[0]), (0, 0)))
    else:
        operand = kref.pad_input(plan, operand)

    if lw.impl == "pallas_v1":
        # v1 computes in fp32; keep the plan's streaming-precision contract
        # by rounding the input exactly as the bf16 stream would.
        out = _V1_KERNELS[lw.op](plan, _emulate_stream(plan, operand),
                                 tn=lw.tn)
    else:
        out = _V2_KERNELS[lw.op](plan, operand, tn=lw.tn)
    rows = plan.d if lw.op == "transpose" else plan.k
    return out[:rows, :n]
