"""Public jit'd wrappers for the FlashSketch kernels.

``sketch_apply(plan, A, impl=..., tn=..., dtype=...)`` handles padding, impl
dispatch, tile selection, and differentiation:

  * ``impl``: ``"pallas"`` (the fused v2 kernel, default on TPU),
    ``"pallas_v1"`` (the original κ-grid-reduction kernel, kept as a
    reference/benchmark baseline), or ``"xla"`` (pure-jnp oracle, default on
    CPU). ``"auto"`` picks per backend.
  * ``tn``: column-tile width.  ``None`` (default) defers to the autotuner
    cache (``kernels.tune.resolve_tn``) — tuned winner if one is cached for
    this shape class, else a VMEM-budget heuristic.  The lookup happens at
    *trace time*: load tuned winners (``tune.load_cache``) before the first
    jitted call for a shape, or pass ``tn`` explicitly — jit will not
    retrace when the cache changes later.
  * ``dtype``: streaming precision override (``"float32"``/``"bfloat16"``);
    ``None`` uses the plan-level knob.  bf16 streams the input at half the
    HBM traffic while accumulating in fp32 (robust per Jeendgar et al.).

Every entry point here is a THIN shell: all resolution — impl dispatch and
downgrades, tile selection, VMEM budgeting, the gather-fuse-or-materialize
decision, padding — lives in ``kernels.lowering``.  Each call builds one
``lowering.LaunchSpec``, resolves it with ``lowering.lower`` (memoized,
trace-time safe) and runs ``lowering.execute`` on the operands; the
``custom_vjp`` wiring below is the only logic this module owns.  Inspect
any launch decision with ``lowering.explain(plan, n=..., ...)`` or the
``tools/explain_lowering.py`` CLI.

The VJP of ``Y = S A`` w.r.t. ``A`` is ``Sᵀ dY`` — the transpose kernel —
so sketching composes with ``jax.grad`` (needed when the sketch sits inside
a training graph, e.g. sketched gradient compression with error feedback).

Gather-fused path (the GraSS sparsify→sketch fusion): every forward entry
point takes ``row_index=`` — a ``(plan.d,)`` int array of source rows — and
computes ``Y = S @ A[row_index, :]`` in ONE kernel launch with no
``A[row_index]`` intermediate (``sketch_apply_indexed`` is the underlying
custom_vjp primitive; its VJP scatters ``Sᵀ dY`` back into the masked
rows).  ``sketch_apply_batched`` folds a stack of matrices into the column
axis of that same single launch, so a B-example batch of sparsified
gradients is sketched at full tile width instead of B skinny launches.

Ragged ``n`` (``n`` not a multiple of the tile) is handled IN-KERNEL on
every path — the edge column tile rides the Pallas machinery (masked
loads/stores on TPU, internal pad+slice in interpret mode) and the gather
kernels clip their row DMAs — so no entry point ever materializes a
column-padded copy of the operand (regression-tested structurally: the
jaxpr contains no ``pad`` of the operand's column axis).
"""
from __future__ import annotations

import functools
import warnings
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core.blockperm import BlockPermPlan
from repro.health import report as health_report
from repro.kernels import lowering

Impl = Literal["auto", "pallas", "pallas_v1", "xla"]


def _lower(plan: BlockPermPlan, op: str, n: int, impl: Impl,
           tn: Optional[int], dtype: Optional[str], *,
           gather: bool = False, batch: int = 1) -> lowering.Lowering:
    return lowering.lower(plan, lowering.LaunchSpec(
        op=op, n=n, impl=impl, tn=tn, dtype=dtype, gather=gather,
        batch=batch))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 2, 3, 4))
def _sketch_apply_vjp(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
):
    """custom_vjp core of ``sketch_apply`` (VJP is ``Sᵀ dY``)."""
    return _sketch_apply_impl(plan, A, impl, tn, dtype)


def sketch_apply(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    *,
    row_index: Optional[jnp.ndarray] = None,
):
    """Apply the sketch: ``Y = S A`` (or ``S A[row_index, :]``, fused).

    Args:
      plan: frozen ``BlockPermPlan`` (static — participates in jit keys).
      A: ``(d, n)`` float array; rows beyond ``plan.d`` must not exist
        (padding to ``d_pad`` is internal).  Any float dtype; the kernel
        streams it in ``plan.stream_dtype`` (see ``dtype`` below).  With
        ``row_index`` the row count is instead the source dim ``d_src``.
      impl: ``"auto"`` (pallas on TPU, xla elsewhere), ``"pallas"`` (v2
        fused-κ kernel; silently downgrades to v1 if the fused Φ scratch
        cannot fit VMEM — the downgrade and its reason are recorded on the
        ``lowering.Lowering`` record), ``"pallas_v1"`` (κ-grid-reduction
        baseline), or ``"xla"`` (pure-jnp oracle).  Anything else raises
        ``ValueError``.
      tn: column-tile width for the Pallas paths; ``None`` defers to the
        autotuner cache (trace-time lookup).  Ignored by ``"xla"``.
      dtype: streaming-precision override, ``"float32"`` or ``"bfloat16"``;
        ``None`` keeps the plan's knob.  bf16 halves the HBM stream of A
        while the MXU accumulates in fp32; the output is always fp32.
      row_index: optional ``(plan.d,)`` int array of source rows; when
        given, computes ``S @ A[row_index, :]`` with the gather fused into
        the kernel load (no ``A[row_index]`` intermediate) — see
        ``sketch_apply_indexed``.

    Returns:
      ``(k, n)`` fp32 array, ``k = plan.k`` (the padded-up sketch dim).
      Differentiable in A: the VJP is ``sketch_apply_t`` (``Sᵀ dY``) at the
      same impl/tn/dtype (scattered back into the masked rows when
      ``row_index`` is given).
    """
    if row_index is None:
        return _sketch_apply_vjp(plan, A, impl, tn, dtype)
    return sketch_apply_indexed(plan, A, row_index, impl, tn, dtype)


def _sketch_apply_impl(plan, A, impl, tn, dtype):
    lw = _lower(plan, "fwd", A.shape[1], impl, tn, dtype)
    return lowering.execute(lw, A)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 2, 3, 4))
def _sketch_apply_t_vjp(
    plan: BlockPermPlan,
    Y: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
):
    """custom_vjp core of ``sketch_apply_t`` (VJP is ``S dX``)."""
    return _sketch_apply_t_impl(plan, Y, impl, tn, dtype)


def sketch_apply_t(
    plan: BlockPermPlan,
    Y: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    *,
    row_index: Optional[jnp.ndarray] = None,
    d_src: Optional[int] = None,
):
    """Apply the transposed sketch: ``X = Sᵀ Y`` (the un-sketch / VJP map).

    Args:
      plan: frozen ``BlockPermPlan``.
      Y: ``(k, n)`` float array (``k = plan.k`` or ``plan.k_pad``; shorter
        inputs are zero-padded to ``k_pad``).  Streamed in the effective
        streaming dtype, accumulated in fp32.
      impl: same valid values and semantics as ``sketch_apply``:
        ``"auto" | "pallas" | "pallas_v1" | "xla"``.
      tn / dtype: as in ``sketch_apply`` (``dtype`` rounds the Y stream to
        bf16 when ``"bfloat16"``; accumulation stays fp32).
      row_index / d_src: the dual of the gather path — when given, the
        compact ``(plan.d, n)`` result is scattered into rows ``row_index``
        of a zero ``(d_src, n)`` array (the un-sketch of a gather-fused
        sketch lands back at the masked coordinates).

    Returns:
      ``(d, n)`` fp32 array (logical d, padding stripped) — or ``(d_src,
      n)`` with the scatter.  Differentiable in Y; the VJP is
      ``sketch_apply``.
    """
    X = _sketch_apply_t_vjp(plan, Y, impl, tn, dtype)
    if row_index is None:
        return X
    if d_src is None:
        raise ValueError("row_index requires d_src (the scatter target dim)")
    out = jnp.zeros((d_src, X.shape[1]), X.dtype)
    return out.at[jnp.asarray(row_index, jnp.int32)].add(X)


def _sketch_apply_t_impl(plan, Y, impl, tn, dtype):
    lw = _lower(plan, "transpose", Y.shape[1], impl, tn, dtype)
    return lowering.execute(lw, Y)


def _apply_fwd(plan, A, impl, tn, dtype):
    return _sketch_apply_impl(plan, A, impl, tn, dtype), None


def _apply_bwd(plan, impl, tn, dtype, _res, dY):
    return (_sketch_apply_t_impl(plan, dY, impl, tn, dtype),)


def _apply_t_fwd(plan, Y, impl, tn, dtype):
    return _sketch_apply_t_impl(plan, Y, impl, tn, dtype), None


def _apply_t_bwd(plan, impl, tn, dtype, _res, dX):
    return (_sketch_apply_impl(plan, dX, impl, tn, dtype),)


_sketch_apply_vjp.defvjp(_apply_fwd, _apply_bwd)
_sketch_apply_t_vjp.defvjp(_apply_t_fwd, _apply_t_bwd)


# ---------------------------------------------------------------------------
# Gather-fused apply: Y = S @ A[row_index, :] in one launch.
# ---------------------------------------------------------------------------

def _sketch_apply_indexed_impl(plan, A, row_index, impl, tn, dtype):
    lw = _lower(plan, "fwd", A.shape[1], impl, tn, dtype, gather=True)
    return lowering.execute(lw, A, row_index=row_index)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 3, 4, 5))
def sketch_apply_indexed(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    row_index: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
):
    """Gather-fused sketch: ``Y = S @ A[row_index, :]`` in ONE launch.

    The sparsify→sketch fusion of the GraSS pipeline: the kernel keeps
    ``A`` in HBM and DMAs only the ``row_index`` rows into its gather
    scratch — no ``A[row_index]`` intermediate is ever written, which
    removes one full read+write of the sparsified matrix per application
    and (batched) turns B per-example gathers into tile-wide streams.

    Args:
      plan: frozen plan for the MASKED dim — ``plan.d`` must equal
        ``len(row_index)``.
      A: ``(d_src, n)`` float array, ``d_src >= 1``; only the indexed rows
        are read (streamed in the effective dtype, see ``dtype``).
      row_index: ``(plan.d,)`` int array of row indices into ``A``.
        Treated as non-differentiable (integer) data.
      impl / tn / dtype: as in ``sketch_apply``.  ``"xla"`` runs the
        materializing oracle ``flashsketch_ref(plan, A[row_index])``;
        ``"pallas_v1"`` (and the VMEM fallback) materialize the gather and
        use the regular kernels — the ``lowering.Lowering`` record keeps
        ``gather_fused=False`` plus the reason.

    Returns:
      ``(k, n)`` fp32 array.  Differentiable in ``A``: the VJP scatters
      ``Sᵀ dY`` into rows ``row_index`` of a zero ``(d_src, n)`` cotangent.
    """
    return _sketch_apply_indexed_impl(plan, A, row_index, impl, tn, dtype)


def _indexed_fwd(plan, A, row_index, impl, tn, dtype):
    out = _sketch_apply_indexed_impl(plan, A, row_index, impl, tn, dtype)
    return out, (row_index, A.shape[0])


def _indexed_bwd(plan, impl, tn, dtype, res, dY):
    row_index, d_src = res
    # the scatter dual is single-sourced in sketch_apply_t(row_index=)
    dA = sketch_apply_t(plan, dY, impl, tn, dtype,
                        row_index=row_index, d_src=d_src)
    return dA, None


sketch_apply_indexed.defvjp(_indexed_fwd, _indexed_bwd)


def blockrow_apply(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    *,
    row_index: Optional[jnp.ndarray] = None,
):
    """FLASHBLOCKROW forward: ``Y = S_blockrow A`` (paper App. C).

    The gather-only appendix variant (iid block wiring, per-row pattern):
    reads A approximately once, but its embedding guarantees are weaker —
    eval-only, and intentionally has NO custom VJP (it never sits inside a
    training graph).

    Args:
      plan: frozen ``BlockPermPlan`` (wiring drawn iid per plan seed).
      A: ``(d, n)`` float array (``(d_src, n)`` with ``row_index``).
      impl: ``"auto" | "pallas" | "pallas_v1" | "xla"`` — same dispatch
        rules as ``sketch_apply``.
      tn / dtype: as in ``sketch_apply`` (bf16 streams A at half the HBM
        traffic, fp32 accumulate).
      row_index: optional ``(plan.d,)`` int rows; computes
        ``S_blockrow @ A[row_index, :]`` with the gather fused in-kernel
        (same contract as ``sketch_apply_indexed``).

    Returns:
      ``(k, n)`` fp32 array.
    """
    lw = _lower(plan, "blockrow", A.shape[1], impl, tn, dtype,
                gather=row_index is not None)
    return lowering.execute(lw, A, row_index=row_index)


def _lower_batched(plan, op, n, impl, tn, dtype, n_batch, gather):
    """One batch-aware lowering shared by the two batch entry points, so
    ``sketch_vectors`` and ``sketch_apply_batched`` resolve the identical
    launch (same tuner shape class, same downgrade ladder)."""
    return lowering.lower(plan, lowering.LaunchSpec(
        op=op, n=n, impl=impl, tn=tn, dtype=dtype, gather=gather,
        batch=n_batch))


def sketch_vectors(plan: BlockPermPlan, x: jnp.ndarray, impl: Impl = "auto",
                   tn: Optional[int] = None, dtype: Optional[str] = None,
                   *, row_index: Optional[jnp.ndarray] = None):
    """Sketch a batch of vectors laid out along the LAST axis.

    Args:
      plan: the frozen sketch draw (``core.blockperm.make_plan``).
      x: ``(..., d)`` float array; leading axes are an arbitrary batch
        (``(..., d_src)`` with ``row_index`` — e.g. a stack of raw
        per-example gradients whose sparsification is fused into the
        sketch).
      impl: one of ``"auto" | "pallas" | "pallas_v1" | "xla"`` (see
        ``sketch_apply``).
      tn / dtype: forwarded to ``sketch_apply``.  ``tn=None`` resolves
        against the autotuner's *batched* shape class exactly as
        ``sketch_apply_batched`` does (each vector is a width-1 matrix,
        the batch is folded into the column axis) — both entry points
        share ``_lower_batched``.
      row_index: optional ``(plan.d,)`` int rows — fused
        ``S x[..., row_index]`` (the GraSS sparsify→sketch fusion).

    Returns:
      ``(..., k)`` array, ``y[..., :] = S x[..., :]``.  Internally the batch
      is flattened into the column axis of one ``sketch_apply`` launch.
    """
    flat = x.reshape(-1, x.shape[-1])                 # (n, d)
    if tn is None:
        tn = _lower_batched(plan, "fwd", 1, impl, tn, dtype, flat.shape[0],
                            row_index is not None).tn
    Y = sketch_apply(plan, flat.T, impl, tn, dtype,
                     row_index=row_index)             # (k, n)
    return Y.T.reshape(*x.shape[:-1], plan.k)


def sketch_apply_batched(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    *,
    row_index: Optional[jnp.ndarray] = None,
):
    """Apply S to a stack of matrices in ONE kernel launch.

    Args:
      plan: the frozen sketch draw.
      A: ``(..., d, n)`` float array — a batch of tall matrices sharing the
        sketch.  The batch axes are folded into the column axis (``S`` acts
        on the row axis only), so a ``(B, d, n)`` stack costs one launch on
        a ``(d, B·n)`` operand instead of ``B`` launches (or a vmap, which
        would re-trace the Pallas kernel per batch layout).  The cached Φ
        scratch is built once per launch and reused across the whole batch.
      impl / tn / dtype: forwarded to ``sketch_apply`` (same valid values).
        ``tn=None`` resolves against the autotuner's *batched* shape class
        (``batch=B`` on the ``LaunchSpec``), not the per-matrix width.
      row_index: optional ``(plan.d,)`` int rows shared by every batch
        element — fused ``S @ A[b][row_index, :]`` per element, still one
        launch (the GraSS per-example-gradient path).

    Returns:
      ``(..., k, n)`` array with ``out[b] = S @ A[b]`` for every batch
      index ``b``.  Differentiable in ``A`` (inherits the custom VJP of
      ``sketch_apply`` / ``sketch_apply_indexed``).
    """
    if A.ndim < 2:
        raise ValueError(f"A must be at least 2-D (d, n), got shape {A.shape}")
    batch = A.shape[:-2]
    d, n = A.shape[-2:]
    n_batch = 1
    for b in batch:
        n_batch *= b
    if tn is None:
        tn = _lower_batched(plan, "fwd", n, impl, tn, dtype, n_batch,
                            row_index is not None).tn
    flat = jnp.moveaxis(A.reshape((-1, d, n)), 0, 1).reshape(d, -1)  # (d, B·n)
    Y = sketch_apply(plan, flat, impl, tn, dtype, row_index=row_index)
    Y = jnp.moveaxis(Y.reshape(plan.k, -1, n), 1, 0)                 # (k, B·n)
    return Y.reshape(*batch, plan.k, n)


def sketch_qr(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    factorization: str = "qr",
):
    """Sketch-and-factor: ``SA = S A`` plus a triangular factor of ``SA``.

    The workhorse of sketch-and-precondition (Rokhlin–Tygert / Blendenpik
    lineage): for tall ``A (d, n)`` with ``d >> n``, the ``(k, n)`` sketch
    ``SA`` is an approximate isometry on ``range(A)``, so the triangular
    ``R`` with ``SAᵀ SA = Rᵀ R`` makes ``A R⁻¹`` nearly orthonormal — LSQR
    on ``A R⁻¹`` then converges in O(1) iterations regardless of cond(A).

    Args:
      plan: the frozen sketch draw; ``plan.k`` should be a few × n.
      A: ``(d, n)`` float array, ``d >> n``.
      impl / tn / dtype: forwarded to ``sketch_apply`` (``dtype="bfloat16"``
        streams the sketch in bf16; the factorization itself is always fp32).
      factorization: ``"qr"`` (Householder QR of SA — backward stable) or
        ``"chol"`` (Cholesky of ``SAᵀSA`` — cheaper, squares the condition
        number of the sketch; fine when ``SA`` is well-conditioned, which a
        subspace-embedding sketch guarantees).

    Returns:
      ``(SA, R)``: the sketch ``(k, n)`` and upper-triangular ``R (n, n)``
      with ``SAᵀ SA = Rᵀ R`` (up to rounding).  ``R`` may be singular only
      if ``A`` is rank-deficient.
    """
    SA = sketch_apply(plan, A, impl, tn, dtype).astype(jnp.float32)
    return SA, triangular_factor(SA, factorization)


def triangular_factor(SA: jnp.ndarray, factorization: str = "qr") -> jnp.ndarray:
    """Upper-triangular R (n, n) with ``SAᵀ SA = Rᵀ R``, positive diagonal.

    Args:
      SA: ``(k, n)`` fp32 matrix (typically a sketch).
      factorization: ``"qr"`` (Householder QR — backward stable) or
        ``"chol"`` (Cholesky of the Gram — cheaper, squares the condition
        number).  Anything else raises ``ValueError``.

    Returns:
      R with a positive diagonal (fixes the QR/Cholesky sign ambiguity so
      the two factorizations agree and ``R⁻¹`` is well-defined).

    The Cholesky path squares cond(SA); on a (near-)rank-deficient Gram it
    silently returns NaN columns rather than raising.  On concrete (eager)
    inputs a non-finite Cholesky factor is detected and automatically
    downgraded to Householder QR, with the reason recorded in the health
    registry (``factor.chol_downgrade``) and warned once per call — under
    a jit tracer values are unreadable, so the jitted path keeps the
    caller's choice (guarded entry points run this eagerly).
    """
    if factorization == "qr":
        R = jnp.linalg.qr(SA, mode="r")
    elif factorization == "chol":
        R = jnp.linalg.cholesky(SA.T @ SA).T  # upper-triangular
        if not isinstance(R, jax.core.Tracer) and not bool(
                jnp.all(jnp.isfinite(R))):
            health_report.record(
                "factor.chol_downgrade",
                detail="non-finite Cholesky factor -> Householder QR")
            warnings.warn(
                "Cholesky of the sketch Gram returned non-finite entries "
                "(near-rank-deficient SA); falling back to Householder QR",
                RuntimeWarning, stacklevel=2)
            R = jnp.linalg.qr(SA, mode="r")
    else:
        raise ValueError(
            f"factorization must be 'qr' or 'chol', got {factorization!r}")
    sgn = jnp.sign(jnp.diagonal(R))
    sgn = jnp.where(sgn == 0, 1.0, sgn)
    return R * sgn[:, None]
