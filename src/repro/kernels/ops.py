"""Public jit'd wrappers for the FlashSketch kernels.

``sketch_apply(plan, A, impl=...)`` handles padding, impl dispatch
(Pallas-on-TPU / interpret-on-CPU / pure-XLA einsum), and differentiation:
the VJP of ``Y = S A`` w.r.t. ``A`` is ``Sᵀ dY`` — the transpose kernel —
so sketching composes with ``jax.grad`` (needed when the sketch sits inside
a training graph, e.g. sketched gradient compression with error feedback).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.blockperm import BlockPermPlan
from repro.kernels import flashsketch as fsk
from repro.kernels import ref as kref

Impl = Literal["auto", "pallas", "xla"]


def _resolve_impl(impl: Impl) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _pad_cols(A: jnp.ndarray, tn: int) -> tuple[jnp.ndarray, int]:
    n = A.shape[1]
    n_pad = ((n + tn - 1) // tn) * tn
    if n_pad != n:
        A = jnp.pad(A, ((0, 0), (0, n_pad - n)))
    return A, n


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 2, 3))
def sketch_apply(plan: BlockPermPlan, A: jnp.ndarray, impl: Impl = "auto", tn: int = 128):
    """Y = S A.  A: (d, n) -> (k, n).  Differentiable in A."""
    return _sketch_apply_impl(plan, A, impl, tn)


def _sketch_apply_impl(plan, A, impl, tn):
    impl = _resolve_impl(impl)
    if impl == "xla":
        return kref.flashsketch_ref(plan, A)
    Ap = kref.pad_input(plan, A)
    Ap, n = _pad_cols(Ap, tn)
    Y = fsk.flashsketch_pallas(plan, Ap, tn=tn)
    return Y[: plan.k, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 2, 3))
def sketch_apply_t(plan: BlockPermPlan, Y: jnp.ndarray, impl: Impl = "auto", tn: int = 128):
    """X = Sᵀ Y.  Y: (k, n) -> (d, n).  Differentiable in Y."""
    return _sketch_apply_t_impl(plan, Y, impl, tn)


def _sketch_apply_t_impl(plan, Y, impl, tn):
    impl = _resolve_impl(impl)
    if impl == "xla":
        return kref.flashsketch_transpose_ref(plan, Y)
    Yp = Y
    if Y.shape[0] != plan.k_pad:
        Yp = jnp.pad(Y, ((0, plan.k_pad - Y.shape[0]), (0, 0)))
    Yp, n = _pad_cols(Yp, tn)
    X = fsk.flashsketch_transpose_pallas(plan, Yp, tn=tn)
    return X[: plan.d, :n]


def _apply_fwd(plan, A, impl, tn):
    return _sketch_apply_impl(plan, A, impl, tn), None


def _apply_bwd(plan, impl, tn, _res, dY):
    return (_sketch_apply_t_impl(plan, dY, impl, tn),)


def _apply_t_fwd(plan, Y, impl, tn):
    return _sketch_apply_t_impl(plan, Y, impl, tn), None


def _apply_t_bwd(plan, impl, tn, _res, dX):
    return (_sketch_apply_impl(plan, dX, impl, tn),)


sketch_apply.defvjp(_apply_fwd, _apply_bwd)
sketch_apply_t.defvjp(_apply_t_fwd, _apply_t_bwd)


def blockrow_apply(plan: BlockPermPlan, A: jnp.ndarray, impl: Impl = "auto", tn: int = 128):
    """FLASHBLOCKROW forward (no VJP — appendix-C variant is eval-only)."""
    impl = _resolve_impl(impl)
    if impl == "xla":
        return kref.blockrow_ref(plan, A)
    Ap = kref.pad_input(plan, A)
    Ap, n = _pad_cols(Ap, tn)
    Y = fsk.blockrow_pallas(plan, Ap, tn=tn)
    return Y[: plan.k, :n]


def sketch_vectors(plan: BlockPermPlan, x: jnp.ndarray, impl: Impl = "auto"):
    """Sketch a single vector or batch-of-vectors laid out (..., d) -> (..., k)."""
    flat = x.reshape(-1, x.shape[-1])                 # (n, d)
    Y = sketch_apply(plan, flat.T, impl)              # (k, n)
    return Y.T.reshape(*x.shape[:-1], plan.k)
