"""Public jit'd wrappers for the FlashSketch kernels.

``sketch_apply(plan, A, impl=..., tn=..., dtype=...)`` handles padding, impl
dispatch, tile selection, and differentiation:

  * ``impl``: ``"pallas"`` (the fused v2 kernel, default on TPU),
    ``"pallas_v1"`` (the original κ-grid-reduction kernel, kept as a
    reference/benchmark baseline), or ``"xla"`` (pure-jnp oracle, default on
    CPU). ``"auto"`` picks per backend.
  * ``tn``: column-tile width.  ``None`` (default) defers to the autotuner
    cache (``kernels.tune.resolve_tn``) — tuned winner if one is cached for
    this shape class, else a VMEM-budget heuristic.  The lookup happens at
    *trace time*: load tuned winners (``tune.load_cache``) before the first
    jitted call for a shape, or pass ``tn`` explicitly — jit will not
    retrace when the cache changes later.
  * ``dtype``: streaming precision override (``"float32"``/``"bfloat16"``);
    ``None`` uses the plan-level knob.  bf16 streams the input at half the
    HBM traffic while accumulating in fp32 (robust per Jeendgar et al.).

The VJP of ``Y = S A`` w.r.t. ``A`` is ``Sᵀ dY`` — the transpose kernel —
so sketching composes with ``jax.grad`` (needed when the sketch sits inside
a training graph, e.g. sketched gradient compression with error feedback).
"""
from __future__ import annotations

import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core.blockperm import BlockPermPlan
from repro.kernels import flashsketch as fsk
from repro.kernels import ref as kref
from repro.kernels import tune

Impl = Literal["auto", "pallas", "pallas_v1", "xla"]

_PALLAS_IMPLS = ("pallas", "pallas_v1")


def _resolve_impl(impl: Impl) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("xla",) + _PALLAS_IMPLS:
        raise ValueError(
            f"impl must be one of ('auto', 'pallas', 'pallas_v1', 'xla'), "
            f"got {impl!r}")
    return impl


def _resolve_pallas(impl: str, plan: BlockPermPlan, n: int, variant: str) -> str:
    """Downgrade v2 → v1 when the fused Φ scratch cannot fit VMEM.

    The stacked Φ is (Br, κ·Bc), independent of the tile width, so huge
    d_pad/M plans must use the revisiting kernel on real hardware.  (In
    interpret mode there is no VMEM, but dispatch stays consistent so the
    two backends run the same kernel for a given shape.)
    """
    if impl == "pallas" and not tune.fused_fits_vmem(plan, n, variant):
        return "pallas_v1"
    return impl


def _resolve_plan(plan: BlockPermPlan, dtype: Optional[str]) -> BlockPermPlan:
    if dtype is None or dtype == plan.dtype:
        return plan
    return plan.with_dtype(dtype)


def _resolve_tn(tn: Optional[int], plan: BlockPermPlan, n: int, variant: str,
                impl: str = "pallas") -> int:
    if tn is None:
        if impl == "pallas_v1":
            # v1's working set is one block pair + the Φ tile — the v2
            # VMEM heuristic would pick a degenerate tile here.
            return tune.v1_default_tn(plan, n)
        return tune.resolve_tn(plan, n, variant)
    if tn < 1:
        raise ValueError(f"tn must be >= 1, got {tn}")
    return tn


def _pad_cols(A: jnp.ndarray, tn: int) -> tuple[jnp.ndarray, int]:
    n = A.shape[1]
    n_pad = ((n + tn - 1) // tn) * tn
    if n_pad != n:
        A = jnp.pad(A, ((0, 0), (0, n_pad - n)))
    return A, n


def _emulate_stream(plan: BlockPermPlan, A: jnp.ndarray) -> jnp.ndarray:
    """Round through the streaming dtype so the XLA oracle sees the same
    input precision the Pallas bf16 path streams from HBM."""
    if plan.dtype == "float32":
        return A
    return A.astype(plan.stream_dtype).astype(jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 2, 3, 4))
def sketch_apply(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
):
    """Apply the sketch: ``Y = S A``.

    Args:
      plan: frozen ``BlockPermPlan`` (static — participates in jit keys).
      A: ``(d, n)`` float array; rows beyond ``plan.d`` must not exist
        (padding to ``d_pad`` is internal).  Any float dtype; the kernel
        streams it in ``plan.stream_dtype`` (see ``dtype`` below).
      impl: ``"auto"`` (pallas on TPU, xla elsewhere), ``"pallas"`` (v2
        fused-κ kernel; silently downgrades to v1 if the fused Φ scratch
        cannot fit VMEM), ``"pallas_v1"`` (κ-grid-reduction baseline), or
        ``"xla"`` (pure-jnp oracle).  Anything else raises ``ValueError``.
      tn: column-tile width for the Pallas paths; ``None`` defers to the
        autotuner cache (trace-time lookup).  Ignored by ``"xla"``.
      dtype: streaming-precision override, ``"float32"`` or ``"bfloat16"``;
        ``None`` keeps the plan's knob.  bf16 halves the HBM stream of A
        while the MXU accumulates in fp32; the output is always fp32.

    Returns:
      ``(k, n)`` fp32 array, ``k = plan.k`` (the padded-up sketch dim).
      Differentiable in A: the VJP is ``sketch_apply_t`` (``Sᵀ dY``) at the
      same impl/tn/dtype.
    """
    return _sketch_apply_impl(plan, A, impl, tn, dtype)


def _sketch_apply_impl(plan, A, impl, tn, dtype):
    plan = _resolve_plan(plan, dtype)
    impl = _resolve_impl(impl)
    if impl == "xla":
        return kref.flashsketch_ref(plan, _emulate_stream(plan, A))
    assert impl in _PALLAS_IMPLS, impl
    Ap = kref.pad_input(plan, A)
    impl = _resolve_pallas(impl, plan, Ap.shape[1], "fwd")
    tn = _resolve_tn(tn, plan, Ap.shape[1], "fwd", impl)
    Ap, n = _pad_cols(Ap, tn)
    if impl == "pallas_v1":
        # v1 computes in fp32; keep the plan's streaming-precision contract
        # by rounding the input exactly as the bf16 stream would.
        Y = fsk.flashsketch_pallas_v1(plan, _emulate_stream(plan, Ap), tn=tn)
    else:
        Y = fsk.flashsketch_pallas(plan, Ap, tn=tn)
    return Y[: plan.k, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 2, 3, 4))
def sketch_apply_t(
    plan: BlockPermPlan,
    Y: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
):
    """Apply the transposed sketch: ``X = Sᵀ Y`` (the un-sketch / VJP map).

    Args:
      plan: frozen ``BlockPermPlan``.
      Y: ``(k, n)`` float array (``k = plan.k`` or ``plan.k_pad``; shorter
        inputs are zero-padded to ``k_pad``).  Streamed in the effective
        streaming dtype, accumulated in fp32.
      impl: same valid values and semantics as ``sketch_apply``:
        ``"auto" | "pallas" | "pallas_v1" | "xla"``.
      tn / dtype: as in ``sketch_apply`` (``dtype`` rounds the Y stream to
        bf16 when ``"bfloat16"``; accumulation stays fp32).

    Returns:
      ``(d, n)`` fp32 array (logical d, padding stripped).  Differentiable
      in Y; the VJP is ``sketch_apply``.
    """
    return _sketch_apply_t_impl(plan, Y, impl, tn, dtype)


def _sketch_apply_t_impl(plan, Y, impl, tn, dtype):
    plan = _resolve_plan(plan, dtype)
    impl = _resolve_impl(impl)
    if impl == "xla":
        return kref.flashsketch_transpose_ref(plan, _emulate_stream(plan, Y))
    assert impl in _PALLAS_IMPLS, impl
    Yp = Y
    if Y.shape[0] != plan.k_pad:
        Yp = jnp.pad(Y, ((0, plan.k_pad - Y.shape[0]), (0, 0)))
    impl = _resolve_pallas(impl, plan, Yp.shape[1], "transpose")
    tn = _resolve_tn(tn, plan, Yp.shape[1], "transpose", impl)
    Yp, n = _pad_cols(Yp, tn)
    if impl == "pallas_v1":
        X = fsk.flashsketch_transpose_pallas_v1(plan, _emulate_stream(plan, Yp), tn=tn)
    else:
        X = fsk.flashsketch_transpose_pallas(plan, Yp, tn=tn)
    return X[: plan.d, :n]


def _apply_fwd(plan, A, impl, tn, dtype):
    return _sketch_apply_impl(plan, A, impl, tn, dtype), None


def _apply_bwd(plan, impl, tn, dtype, _res, dY):
    return (_sketch_apply_t_impl(plan, dY, impl, tn, dtype),)


def _apply_t_fwd(plan, Y, impl, tn, dtype):
    return _sketch_apply_t_impl(plan, Y, impl, tn, dtype), None


def _apply_t_bwd(plan, impl, tn, dtype, _res, dX):
    return (_sketch_apply_impl(plan, dX, impl, tn, dtype),)


sketch_apply.defvjp(_apply_fwd, _apply_bwd)
sketch_apply_t.defvjp(_apply_t_fwd, _apply_t_bwd)


def blockrow_apply(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
):
    """FLASHBLOCKROW forward: ``Y = S_blockrow A`` (paper App. C).

    The gather-only appendix variant (iid block wiring, per-row pattern):
    reads A approximately once, but its embedding guarantees are weaker —
    eval-only, and intentionally has NO custom VJP (it never sits inside a
    training graph).

    Args:
      plan: frozen ``BlockPermPlan`` (wiring drawn iid per plan seed).
      A: ``(d, n)`` float array.
      impl: ``"auto" | "pallas" | "pallas_v1" | "xla"`` — same dispatch
        rules as ``sketch_apply``.
      tn / dtype: as in ``sketch_apply`` (bf16 streams A at half the HBM
        traffic, fp32 accumulate).

    Returns:
      ``(k, n)`` fp32 array.
    """
    plan = _resolve_plan(plan, dtype)
    impl = _resolve_impl(impl)
    if impl == "xla":
        return kref.blockrow_ref(plan, _emulate_stream(plan, A))
    assert impl in _PALLAS_IMPLS, impl
    Ap = kref.pad_input(plan, A)
    impl = _resolve_pallas(impl, plan, Ap.shape[1], "blockrow")
    tn = _resolve_tn(tn, plan, Ap.shape[1], "blockrow", impl)
    Ap, n = _pad_cols(Ap, tn)
    if impl == "pallas_v1":
        Y = fsk.blockrow_pallas_v1(plan, _emulate_stream(plan, Ap), tn=tn)
    else:
        Y = fsk.blockrow_pallas(plan, Ap, tn=tn)
    return Y[: plan.k, :n]


def sketch_vectors(plan: BlockPermPlan, x: jnp.ndarray, impl: Impl = "auto"):
    """Sketch a batch of vectors laid out along the LAST axis.

    Args:
      plan: the frozen sketch draw (``core.blockperm.make_plan``).
      x: ``(..., d)`` float array; leading axes are an arbitrary batch.
      impl: one of ``"auto" | "pallas" | "pallas_v1" | "xla"`` (see
        ``sketch_apply``).

    Returns:
      ``(..., k)`` array, ``y[..., :] = S x[..., :]``.  Internally the batch
      is flattened into the column axis of one ``sketch_apply`` launch.
    """
    flat = x.reshape(-1, x.shape[-1])                 # (n, d)
    Y = sketch_apply(plan, flat.T, impl)              # (k, n)
    return Y.T.reshape(*x.shape[:-1], plan.k)


def sketch_apply_batched(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
):
    """Apply S to a stack of matrices in ONE kernel launch.

    Args:
      plan: the frozen sketch draw.
      A: ``(..., d, n)`` float array — a batch of tall matrices sharing the
        sketch.  The batch axes are folded into the column axis (``S`` acts
        on the row axis only), so a ``(B, d, n)`` stack costs one launch on
        a ``(d, B·n)`` operand instead of ``B`` launches (or a vmap, which
        would re-trace the Pallas kernel per batch layout).
      impl / tn / dtype: forwarded to ``sketch_apply`` (same valid values).

    Returns:
      ``(..., k, n)`` array with ``out[b] = S @ A[b]`` for every batch
      index ``b``.  Differentiable in ``A`` (inherits ``sketch_apply``'s
      custom VJP).
    """
    if A.ndim < 2:
        raise ValueError(f"A must be at least 2-D (d, n), got shape {A.shape}")
    batch = A.shape[:-2]
    d, n = A.shape[-2:]
    flat = jnp.moveaxis(A.reshape((-1, d, n)), 0, 1).reshape(d, -1)  # (d, B·n)
    Y = sketch_apply(plan, flat, impl, tn, dtype)                    # (k, B·n)
    Y = jnp.moveaxis(Y.reshape(plan.k, -1, n), 1, 0)
    return Y.reshape(*batch, plan.k, n)


def sketch_qr(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    factorization: str = "qr",
):
    """Sketch-and-factor: ``SA = S A`` plus a triangular factor of ``SA``.

    The workhorse of sketch-and-precondition (Rokhlin–Tygert / Blendenpik
    lineage): for tall ``A (d, n)`` with ``d >> n``, the ``(k, n)`` sketch
    ``SA`` is an approximate isometry on ``range(A)``, so the triangular
    ``R`` with ``SAᵀ SA = Rᵀ R`` makes ``A R⁻¹`` nearly orthonormal — LSQR
    on ``A R⁻¹`` then converges in O(1) iterations regardless of cond(A).

    Args:
      plan: the frozen sketch draw; ``plan.k`` should be a few × n.
      A: ``(d, n)`` float array, ``d >> n``.
      impl / tn / dtype: forwarded to ``sketch_apply`` (``dtype="bfloat16"``
        streams the sketch in bf16; the factorization itself is always fp32).
      factorization: ``"qr"`` (Householder QR of SA — backward stable) or
        ``"chol"`` (Cholesky of ``SAᵀSA`` — cheaper, squares the condition
        number of the sketch; fine when ``SA`` is well-conditioned, which a
        subspace-embedding sketch guarantees).

    Returns:
      ``(SA, R)``: the sketch ``(k, n)`` and upper-triangular ``R (n, n)``
      with ``SAᵀ SA = Rᵀ R`` (up to rounding).  ``R`` may be singular only
      if ``A`` is rank-deficient.
    """
    SA = sketch_apply(plan, A, impl, tn, dtype).astype(jnp.float32)
    return SA, triangular_factor(SA, factorization)


def triangular_factor(SA: jnp.ndarray, factorization: str = "qr") -> jnp.ndarray:
    """Upper-triangular R (n, n) with ``SAᵀ SA = Rᵀ R``, positive diagonal.

    Args:
      SA: ``(k, n)`` fp32 matrix (typically a sketch).
      factorization: ``"qr"`` (Householder QR — backward stable) or
        ``"chol"`` (Cholesky of the Gram — cheaper, squares the condition
        number).  Anything else raises ``ValueError``.

    Returns:
      R with a positive diagonal (fixes the QR/Cholesky sign ambiguity so
      the two factorizations agree and ``R⁻¹`` is well-defined).
    """
    if factorization == "qr":
        R = jnp.linalg.qr(SA, mode="r")
    elif factorization == "chol":
        R = jnp.linalg.cholesky(SA.T @ SA).T  # upper-triangular
    else:
        raise ValueError(
            f"factorization must be 'qr' or 'chol', got {factorization!r}")
    sgn = jnp.sign(jnp.diagonal(R))
    sgn = jnp.where(sgn == 0, 1.0, sgn)
    return R * sgn[:, None]
