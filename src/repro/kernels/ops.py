"""Public jit'd wrappers for the FlashSketch kernels.

``sketch_apply(plan, A, impl=..., tn=..., dtype=...)`` handles padding, impl
dispatch, tile selection, and differentiation:

  * ``impl``: ``"pallas"`` (the fused v2 kernel, default on TPU),
    ``"pallas_v1"`` (the original κ-grid-reduction kernel, kept as a
    reference/benchmark baseline), or ``"xla"`` (pure-jnp oracle, default on
    CPU). ``"auto"`` picks per backend.
  * ``tn``: column-tile width.  ``None`` (default) defers to the autotuner
    cache (``kernels.tune.resolve_tn``) — tuned winner if one is cached for
    this shape class, else a VMEM-budget heuristic.  The lookup happens at
    *trace time*: load tuned winners (``tune.load_cache``) before the first
    jitted call for a shape, or pass ``tn`` explicitly — jit will not
    retrace when the cache changes later.
  * ``dtype``: streaming precision override (``"float32"``/``"bfloat16"``);
    ``None`` uses the plan-level knob.  bf16 streams the input at half the
    HBM traffic while accumulating in fp32 (robust per Jeendgar et al.).

The VJP of ``Y = S A`` w.r.t. ``A`` is ``Sᵀ dY`` — the transpose kernel —
so sketching composes with ``jax.grad`` (needed when the sketch sits inside
a training graph, e.g. sketched gradient compression with error feedback).

Gather-fused path (the GraSS sparsify→sketch fusion): every forward entry
point takes ``row_index=`` — a ``(plan.d,)`` int array of source rows — and
computes ``Y = S @ A[row_index, :]`` in ONE kernel launch with no
``A[row_index]`` intermediate (``sketch_apply_indexed`` is the underlying
custom_vjp primitive; its VJP scatters ``Sᵀ dY`` back into the masked
rows).  ``sketch_apply_batched`` folds a stack of matrices into the column
axis of that same single launch, so a B-example batch of sparsified
gradients is sketched at full tile width instead of B skinny launches.
"""
from __future__ import annotations

import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core.blockperm import BlockPermPlan
from repro.kernels import flashsketch as fsk
from repro.kernels import ref as kref
from repro.kernels import tune

Impl = Literal["auto", "pallas", "pallas_v1", "xla"]

_PALLAS_IMPLS = ("pallas", "pallas_v1")


def _resolve_impl(impl: Impl) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("xla",) + _PALLAS_IMPLS:
        raise ValueError(
            f"impl must be one of ('auto', 'pallas', 'pallas_v1', 'xla'), "
            f"got {impl!r}")
    return impl


def _resolve_pallas(impl: str, plan: BlockPermPlan, n: int, variant: str) -> str:
    """Downgrade v2 → v1 when the fused Φ scratch cannot fit VMEM.

    The stacked Φ is (Br, κ·Bc), independent of the tile width, so huge
    d_pad/M plans must use the revisiting kernel on real hardware.  (In
    interpret mode there is no VMEM, but dispatch stays consistent so the
    two backends run the same kernel for a given shape.)
    """
    if impl == "pallas" and not tune.fused_fits_vmem(plan, n, variant):
        return "pallas_v1"
    return impl


def _resolve_plan(plan: BlockPermPlan, dtype: Optional[str]) -> BlockPermPlan:
    if dtype is None or dtype == plan.dtype:
        return plan
    return plan.with_dtype(dtype)


def _resolve_tn(tn: Optional[int], plan: BlockPermPlan, n: int, variant: str,
                impl: str = "pallas") -> int:
    if tn is None:
        if impl == "pallas_v1":
            # v1's working set is one block pair + the Φ tile — the v2
            # VMEM heuristic would pick a degenerate tile here.
            return tune.v1_default_tn(plan, n)
        return tune.resolve_tn(plan, n, variant)
    if tn < 1:
        raise ValueError(f"tn must be >= 1, got {tn}")
    return tn


def _pad_cols(A: jnp.ndarray, tn: int) -> tuple[jnp.ndarray, int]:
    n = A.shape[1]
    n_pad = ((n + tn - 1) // tn) * tn
    if n_pad != n:
        A = jnp.pad(A, ((0, 0), (0, n_pad - n)))
    return A, n


def _emulate_stream(plan: BlockPermPlan, A: jnp.ndarray) -> jnp.ndarray:
    """Round through the streaming dtype so the XLA oracle sees the same
    input precision the Pallas bf16 path streams from HBM."""
    if plan.dtype == "float32":
        return A
    return A.astype(plan.stream_dtype).astype(jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 2, 3, 4))
def _sketch_apply_vjp(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
):
    """custom_vjp core of ``sketch_apply`` (VJP is ``Sᵀ dY``)."""
    return _sketch_apply_impl(plan, A, impl, tn, dtype)


def sketch_apply(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    *,
    row_index: Optional[jnp.ndarray] = None,
):
    """Apply the sketch: ``Y = S A`` (or ``S A[row_index, :]``, fused).

    Args:
      plan: frozen ``BlockPermPlan`` (static — participates in jit keys).
      A: ``(d, n)`` float array; rows beyond ``plan.d`` must not exist
        (padding to ``d_pad`` is internal).  Any float dtype; the kernel
        streams it in ``plan.stream_dtype`` (see ``dtype`` below).  With
        ``row_index`` the row count is instead the source dim ``d_src``.
      impl: ``"auto"`` (pallas on TPU, xla elsewhere), ``"pallas"`` (v2
        fused-κ kernel; silently downgrades to v1 if the fused Φ scratch
        cannot fit VMEM), ``"pallas_v1"`` (κ-grid-reduction baseline), or
        ``"xla"`` (pure-jnp oracle).  Anything else raises ``ValueError``.
      tn: column-tile width for the Pallas paths; ``None`` defers to the
        autotuner cache (trace-time lookup).  Ignored by ``"xla"``.
      dtype: streaming-precision override, ``"float32"`` or ``"bfloat16"``;
        ``None`` keeps the plan's knob.  bf16 halves the HBM stream of A
        while the MXU accumulates in fp32; the output is always fp32.
      row_index: optional ``(plan.d,)`` int array of source rows; when
        given, computes ``S @ A[row_index, :]`` with the gather fused into
        the kernel load (no ``A[row_index]`` intermediate) — see
        ``sketch_apply_indexed``.

    Returns:
      ``(k, n)`` fp32 array, ``k = plan.k`` (the padded-up sketch dim).
      Differentiable in A: the VJP is ``sketch_apply_t`` (``Sᵀ dY``) at the
      same impl/tn/dtype (scattered back into the masked rows when
      ``row_index`` is given).
    """
    if row_index is None:
        return _sketch_apply_vjp(plan, A, impl, tn, dtype)
    return sketch_apply_indexed(plan, A, row_index, impl, tn, dtype)


def _sketch_apply_impl(plan, A, impl, tn, dtype):
    plan = _resolve_plan(plan, dtype)
    impl = _resolve_impl(impl)
    if impl == "xla":
        return kref.flashsketch_ref(plan, _emulate_stream(plan, A))
    assert impl in _PALLAS_IMPLS, impl
    Ap = kref.pad_input(plan, A)
    impl = _resolve_pallas(impl, plan, Ap.shape[1], "fwd")
    tn = _resolve_tn(tn, plan, Ap.shape[1], "fwd", impl)
    Ap, n = _pad_cols(Ap, tn)
    if impl == "pallas_v1":
        # v1 computes in fp32; keep the plan's streaming-precision contract
        # by rounding the input exactly as the bf16 stream would.
        Y = fsk.flashsketch_pallas_v1(plan, _emulate_stream(plan, Ap), tn=tn)
    else:
        Y = fsk.flashsketch_pallas(plan, Ap, tn=tn)
    return Y[: plan.k, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 2, 3, 4))
def _sketch_apply_t_vjp(
    plan: BlockPermPlan,
    Y: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
):
    """custom_vjp core of ``sketch_apply_t`` (VJP is ``S dX``)."""
    return _sketch_apply_t_impl(plan, Y, impl, tn, dtype)


def sketch_apply_t(
    plan: BlockPermPlan,
    Y: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    *,
    row_index: Optional[jnp.ndarray] = None,
    d_src: Optional[int] = None,
):
    """Apply the transposed sketch: ``X = Sᵀ Y`` (the un-sketch / VJP map).

    Args:
      plan: frozen ``BlockPermPlan``.
      Y: ``(k, n)`` float array (``k = plan.k`` or ``plan.k_pad``; shorter
        inputs are zero-padded to ``k_pad``).  Streamed in the effective
        streaming dtype, accumulated in fp32.
      impl: same valid values and semantics as ``sketch_apply``:
        ``"auto" | "pallas" | "pallas_v1" | "xla"``.
      tn / dtype: as in ``sketch_apply`` (``dtype`` rounds the Y stream to
        bf16 when ``"bfloat16"``; accumulation stays fp32).
      row_index / d_src: the dual of the gather path — when given, the
        compact ``(plan.d, n)`` result is scattered into rows ``row_index``
        of a zero ``(d_src, n)`` array (the un-sketch of a gather-fused
        sketch lands back at the masked coordinates).

    Returns:
      ``(d, n)`` fp32 array (logical d, padding stripped) — or ``(d_src,
      n)`` with the scatter.  Differentiable in Y; the VJP is
      ``sketch_apply``.
    """
    X = _sketch_apply_t_vjp(plan, Y, impl, tn, dtype)
    if row_index is None:
        return X
    if d_src is None:
        raise ValueError("row_index requires d_src (the scatter target dim)")
    out = jnp.zeros((d_src, X.shape[1]), X.dtype)
    return out.at[jnp.asarray(row_index, jnp.int32)].add(X)


def _sketch_apply_t_impl(plan, Y, impl, tn, dtype):
    plan = _resolve_plan(plan, dtype)
    impl = _resolve_impl(impl)
    if impl == "xla":
        return kref.flashsketch_transpose_ref(plan, _emulate_stream(plan, Y))
    assert impl in _PALLAS_IMPLS, impl
    Yp = Y
    if Y.shape[0] != plan.k_pad:
        Yp = jnp.pad(Y, ((0, plan.k_pad - Y.shape[0]), (0, 0)))
    impl = _resolve_pallas(impl, plan, Yp.shape[1], "transpose")
    tn = _resolve_tn(tn, plan, Yp.shape[1], "transpose", impl)
    Yp, n = _pad_cols(Yp, tn)
    if impl == "pallas_v1":
        X = fsk.flashsketch_transpose_pallas_v1(plan, _emulate_stream(plan, Yp), tn=tn)
    else:
        X = fsk.flashsketch_transpose_pallas(plan, Yp, tn=tn)
    return X[: plan.d, :n]


def _apply_fwd(plan, A, impl, tn, dtype):
    return _sketch_apply_impl(plan, A, impl, tn, dtype), None


def _apply_bwd(plan, impl, tn, dtype, _res, dY):
    return (_sketch_apply_t_impl(plan, dY, impl, tn, dtype),)


def _apply_t_fwd(plan, Y, impl, tn, dtype):
    return _sketch_apply_t_impl(plan, Y, impl, tn, dtype), None


def _apply_t_bwd(plan, impl, tn, dtype, _res, dX):
    return (_sketch_apply_impl(plan, dX, impl, tn, dtype),)


_sketch_apply_vjp.defvjp(_apply_fwd, _apply_bwd)
_sketch_apply_t_vjp.defvjp(_apply_t_fwd, _apply_t_bwd)


# ---------------------------------------------------------------------------
# Gather-fused apply: Y = S @ A[row_index, :] in one launch.
# ---------------------------------------------------------------------------

def _row_map_for(plan: BlockPermPlan, row_index: jnp.ndarray) -> jnp.ndarray:
    """(d_pad,) int32 source-row map.  Padding entries point at row 0 — a
    placeholder valid source; the gather kernel zeroes the corresponding
    scratch rows itself (rows ≥ ``plan.d``), so A is never copied just to
    host a zero row and padding still contributes exact zeros."""
    ri = jnp.asarray(row_index, jnp.int32).reshape(-1)
    pad = plan.d_pad - ri.shape[0]
    if pad == 0:
        return ri
    return jnp.concatenate([ri, jnp.zeros((pad,), jnp.int32)])


def _apply_gather_path(plan, A, row_index, impl, tn, dtype, *, variant,
                       gather_kernel, oracle, materialized_apply):
    """Shared gather dispatch for the ``row_index=`` forward paths.

    One copy of the protocol — mask-length check, xla oracle, the
    materializing fallback (v1 / VMEM overflow), tile resolution, column
    padding, zero-row append, row-map construction, output slice — so the
    fwd and blockrow gather entries cannot silently diverge.

    Args:
      variant: tuner/VMEM shape-class name (``"fwd_gather"`` /
        ``"blockrow_gather"``).
      gather_kernel: ``fsk.*_pallas_gather(plan, Az, rmap, tn=)``.
      oracle: pure-jnp reference taking the materialized gather.
      materialized_apply: fallback on ``A[row_index]`` when no fused
        gather kernel applies (``pallas_v1``, or the Φ scratch overflows
        VMEM at the smallest tile).
    """
    plan = _resolve_plan(plan, dtype)
    impl = _resolve_impl(impl)
    d_keep = row_index.shape[0]
    if d_keep != plan.d:
        raise ValueError(
            f"row_index has {d_keep} entries but plan.d == {plan.d}; build "
            f"the plan for the masked dim (make_plan(d_keep, k, ...))")
    if impl == "xla":
        return oracle(plan, _emulate_stream(plan, A[row_index]))
    assert impl in _PALLAS_IMPLS, impl
    n = A.shape[1]
    if impl == "pallas_v1" or not tune.fused_fits_vmem(plan, n, variant):
        return materialized_apply(A[row_index], impl)
    if tn is None:
        tn = tune.resolve_tn(plan, n, variant)
    # A is deliberately NOT column-padded here — a ragged last tile is
    # zero-filled inside the gather kernel.  Padding the (d_src, n) HBM
    # operand would materialize a full copy of A, breaking the path's
    # no-A-copy contract (only the small (k, ·) output is tile-padded).
    rmap = _row_map_for(plan, row_index)
    Y = gather_kernel(plan, A, rmap, tn=tn)
    return Y[: plan.k, :n]


def _sketch_apply_indexed_impl(plan, A, row_index, impl, tn, dtype):
    return _apply_gather_path(
        plan, A, row_index, impl, tn, dtype,
        variant="fwd_gather",
        gather_kernel=fsk.flashsketch_pallas_gather,
        oracle=kref.flashsketch_ref,
        materialized_apply=lambda Am, im: _sketch_apply_impl(
            plan, Am, im, tn, dtype),
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 3, 4, 5))
def sketch_apply_indexed(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    row_index: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
):
    """Gather-fused sketch: ``Y = S @ A[row_index, :]`` in ONE launch.

    The sparsify→sketch fusion of the GraSS pipeline: the kernel keeps
    ``A`` in HBM and DMAs only the ``row_index`` rows into its gather
    scratch — no ``A[row_index]`` intermediate is ever written, which
    removes one full read+write of the sparsified matrix per application
    and (batched) turns B per-example gathers into tile-wide streams.

    Args:
      plan: frozen plan for the MASKED dim — ``plan.d`` must equal
        ``len(row_index)``.
      A: ``(d_src, n)`` float array, ``d_src >= 1``; only the indexed rows
        are read (streamed in the effective dtype, see ``dtype``).
      row_index: ``(plan.d,)`` int array of row indices into ``A``.
        Treated as non-differentiable (integer) data.
      impl / tn / dtype: as in ``sketch_apply``.  ``"xla"`` runs the
        materializing oracle ``flashsketch_ref(plan, A[row_index])``;
        ``"pallas_v1"`` (and the VMEM fallback) materialize the gather and
        use the regular kernels.

    Returns:
      ``(k, n)`` fp32 array.  Differentiable in ``A``: the VJP scatters
      ``Sᵀ dY`` into rows ``row_index`` of a zero ``(d_src, n)`` cotangent.
    """
    return _sketch_apply_indexed_impl(plan, A, row_index, impl, tn, dtype)


def _indexed_fwd(plan, A, row_index, impl, tn, dtype):
    out = _sketch_apply_indexed_impl(plan, A, row_index, impl, tn, dtype)
    return out, (row_index, A.shape[0])


def _indexed_bwd(plan, impl, tn, dtype, res, dY):
    row_index, d_src = res
    # the scatter dual is single-sourced in sketch_apply_t(row_index=)
    dA = sketch_apply_t(plan, dY, impl, tn, dtype,
                        row_index=row_index, d_src=d_src)
    return dA, None


sketch_apply_indexed.defvjp(_indexed_fwd, _indexed_bwd)


def blockrow_apply(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    *,
    row_index: Optional[jnp.ndarray] = None,
):
    """FLASHBLOCKROW forward: ``Y = S_blockrow A`` (paper App. C).

    The gather-only appendix variant (iid block wiring, per-row pattern):
    reads A approximately once, but its embedding guarantees are weaker —
    eval-only, and intentionally has NO custom VJP (it never sits inside a
    training graph).

    Args:
      plan: frozen ``BlockPermPlan`` (wiring drawn iid per plan seed).
      A: ``(d, n)`` float array (``(d_src, n)`` with ``row_index``).
      impl: ``"auto" | "pallas" | "pallas_v1" | "xla"`` — same dispatch
        rules as ``sketch_apply``.
      tn / dtype: as in ``sketch_apply`` (bf16 streams A at half the HBM
        traffic, fp32 accumulate).
      row_index: optional ``(plan.d,)`` int rows; computes
        ``S_blockrow @ A[row_index, :]`` with the gather fused in-kernel
        (same contract as ``sketch_apply_indexed``).

    Returns:
      ``(k, n)`` fp32 array.
    """
    if row_index is not None:
        return _apply_gather_path(
            plan, A, row_index, impl, tn, dtype,
            variant="blockrow_gather",
            gather_kernel=fsk.blockrow_pallas_gather,
            oracle=kref.blockrow_ref,
            materialized_apply=lambda Am, im: blockrow_apply(
                plan, Am, im, tn, dtype),
        )
    plan = _resolve_plan(plan, dtype)
    impl = _resolve_impl(impl)
    if impl == "xla":
        return kref.blockrow_ref(plan, _emulate_stream(plan, A))
    assert impl in _PALLAS_IMPLS, impl
    Ap = kref.pad_input(plan, A)
    impl = _resolve_pallas(impl, plan, Ap.shape[1], "blockrow")
    tn = _resolve_tn(tn, plan, Ap.shape[1], "blockrow", impl)
    Ap, n = _pad_cols(Ap, tn)
    if impl == "pallas_v1":
        Y = fsk.blockrow_pallas_v1(plan, _emulate_stream(plan, Ap), tn=tn)
    else:
        Y = fsk.blockrow_pallas(plan, Ap, tn=tn)
    return Y[: plan.k, :n]


def _resolve_batched_tn(plan, impl, dtype, n: int, n_batch: int,
                        row_index) -> Optional[int]:
    """Trace-time tile width for a batch-folded launch (shared by
    ``sketch_apply_batched`` and ``sketch_vectors`` so the two batch entry
    points resolve tiles identically).

    Resolves against the autotuner's BATCHED shape class
    (``tune.resolve_tn(..., batch=n_batch)``) — but only when the launch
    will actually be the fused v2 kernel; v1 dispatch (explicit or the
    VMEM-overflow downgrade) must keep ``tn=None`` so the downstream
    ``_resolve_tn`` applies ``v1_default_tn``, not the v2 heuristic.
    """
    eff_plan = _resolve_plan(plan, dtype)
    variant = "fwd" if row_index is None else "fwd_gather"
    if (_resolve_impl(impl) == "pallas"
            and tune.fused_fits_vmem(eff_plan, n * n_batch, variant)):
        return tune.resolve_tn(eff_plan, n, variant, batch=n_batch)
    return None


def sketch_vectors(plan: BlockPermPlan, x: jnp.ndarray, impl: Impl = "auto",
                   tn: Optional[int] = None, dtype: Optional[str] = None,
                   *, row_index: Optional[jnp.ndarray] = None):
    """Sketch a batch of vectors laid out along the LAST axis.

    Args:
      plan: the frozen sketch draw (``core.blockperm.make_plan``).
      x: ``(..., d)`` float array; leading axes are an arbitrary batch
        (``(..., d_src)`` with ``row_index`` — e.g. a stack of raw
        per-example gradients whose sparsification is fused into the
        sketch).
      impl: one of ``"auto" | "pallas" | "pallas_v1" | "xla"`` (see
        ``sketch_apply``).
      tn / dtype: forwarded to ``sketch_apply``.  ``tn=None`` resolves
        against the autotuner's *batched* shape class exactly as
        ``sketch_apply_batched`` does (each vector is a width-1 matrix,
        the batch is folded into the column axis).
      row_index: optional ``(plan.d,)`` int rows — fused
        ``S x[..., row_index]`` (the GraSS sparsify→sketch fusion).

    Returns:
      ``(..., k)`` array, ``y[..., :] = S x[..., :]``.  Internally the batch
      is flattened into the column axis of one ``sketch_apply`` launch.
    """
    flat = x.reshape(-1, x.shape[-1])                 # (n, d)
    if tn is None:
        tn = _resolve_batched_tn(plan, impl, dtype, 1, flat.shape[0],
                                 row_index)
    Y = sketch_apply(plan, flat.T, impl, tn, dtype,
                     row_index=row_index)             # (k, n)
    return Y.T.reshape(*x.shape[:-1], plan.k)


def sketch_apply_batched(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    *,
    row_index: Optional[jnp.ndarray] = None,
):
    """Apply S to a stack of matrices in ONE kernel launch.

    Args:
      plan: the frozen sketch draw.
      A: ``(..., d, n)`` float array — a batch of tall matrices sharing the
        sketch.  The batch axes are folded into the column axis (``S`` acts
        on the row axis only), so a ``(B, d, n)`` stack costs one launch on
        a ``(d, B·n)`` operand instead of ``B`` launches (or a vmap, which
        would re-trace the Pallas kernel per batch layout).  The cached Φ
        scratch is built once per launch and reused across the whole batch.
      impl / tn / dtype: forwarded to ``sketch_apply`` (same valid values).
        ``tn=None`` resolves against the autotuner's *batched* shape class
        (``tune.resolve_tn(..., batch=B)``), not the per-matrix width.
      row_index: optional ``(plan.d,)`` int rows shared by every batch
        element — fused ``S @ A[b][row_index, :]`` per element, still one
        launch (the GraSS per-example-gradient path).

    Returns:
      ``(..., k, n)`` array with ``out[b] = S @ A[b]`` for every batch
      index ``b``.  Differentiable in ``A`` (inherits the custom VJP of
      ``sketch_apply`` / ``sketch_apply_indexed``).
    """
    if A.ndim < 2:
        raise ValueError(f"A must be at least 2-D (d, n), got shape {A.shape}")
    batch = A.shape[:-2]
    d, n = A.shape[-2:]
    n_batch = 1
    for b in batch:
        n_batch *= b
    if tn is None:
        tn = _resolve_batched_tn(plan, impl, dtype, n, n_batch, row_index)
    flat = jnp.moveaxis(A.reshape((-1, d, n)), 0, 1).reshape(d, -1)  # (d, B·n)
    Y = sketch_apply(plan, flat, impl, tn, dtype, row_index=row_index)
    Y = jnp.moveaxis(Y.reshape(plan.k, -1, n), 1, 0)                 # (k, B·n)
    return Y.reshape(*batch, plan.k, n)


def sketch_qr(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    impl: Impl = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    factorization: str = "qr",
):
    """Sketch-and-factor: ``SA = S A`` plus a triangular factor of ``SA``.

    The workhorse of sketch-and-precondition (Rokhlin–Tygert / Blendenpik
    lineage): for tall ``A (d, n)`` with ``d >> n``, the ``(k, n)`` sketch
    ``SA`` is an approximate isometry on ``range(A)``, so the triangular
    ``R`` with ``SAᵀ SA = Rᵀ R`` makes ``A R⁻¹`` nearly orthonormal — LSQR
    on ``A R⁻¹`` then converges in O(1) iterations regardless of cond(A).

    Args:
      plan: the frozen sketch draw; ``plan.k`` should be a few × n.
      A: ``(d, n)`` float array, ``d >> n``.
      impl / tn / dtype: forwarded to ``sketch_apply`` (``dtype="bfloat16"``
        streams the sketch in bf16; the factorization itself is always fp32).
      factorization: ``"qr"`` (Householder QR of SA — backward stable) or
        ``"chol"`` (Cholesky of ``SAᵀSA`` — cheaper, squares the condition
        number of the sketch; fine when ``SA`` is well-conditioned, which a
        subspace-embedding sketch guarantees).

    Returns:
      ``(SA, R)``: the sketch ``(k, n)`` and upper-triangular ``R (n, n)``
      with ``SAᵀ SA = Rᵀ R`` (up to rounding).  ``R`` may be singular only
      if ``A`` is rank-deficient.
    """
    SA = sketch_apply(plan, A, impl, tn, dtype).astype(jnp.float32)
    return SA, triangular_factor(SA, factorization)


def triangular_factor(SA: jnp.ndarray, factorization: str = "qr") -> jnp.ndarray:
    """Upper-triangular R (n, n) with ``SAᵀ SA = Rᵀ R``, positive diagonal.

    Args:
      SA: ``(k, n)`` fp32 matrix (typically a sketch).
      factorization: ``"qr"`` (Householder QR — backward stable) or
        ``"chol"`` (Cholesky of the Gram — cheaper, squares the condition
        number).  Anything else raises ``ValueError``.

    Returns:
      R with a positive diagonal (fixes the QR/Cholesky sign ambiguity so
      the two factorizations agree and ``R⁻¹`` is well-defined).
    """
    if factorization == "qr":
        R = jnp.linalg.qr(SA, mode="r")
    elif factorization == "chol":
        R = jnp.linalg.cholesky(SA.T @ SA).T  # upper-triangular
    else:
        raise ValueError(
            f"factorization must be 'qr' or 'chol', got {factorization!r}")
    sgn = jnp.sign(jnp.diagonal(R))
    sgn = jnp.where(sgn == 0, 1.0, sgn)
    return R * sgn[:, None]
