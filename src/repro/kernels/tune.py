"""Tile autotuner for the FlashSketch v2 kernels.

Two layers:

  * ``resolve_tn(plan, n, variant)`` — the cheap path used by ``ops``
    dispatch whenever the caller passes ``tn=None``.  Pure python: returns
    the cached tuned width for this shape class if one exists, else a
    VMEM-budget heuristic.  Safe to call at trace time (no timing, no jit).
  * ``autotune(plan, n, ...)`` / ``autotune_plan(d, k, n, ...)`` — the
    active path: times real kernel launches over a sweep of ``tn`` (and,
    for ``autotune_plan``, the ``M``/``B_r`` split via ``block_rows``),
    then populates the cache so subsequent ``resolve_tn`` calls return the
    measured winner.

Cache entries are keyed by the *shape class* ``(backend, variant, d_pad,
k_pad, M, Br, kappa, s, bucket(n), dtype, gather, bucket(batch))`` — ``n``
is bucketed to its next power of two so nearby column counts share a
winner, and the backend tag ("interpret" off-TPU) keeps interpreter
timings from ever being served as compiled-TPU winners.  ``cache_key`` is
the ONE key builder: every consult (``lookup``/``resolve_tn``) and every
write (``autotune``, ``autotune_plan``) routes through it, including the
batched fields — a write under one spelling of a batched shape is
guaranteed visible to every reader.  The cache is a process-global
dict with optional JSON persistence (``save_cache``/``load_cache``) so
benchmark runs can ship winners to serving jobs; ``cache_generation()``
counts mutations so trace-time consumers (the lowering engine's record
cache) can invalidate when new winners land.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import threading
import time
import warnings
from typing import Dict, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockperm import (GATHER_VARIANTS, MIN_TILE_N,
                                  SKETCH_VARIANTS, BlockPermPlan,
                                  VMEM_BUDGET_BYTES, _next_pow2,
                                  fused_variant_bytes, make_plan)
from repro.kernels import flashsketch as fsk

VARIANTS = SKETCH_VARIANTS + GATHER_VARIANTS

_MIN_TN = MIN_TILE_N
_MAX_TN = 1024


@dataclasses.dataclass(frozen=True)
class TuneResult:
    tn: int
    block_rows: Optional[int] = None   # set by autotune_plan sweeps
    time_us: float = float("nan")
    source: str = "heuristic"          # "heuristic" | "tuned" | "loaded"


_CACHE: Dict[Tuple, TuneResult] = {}

# Serializes every _CACHE mutation and keeps _bump_generation atomic with
# the mutation it describes: the serving layer tunes/loads/saves from
# worker threads, and an unguarded save_cache iterating _CACHE while
# autotune inserts a winner dies with "dict changed size during
# iteration".  RLock because load_cache(merge=False) calls clear_cache.
_CACHE_LOCK = threading.RLock()

# Bumped on every cache mutation (tuned win, JSON load, clear) so consumers
# that memoize *derived* trace-time decisions — ``kernels.lowering``'s
# record cache — know when a cached decision may have gone stale.
_GENERATION: int = 0


def cache_generation() -> int:
    """Monotone counter of tuner-cache mutations (see module docstring)."""
    return _GENERATION


def _bump_generation() -> None:
    global _GENERATION
    with _CACHE_LOCK:
        _GENERATION += 1


def _n_bucket(n: int) -> int:
    return _next_pow2(max(1, n))


def _is_better(candidate: TuneResult, incumbent: Optional[TuneResult]) -> bool:
    """Timed results beat untimed (NaN) ones; among timed, lower wins."""
    if incumbent is None:
        return True
    if math.isnan(candidate.time_us):
        return False
    if math.isnan(incumbent.time_us):
        return True
    return candidate.time_us < incumbent.time_us


def _backend_tag(interpret: Optional[bool] = None) -> str:
    """Interpret-mode timings say nothing about compiled-TPU behavior, so
    winners tuned on one backend must never be served to the other."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return "interpret" if interpret else jax.default_backend()


def cache_key(plan: BlockPermPlan, n: int, variant: str,
              interpret: Optional[bool] = None, *, batch: int = 1) -> Tuple:
    """Shape-class key.  Beyond the PR-1 fields it carries the gather/batch
    dims of the fused-batched path: whether the kernel does an in-kernel
    row gather (``*_gather`` variants tile differently — no pipelined input
    blocks, one DMA'd gather scratch) and the bucketed batch count folded
    into the column axis (a B-example batched launch has B·n effective
    columns, which moves the tile-width sweet spot)."""
    return (_backend_tag(interpret), variant, plan.family, plan.d_pad,
            plan.k_pad, plan.M, plan.Br, plan.kappa, plan.s, _n_bucket(n),
            plan.dtype, variant in GATHER_VARIANTS, _n_bucket(batch))


def clear_cache() -> None:
    with _CACHE_LOCK:
        _CACHE.clear()
        _bump_generation()


def cache_size() -> int:
    return len(_CACHE)


def _vmem_footprint(plan: BlockPermPlan, tn: int, variant: str) -> int:
    return fused_variant_bytes(plan.kappa, plan.Br, plan.Bc, tn,
                               plan.stream_itemsize, variant,
                               plan.precision.compute_itemsize)


def fused_fits_vmem(plan: BlockPermPlan, n: int, variant: str = "fwd") -> bool:
    """Whether the v2 fused working set (stacked Φ scratch + pipelined
    blocks) fits the VMEM budget at the smallest tile width.

    The Φ scratch is (Br, κ·Bc) — independent of ``tn`` — so for very large
    d_pad/M the fused kernel cannot fit no matter how the tuner shrinks the
    tile; dispatch falls back to the v1 revisiting kernel in that case.
    """
    return _vmem_footprint(plan, _MIN_TN, variant) <= VMEM_BUDGET_BYTES


def heuristic_tn(plan: BlockPermPlan, n: int, variant: str = "fwd",
                 batch: int = 1, trace: Optional[list] = None) -> int:
    """Largest power-of-two tile width that fits the VMEM budget.

    Prefers ≥128 (TPU lane width) when the problem is wide enough; never
    exceeds the (power-of-two-rounded) effective column count ``n·batch``
    (a batched launch folds the batch into the column axis), so small
    problems are not padded into oblivion.  ``trace`` (a list, appended in
    place) records every rejected candidate width for ``lowering.explain``.
    """
    cap = min(_MAX_TN, _n_bucket(n * max(1, batch)))
    tn = max(_MIN_TN, cap)
    while tn > _MIN_TN and _vmem_footprint(plan, tn, variant) > VMEM_BUDGET_BYTES:
        if trace is not None:
            trace.append(
                f"tn={tn} rejected: {variant!r} working set "
                f"{_vmem_footprint(plan, tn, variant)} B > VMEM budget "
                f"{VMEM_BUDGET_BYTES} B")
        tn //= 2
    return tn


def lookup(plan: BlockPermPlan, n: int, variant: str = "fwd",
           batch: int = 1,
           interpret: Optional[bool] = None) -> Optional[TuneResult]:
    """The ONE cache consult: the tuned/loaded winner for this shape class,
    or ``None``.  Every reader (``resolve_tn``, the lowering engine) and
    every writer (``autotune``/``autotune_plan``) shares ``cache_key``, so
    a batched write is never invisible to a batched read."""
    with _CACHE_LOCK:
        return _CACHE.get(cache_key(plan, n, variant, interpret, batch=batch))


def resolve_tn(plan: BlockPermPlan, n: int, variant: str = "fwd",
               batch: int = 1) -> int:
    """Cache-or-heuristic tile width (the dispatch path, no timing)."""
    hit = lookup(plan, n, variant, batch=batch)
    if hit is not None:
        return hit.tn
    return heuristic_tn(plan, n, variant, batch)


def v1_default_tn(plan: BlockPermPlan, n: int) -> int:
    """Tile width for the v1 revisiting kernel (always fp32).

    v1's per-program working set is one double-buffered block pair plus the
    materialized Φ tile (Br, Bc); for the huge-Bc plans that trigger the
    v2→v1 fallback the tile width must shrink accordingly.  If the Φ tile
    alone busts the budget, the minimum tile is returned — that matches the
    seed kernel's (pre-existing) capability ceiling."""
    tn = min(128, _n_bucket(n))
    fixed = 4 * plan.Br * plan.Bc                       # Φ tile, fp32
    while tn > _MIN_TN and fixed + 8 * (plan.Bc + plan.Br) * tn > VMEM_BUDGET_BYTES:
        tn //= 2
    return tn


# ---------------------------------------------------------------------------
# Active tuning
# ---------------------------------------------------------------------------

def _with_identity_row_map(kernel):
    """Adapt a gather kernel to the (plan, X, tn, interpret) timing shape:
    tuning uses the identity row map (the gather cost is index-independent;
    only the DMA count and tile shapes matter)."""
    def run(plan, X, *, tn, interpret=None):
        rmap = jnp.arange(plan.d_pad, dtype=jnp.int32)
        return kernel(plan, X, rmap, tn=tn, interpret=interpret)
    return run


_KERNELS = {
    "fwd": fsk.flashsketch_pallas,
    "transpose": fsk.flashsketch_transpose_pallas,
    "blockrow": fsk.blockrow_pallas,
    "fwd_gather": _with_identity_row_map(fsk.flashsketch_pallas_gather),
    "blockrow_gather": _with_identity_row_map(fsk.blockrow_pallas_gather),
}


def _candidate_tns(plan: BlockPermPlan, n: int, variant: str,
                   batch: int = 1) -> Tuple[int, ...]:
    cap = min(_MAX_TN, _n_bucket(n * max(1, batch)))
    tns = []
    tn = _MIN_TN
    while tn <= cap:
        if _vmem_footprint(plan, tn, variant) <= VMEM_BUDGET_BYTES:
            tns.append(tn)
        tn *= 2
    return tuple(tns) or (_MIN_TN,)


def _time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time in microseconds of a blocking call."""
    for _ in range(warmup):
        fn(*args).block_until_ready()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        times.append(time.perf_counter() - t0)
    return 1e6 * float(np.median(times))


def _make_operand(plan: BlockPermPlan, n_pad: int, variant: str) -> jnp.ndarray:
    rows = plan.k_pad if variant == "transpose" else plan.d_pad
    # Deterministic pseudo-data: tuning only measures time, not quality.
    x = np.linspace(-1.0, 1.0, num=rows * n_pad, dtype=np.float32)
    return jnp.asarray(x.reshape(rows, n_pad))


def autotune(
    plan: BlockPermPlan,
    n: int,
    variant: str = "fwd",
    *,
    batch: int = 1,
    tns: Optional[Sequence[int]] = None,
    warmup: int = 1,
    iters: int = 3,
    interpret: Optional[bool] = None,
) -> TuneResult:
    """Time the v2 kernel over a ``tn`` sweep and cache the winner.

    ``batch`` is the batched-apply fold factor: a B-stack sketched in one
    launch runs on ``B·n`` effective columns, so it is timed (and keyed)
    that way rather than at the per-matrix width.
    """
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}, got {variant!r}")
    key = cache_key(plan, n, variant, interpret, batch=batch)
    with _CACHE_LOCK:
        hit = _CACHE.get(key)
    if hit is not None and hit.source in ("tuned", "loaded"):
        return hit
    kernel = _KERNELS[variant]
    n_eff = n * max(1, batch)
    best: Optional[TuneResult] = None
    last_error: Optional[Exception] = None
    for tn in (tns or _candidate_tns(plan, n, variant, batch)):
        n_pad = ((n_eff + tn - 1) // tn) * tn
        operand = _make_operand(plan, n_pad, variant)
        fn = jax.jit(lambda x, _tn=tn: kernel(plan, x, tn=_tn, interpret=interpret))
        try:
            us = _time_call(fn, operand, warmup=warmup, iters=iters)
        except Exception as e:  # a failed candidate only narrows the sweep
            last_error = e
            continue
        cand = TuneResult(tn=tn, time_us=us, source="tuned")
        if _is_better(cand, best):
            best = cand
    if best is None:
        # every candidate failed — that is a bug signal, not a tuning result
        warnings.warn(
            f"autotune: all tn candidates failed for {plan.describe()} "
            f"variant={variant!r}; falling back to heuristic "
            f"(last error: {last_error!r})")
        best = TuneResult(tn=heuristic_tn(plan, n, variant, batch),
                          source="heuristic")
    with _CACHE_LOCK:
        _CACHE[key] = best
        _bump_generation()
    return best


def autotune_plan(
    d: int,
    k: int,
    n: int,
    *,
    kappa: int = 4,
    s: int = 2,
    seed: int = 0,
    dtype: str = "float32",
    variant: str = "fwd",
    batch: int = 1,
    block_rows_candidates: Optional[Iterable[int]] = None,
    tns: Optional[Sequence[int]] = None,
    warmup: int = 1,
    iters: int = 3,
) -> Tuple[BlockPermPlan, TuneResult]:
    """Sweep the ``M``/``B_r`` split *and* ``tn``; return the fastest pair.

    The ``B_r`` sweep changes the padded shapes, so the returned plan must be
    used in place of a ``make_plan`` default for the win to apply.

    Only candidates with the SAME effective ``k_pad`` as the default plan
    are timed: a pin that inflates ``k_pad`` (e.g. ``B_r·2`` when M is
    already at the κ floor) would sketch a different statistical object —
    more rows, different embedding — and raw launch time cannot rank it
    against the requested-size plans.  Such candidates are skipped, as are
    duplicates of an already-timed effective ``(M, B_r)`` grid.

    ``batch`` is the batched-apply fold factor, forwarded to ``autotune``
    and — crucially — to the winner's ``cache_key``, so a batched sweep's
    winner is served back to batched ``resolve_tn``/``lookup`` consults
    (one key builder for writers and readers; regression-tested).
    """
    base = make_plan(d, k, kappa=kappa, s=s, seed=seed, dtype=dtype)
    if block_rows_candidates is None:
        block_rows_candidates = sorted(
            {br for br in (base.Br // 2, base.Br, base.Br * 2)
             if br >= max(s, 1) and br % max(s, 1) == 0}
        )
    best_plan: Optional[BlockPermPlan] = None
    best: Optional[TuneResult] = None
    seen_grids: set = set()
    for br in block_rows_candidates:
        try:
            plan = make_plan(d, k, kappa=kappa, s=s, seed=seed,
                             block_rows=br, dtype=dtype)
        except ValueError:
            continue
        # Dedupe by the EFFECTIVE grid: two pins that resolve to the same
        # (M, Br) would time the identical kernel twice.  Skip candidates
        # whose k_pad differs from the default plan's — not comparable.
        if plan.k_pad != base.k_pad or (plan.M, plan.Br) in seen_grids:
            continue
        seen_grids.add((plan.M, plan.Br))
        res = autotune(plan, n, variant, batch=batch, tns=tns, warmup=warmup,
                       iters=iters)
        if _is_better(res, best):
            best_plan, best = plan, dataclasses.replace(res, block_rows=plan.Br)
    if best_plan is None or best is None:
        best_plan = make_plan(d, k, kappa=kappa, s=s, seed=seed, dtype=dtype)
        best = TuneResult(tn=resolve_tn(best_plan, n, variant, batch),
                          block_rows=best_plan.Br, source="heuristic")
    # the winner's key MUST be built by the same cache_key spelling that
    # resolve_tn/lookup consult — including the batched fields (a batched
    # sweep cached under a batch-less key would never be served again)
    with _CACHE_LOCK:
        _CACHE[cache_key(best_plan, n, variant, batch=batch)] = best
        _bump_generation()
    return best_plan, best


# ---------------------------------------------------------------------------
# Persistence (JSON; keys serialized as strings)
# ---------------------------------------------------------------------------

def save_cache(path: str) -> int:
    """Persist the cache to ``path`` ATOMICALLY (tmp file + rename).

    A crash mid-write must never leave a half-written JSON where the next
    process expects a cache — ``os.replace`` makes the new file appear all
    at once (same-filesystem rename is atomic on POSIX), so readers only
    ever see the old complete file or the new complete file.
    """
    def _row(v: TuneResult) -> Dict:
        d = dataclasses.asdict(v)
        # NaN is not valid JSON — untimed entries serialize time_us as null.
        if not math.isfinite(v.time_us):
            d["time_us"] = None
        return d

    with _CACHE_LOCK:       # snapshot: a concurrent tuned win must not
        snap = list(_CACHE.items())   # resize the dict mid-iteration
    payload = {json.dumps(list(k)): _row(v) for k, v in snap}
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, allow_nan=False)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return len(payload)


def load_cache(path: str, *, merge: bool = True) -> int:
    """Load tuned winners from ``path``; returns the number of entries kept.

    Hardened against corruption: a truncated / garbage / malformed cache
    file warns and falls back to the heuristic (returns 0 or skips the bad
    rows) instead of raising — a stale or damaged cache must never take
    down a job whose correctness does not depend on it (the tile heuristic
    is always available).  Every skipped file/row is counted under
    ``tune.cache_corrupt`` in the health registry.
    """
    from repro.health import report as health_report
    try:
        with open(path) as f:
            payload = json.load(f)
        if not isinstance(payload, dict):
            raise ValueError(f"expected a JSON object, got "
                             f"{type(payload).__name__}")
    except (json.JSONDecodeError, ValueError, OSError, UnicodeDecodeError) as e:
        health_report.record("tune.cache_corrupt", detail=f"{path}: {e}")
        warnings.warn(
            f"tuner cache {path!r} is unreadable ({e}); ignoring it — tile "
            f"selection falls back to the VMEM heuristic", RuntimeWarning,
            stacklevel=2)
        return 0
    kept = 0
    bad = 0
    with _CACHE_LOCK:   # replace-or-merge lands atomically w.r.t. readers
        if not merge:
            clear_cache()
        for ks, vd in payload.items():
            try:
                key = tuple(json.loads(ks))
                t = vd.get("time_us")
                row = TuneResult(
                    tn=int(vd["tn"]),
                    block_rows=vd.get("block_rows"),
                    time_us=float(t) if t is not None else float("nan"),
                    source="loaded",
                )
            except (json.JSONDecodeError, ValueError, TypeError, KeyError,
                    AttributeError) as e:
                bad += 1
                health_report.record("tune.cache_corrupt",
                                     detail=f"{path} entry {ks!r}: {e}")
                continue
            _CACHE[key] = row
            kept += 1
        if kept:
            _bump_generation()
    if bad:
        warnings.warn(
            f"tuner cache {path!r}: skipped {bad} malformed entr"
            f"{'y' if bad == 1 else 'ies'} (kept {kept})", RuntimeWarning,
            stacklevel=2)
    return kept
