"""FLASHSKETCH v2 Pallas/TPU kernel suite (paper §5, adapted per DESIGN.md §2).

v2 (default) — fused-κ single-write formulation:

  * Grid ``(M, n/T_n)`` with the column-tile axis ``j`` **innermost**.
    Program ``(g, j)`` owns output tile ``Y[g·B_r:(g+1)B_r, j·T_n:(j+1)T_n]``
    and receives all κ gathered input blocks ``A[π_ℓ(g)·B_c:…, j·T_n:…]``
    for ℓ = 1..κ via κ block-pipelined views of the same operand.
  * The κ reduction happens **inside** the kernel: the stacked tile
    ``[Φ_{g,π₁(g)} | … | Φ_{g,π_κ(g)}] ∈ (B_r, κ·B_c)`` is contracted
    against the stacked input ``(κ·B_c, T_n)`` in a single MXU dot,
    producing exactly **one** output write per tile — no κ grid revisits,
    no output read-modify-writes.
  * The stacked Φ lives in VMEM scratch and depends only on ``g`` — it is
    rebuilt only at ``j == 0`` and reused across all n/T_n column tiles,
    amortizing the s hash passes (VPU work) by a factor of n/T_n.
  * Mixed precision: the plan's ``Precision`` policy (core.precision)
    decides the streaming cast — bf16 halves, fp8 quarters the HBM
    stream of A; the ``*_sr`` fp8 policies apply seeded stochastic
    rounding at the cast (``_stream``).  In-kernel, fp8 tiles upcast to
    bf16 (exact) for the MXU and Φ is held in the compute dtype (entries
    ±1/0 are exact in every policy), while the MXU accumulates in fp32
    (``preferred_element_type``).  This shrinks the dominant HBM term in
    the paper's d ≫ k regime.

v2-gather (``*_gather``) — the same fused-κ formulation with the input row
gather folded INTO the kernel: the operand stays in HBM (``pltpu.ANY``)
and an arbitrary per-row index map (e.g. the GraSS sparsify mask) is
scalar-prefetched; each program DMAs its κ·B_c masked rows straight into a
VMEM gather scratch and contracts the cached stacked Φ against it.
``S @ A[mask, :]`` in one launch — no ``A[mask]`` intermediate ever touches
HBM.  The contraction shape and operand values are identical to the
non-gather v2 kernel fed a materialized gather, so the two are bit-exact.

v1 — the original output-revisiting grid reduction, grid ``(n/T_n, M, κ)``
with κ as an arbitrary-order reduction axis and Φ rebuilt for every
``(j, g, ℓ)`` program.  Kept as a reference oracle for equivalence tests
and as the perf baseline for ``benchmarks/kernel_bench.py``.

Both paths build Φ from counter-based hashes bit-identical to ``ref.py`` /
``core.blockperm.dense_block``; the block wiring arrives as a scalar-prefetch
table so BlockSpec index_maps do data-dependent block gathering (the
Pallas-idiomatic realization of the paper's App. D on-the-fly wiring).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing
from repro.core import precision as precision_mod
from repro.core.blockperm import GLOBAL_FAMILY_TAG, BlockPermPlan
from repro.kernels import ref as kref


# ---------------------------------------------------------------------------
# Static wiring tables:  π_ℓ(g) = A_ℓ·g + B_ℓ (mod M)  for ℓ = 1..κ,
# plus the inverse maps for the transpose kernel.
# ---------------------------------------------------------------------------

def _wiring_tables(plan: BlockPermPlan) -> Tuple[np.ndarray, np.ndarray]:
    A_tab = np.empty(plan.kappa, np.int32)
    B_tab = np.empty(plan.kappa, np.int32)
    a_l, b_l = 1, 0
    for ell in range(plan.kappa):
        # f^{ell+1} = f ∘ f^{ell}:  a_{l+1} = a·a_l, b_{l+1} = a·b_l + b.
        a_l = (plan.a * a_l) % plan.M
        b_l = (plan.a * b_l + plan.b) % plan.M
        A_tab[ell], B_tab[ell] = a_l, b_l
    return A_tab, B_tab


def _inverse_wiring_tables(plan: BlockPermPlan) -> Tuple[np.ndarray, np.ndarray]:
    A_tab, B_tab = _wiring_tables(plan)
    Ai = np.empty_like(A_tab)
    Bi = np.empty_like(B_tab)
    for ell in range(plan.kappa):
        a_inv = pow(int(A_tab[ell]), -1, plan.M) if plan.M > 1 else 0
        Ai[ell] = a_inv % plan.M
        Bi[ell] = (-a_inv * int(B_tab[ell])) % plan.M
    return Ai, Bi


def _fwd_neighbor_table(plan: BlockPermPlan) -> np.ndarray:
    """(κ, M) table: h = π_{ℓ+1}(g)."""
    A_tab, B_tab = _wiring_tables(plan)
    g = np.arange(plan.M, dtype=np.int64)
    return np.stack(
        [(A_tab[l] * g + B_tab[l]) % plan.M for l in range(plan.kappa)]
    ).astype(np.int32)


def _inv_neighbor_table(plan: BlockPermPlan) -> np.ndarray:
    """(κ, M) table: g = π_{ℓ+1}^{-1}(h)."""
    Ai, Bi = _inverse_wiring_tables(plan)
    h = np.arange(plan.M, dtype=np.int64)
    return np.stack(
        [(int(Ai[l]) * h + int(Bi[l])) % plan.M for l in range(plan.kappa)]
    ).astype(np.int32)


@functools.lru_cache(maxsize=None)
def _blockrow_table(plan: BlockPermPlan) -> np.ndarray:
    """(κ, M) iid wiring, forced to concrete numpy so the wrappers stay
    jittable (the table depends only on the static plan)."""
    with jax.ensure_compile_time_eval():
        return np.asarray(kref.blockrow_wiring(plan))


@functools.lru_cache(maxsize=None)
def _global_table(M: int) -> np.ndarray:
    """(M, M) all-blocks wiring for the GLOBAL families (κ == M):
    ``tab[ℓ, ·] = ℓ`` — every input block feeds every output block.  The
    SAME table serves the forward (program g pipelines input block ℓ) and
    the transpose (program hb pipelines output block g = ℓ): both
    directions of the complete bipartite wiring enumerate all M blocks."""
    return np.tile(np.arange(M, dtype=np.int32)[:, None], (1, M))


def _fwd_phi_and_table(plan: BlockPermPlan):
    """(phi_fn, prefetch table) for the forward/gather launch."""
    if plan.is_global:
        return _phi_global_tile, _global_table(plan.M)
    return _phi_tile, _fwd_neighbor_table(plan)


def _transpose_phi_and_table(plan: BlockPermPlan):
    """(phi_fn, prefetch table) for the transpose launch."""
    if plan.is_global:
        return _phi_global_tile, _global_table(plan.M)
    return _phi_tile, _inv_neighbor_table(plan)


# ---------------------------------------------------------------------------
# In-kernel Φ construction (must match ref._phi_all_blocks bit-for-bit).
# ---------------------------------------------------------------------------

def _phi_tile(plan: BlockPermPlan, g, h) -> jnp.ndarray:
    """Φ_{g,h} ∈ (Br, Bc), entries ±1/0, built from hashes. g,h traced scalars."""
    u = jax.lax.broadcasted_iota(jnp.int32, (1, plan.Bc), 1)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (plan.Br, plan.Bc), 0)
    chunk = plan.chunk
    phi = jnp.zeros((plan.Br, plan.Bc), jnp.float32)
    for i in range(plan.s):
        hsh = hashing.hash_words(
            np.uint32(plan.seed),
            g.astype(jnp.uint32),
            h.astype(jnp.uint32),
            u.astype(jnp.uint32),
            np.uint32(i),
        )                                              # (1, Bc)
        rows = i * chunk + hashing.hash_mod(hsh, chunk)
        signs = hashing.hash_to_unit_sign(hsh)
        phi = phi + jnp.where(r_iota == rows, signs, 0.0)
    return phi


def _phi_global_tile(plan: BlockPermPlan, g, h) -> jnp.ndarray:
    """Block (g, h) of a GLOBAL family's S (countsketch/graph), entries
    ±1/0.  Nonzero i of global column ``h·Bc + u`` lands at GLOBAL row
    ``i·(k_pad/s) + hash mod (k_pad/s)``; rows outside block g never match
    the local row iota, so the masking is free.  Matches
    ``core.blockperm.dense_global_block`` bit-for-bit."""
    u = jax.lax.broadcasted_iota(jnp.int32, (1, plan.Bc), 1)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (plan.Br, plan.Bc), 0)
    chunk = plan.chunk                      # k_pad // s (global partition)
    gcol = h * plan.Bc + u                  # global column indices
    phi = jnp.zeros((plan.Br, plan.Bc), jnp.float32)
    for i in range(plan.s):
        hsh = hashing.hash_words(
            np.uint32(plan.seed),
            np.uint32(GLOBAL_FAMILY_TAG),
            gcol.astype(jnp.uint32),
            np.uint32(i),
        )                                              # (1, Bc)
        rows = i * chunk + hashing.hash_mod(hsh, chunk)
        local = rows - g * plan.Br
        signs = hashing.hash_to_unit_sign(hsh)
        phi = phi + jnp.where(r_iota == local, signs, 0.0)
    return phi


def _phi_rows_tile(plan: BlockPermPlan, g, h) -> jnp.ndarray:
    """FLASHBLOCKROW pattern: s ±1 entries per *row*. Matches ref._phi_rows_all_blocks."""
    r = jax.lax.broadcasted_iota(jnp.int32, (plan.Br, 1), 0)
    c_iota = jax.lax.broadcasted_iota(jnp.int32, (plan.Br, plan.Bc), 1)
    phi = jnp.zeros((plan.Br, plan.Bc), jnp.float32)
    for t in range(plan.s):
        hsh = hashing.hash_words(
            np.uint32(plan.seed),
            np.uint32(0x5EED),
            g.astype(jnp.uint32),
            h.astype(jnp.uint32),
            r.astype(jnp.uint32),
            np.uint32(t),
        )                                              # (Br, 1)
        cols = hashing.hash_mod(hsh, plan.Bc)
        signs = hashing.hash_to_unit_sign(hsh)
        phi = phi + jnp.where(c_iota == cols, signs, 0.0)
    return phi


def stacked_phi(plan: BlockPermPlan, g, neighbors, *, rows_pattern: bool = False):
    """The fused tile [Φ_{g,h₁} | … | Φ_{g,h_κ}] ∈ (Br, κ·Bc).

    Exactly the construction the v2 kernel writes into VMEM scratch at
    ``j == 0`` (exposed for bit-exactness tests against ``dense_block`` /
    ``dense_global_block`` — the family picks the tile builder).
    """
    if rows_pattern:
        tile_fn = _phi_rows_tile
    else:
        tile_fn = _phi_global_tile if plan.is_global else _phi_tile
    g = jnp.asarray(g, jnp.int32)
    return jnp.concatenate(
        [tile_fn(plan, g, jnp.asarray(h, jnp.int32)) for h in neighbors], axis=1
    )


# ---------------------------------------------------------------------------
# v2 kernel bodies: fused-κ, single output write, Φ cached across j.
# ---------------------------------------------------------------------------

def _fused_fwd_kernel(tab_ref, *refs, plan: BlockPermPlan, scale, phi_fn):
    a_refs = refs[: plan.kappa]
    o_ref = refs[plan.kappa]
    phi_ref = refs[plan.kappa + 1]          # (Br, κ·Bc) VMEM scratch
    g = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _build_phi():
        for ell in range(plan.kappa):
            h = tab_ref[ell, g]
            phi_ref[:, ell * plan.Bc:(ell + 1) * plan.Bc] = (
                phi_fn(plan, g, h).astype(phi_ref.dtype)
            )

    stacked = jnp.concatenate(
        [a_refs[ell][...] for ell in range(plan.kappa)], axis=0
    ).astype(phi_ref.dtype)    # (κ·Bc, tn): streamed dtype → MXU compute
                               # dtype (no-op for fp32/bf16; fp8 upcasts
                               # to bf16 — exact — inside VMEM)
    o_ref[...] = jnp.dot(
        phi_ref[...], stacked, preferred_element_type=jnp.float32
    ) * scale


def _fused_transpose_kernel(tab_ref, *refs, plan: BlockPermPlan, scale,
                            phi_fn):
    y_refs = refs[: plan.kappa]
    o_ref = refs[plan.kappa]
    phi_ref = refs[plan.kappa + 1]          # (κ·Br, Bc) VMEM scratch
    hb = pl.program_id(0)                   # input-block index (output of Sᵀ)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _build_phi():
        for ell in range(plan.kappa):
            g = tab_ref[ell, hb]            # g = π_{ℓ+1}^{-1}(hb)
            phi_ref[ell * plan.Br:(ell + 1) * plan.Br, :] = (
                phi_fn(plan, g, hb).astype(phi_ref.dtype)
            )

    stacked = jnp.concatenate(
        [y_refs[ell][...] for ell in range(plan.kappa)], axis=0
    ).astype(phi_ref.dtype)                  # (κ·Br, tn), MXU compute dtype
    o_ref[...] = jnp.dot(
        phi_ref[...].T, stacked, preferred_element_type=jnp.float32
    ) * scale


def _fused_gather_kernel(tab_ref, rmap_ref, a_any, o_ref, gat_ref, phi_ref,
                         sem, *, plan: BlockPermPlan, scale, phi_fn, tn: int,
                         n_rem: int = 0):
    """Gather-fused fwd/blockrow body: Y[g, j] = Φ* · A[rmap[blocks], j·tn:].

    The operand ``a_any`` is the FULL source matrix left in HBM
    (``memory_space=ANY``); ``rmap_ref`` is the scalar-prefetched per-row
    index map of the *masked* input (length d_pad, padding entries pointing
    at a caller-appended zero row).  Each program DMAs its κ·B_c gathered
    rows into ``gat_ref`` (VMEM) row by row — the TPU analogue of the
    coalesced index-streamed gather — then reuses the v2 single-write
    contraction against the Φ scratch cached across column tiles.

    ``n_rem`` is the ragged column remainder ``n % tn`` of the UNPADDED
    source: when nonzero, the last column tile DMAs only the ``n_rem``
    valid columns per row (the source is never padded — padding A would
    materialize a full HBM copy, exactly what this path exists to avoid)
    and zero-fills the scratch tail so the contraction still sees a full
    (κ·B_c, tn) tile.
    """
    g = pl.program_id(0)
    j = pl.program_id(1)
    last_j = pl.num_programs(1) - 1

    @pl.when(j == 0)
    def _build_phi():
        for ell in range(plan.kappa):
            h = tab_ref[ell, g]
            phi_ref[:, ell * plan.Bc:(ell + 1) * plan.Bc] = (
                phi_fn(plan, g, h).astype(phi_ref.dtype)
            )

    def _row_dma(ell, h, r, width):
        src = rmap_ref[h * plan.Bc + r]
        return pltpu.make_async_copy(
            a_any.at[src, pl.ds(j * tn, width)],
            gat_ref.at[ell * plan.Bc + r, pl.ds(0, width)],
            sem,
        )

    def _gather_rows(width):
        # Issue every row copy before waiting on any: the destinations are
        # disjoint scratch rows and the DMA semaphore counts completions, so
        # up to κ·B_c transfers are in flight at once instead of paying κ·B_c
        # serialized HBM round-trips per program.
        for ell in range(plan.kappa):
            h = tab_ref[ell, g]
            jax.lax.fori_loop(
                0, plan.Bc,
                lambda r, _, _ell=ell, _h=h: (
                    _row_dma(_ell, _h, r, width).start(), 0)[1],
                0)
        for ell in range(plan.kappa):
            h = tab_ref[ell, g]
            jax.lax.fori_loop(
                0, plan.Bc,
                lambda r, _, _ell=ell, _h=h: (
                    _row_dma(_ell, _h, r, width).wait(), 0)[1],
                0)

    if n_rem:
        if a_any.shape[1] >= tn:
            # only trace the full-width branch when full tiles exist — a
            # tn-wide slice of a narrower-than-tn operand is invalid even
            # inside a never-taken pl.when
            @pl.when(j != last_j)
            def _full_tile():
                _gather_rows(tn)

        @pl.when(j == last_j)
        def _ragged_tile():
            _gather_rows(n_rem)
            # scratch persists across grid steps: columns ≥ n_rem hold the
            # previous tile's data and must be zeroed, making the ragged
            # tail bit-identical to a zero-padded materialized gather
            gat_ref[:, n_rem:] = jnp.zeros_like(gat_ref[:, n_rem:])
    else:
        _gather_rows(tn)

    if plan.d < plan.d_pad:
        # Padded masked rows (global index ≥ plan.d) gathered a placeholder
        # source row; zero them here so padding contributes exact zeros —
        # bit-identical to zero-padding a materialized gather, without ever
        # copying A to append a zero row.
        for ell in range(plan.kappa):
            h = tab_ref[ell, g]
            rows = h * plan.Bc + jax.lax.broadcasted_iota(
                jnp.int32, (plan.Bc, 1), 0)
            blk = gat_ref[ell * plan.Bc:(ell + 1) * plan.Bc, :]
            gat_ref[ell * plan.Bc:(ell + 1) * plan.Bc, :] = jnp.where(
                rows < plan.d, blk, jnp.zeros_like(blk))

    o_ref[...] = jnp.dot(
        phi_ref[...], gat_ref[...].astype(phi_ref.dtype),
        preferred_element_type=jnp.float32,
    ) * scale


def _partial_fwd_kernel(tab_ref, a_ref, o_ref, phi_ref, *,
                        plan: BlockPermPlan, phi_fn):
    """Per-ℓ COMPACT partial sketch over an owned contiguous block slab.

    The multi-device building block (``repro.distributed``): a device that
    owns input blocks ``[lo, lo + M_loc)`` of a row-sharded A computes, for
    every owned pair, the UNSCALED contribution ``Φ_{g,h} · A_h``.  The
    wiring π_ℓ is a permutation, so each owned input block ``h`` feeds
    exactly ONE output block ``g = π_ℓ⁻¹(h)`` per level — the grid is
    ``(M_loc, κ, n/tn)`` over owned pairs ONLY (per-chip MXU, HBM-input
    and Φ-build work all shard 1/P; this is what
    ``roofline.sketch_model.dist_sketch_cost`` charges), and the caller
    scatters the compact ``(κ, M_loc·B_r, n)`` result into the zero-padded
    global ``(κ, k_pad, n)`` layout.  The per-ℓ slices stay separate so
    the cross-device ``psum`` adds exactly one nonzero contributor per
    element (block ownership is a partition) — an EXACT fp32 reduction —
    and the κ-fold happens after, in the reference oracle's summation
    order.

    ``j`` innermost; the (B_r, B_c) Φ tile is cached in VMEM scratch
    across the column tiles (rebuilt at ``j == 0``).  Each output tile is
    written exactly once (v2's single-write property).

    ``tab_ref`` is the (2, κ, M_loc) prefetch table
    ``[global output block g, global input block h]`` — both GLOBAL block
    ids feed the Φ hashes, which is what makes the partials globally
    consistent; the input gather is just the local slab position ``m``.
    """
    m = pl.program_id(0)
    ell = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _build_phi():
        g = tab_ref[0, ell, m]
        h = tab_ref[1, ell, m]
        phi_ref[...] = phi_fn(plan, g, h).astype(phi_ref.dtype)

    o_ref[0] = jnp.dot(
        phi_ref[...], a_ref[...].astype(phi_ref.dtype),
        preferred_element_type=jnp.float32,
    )


def _partial_masked_kernel(tab_ref, a_ref, o_ref, phi_ref, *,
                           plan: BlockPermPlan, phi_fn):
    """Ownership-MASKED per-ℓ partial over a block slab (full (M, κ, n/tn)
    grid, Φ zeroed for non-owned pairs).

    Kept for the FLASHBLOCKROW wiring, which is iid (NOT a permutation):
    an owned input block may feed zero or several output blocks per level,
    so there is no compact owned-pair grid.  Appendix-variant / eval-only
    — the per-chip work does not shard (every device walks the full grid).

    ``tab_ref`` is the (3, κ, M) prefetch table
    ``[local gather index, global h (hash input), owned flag]``.
    """
    g = pl.program_id(0)
    ell = pl.program_id(1)
    j = pl.program_id(2)
    owned = tab_ref[2, ell, g]

    @pl.when((j == 0) & (owned == 1))
    def _build_phi():
        h = tab_ref[1, ell, g]
        phi_ref[...] = phi_fn(plan, g, h).astype(phi_ref.dtype)

    @pl.when((j == 0) & (owned == 0))
    def _zero_phi():
        # non-owned pairs still skip the s hash passes
        phi_ref[...] = jnp.zeros_like(phi_ref)

    o_ref[0] = jnp.dot(
        phi_ref[...], a_ref[...].astype(phi_ref.dtype),
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# pallas_call wrappers (raw; user-facing API with padding/custom_vjp in ops.py)
# ---------------------------------------------------------------------------

def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _compiler_params(interpret: bool, semantics):
    if interpret:
        return None
    try:
        return pltpu.CompilerParams(dimension_semantics=semantics)
    except AttributeError:  # older jax spelling
        return pltpu.TPUCompilerParams(dimension_semantics=semantics)


def _run_v1(plan, kernel, tab, operand, in_block, out_block, out_rows, n, tn,
            interpret):
    """v1 launcher.  ``n`` may be ragged (``n % tn != 0``): the grid covers
    ⌈n/tn⌉ column tiles and the edge tile is handled by the Pallas
    machinery itself (masked loads/stores on TPU, internal pad+slice in
    interpret mode).  The contraction is column-local, so edge-tile
    garbage never leaks into valid columns — the operand is NEVER padded
    at trace level (no HBM copy of A just to round n up)."""
    grid = (-(-n // tn), plan.M, plan.kappa)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec(in_block, lambda j, g, l, tab_ref: (tab_ref[l, g], j)),
        ],
        out_specs=pl.BlockSpec(out_block, lambda j, g, l, tab_ref: (g, j)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((out_rows, n), jnp.float32),
        interpret=interpret,
        compiler_params=_compiler_params(
            interpret, ("parallel", "parallel", "arbitrary")
        ),
    )(jnp.asarray(tab), operand)


def _run_fused(plan, kernel, tab, operand, in_block, out_block, phi_shape,
               out_rows, n, tn, interpret):
    """v2 launcher: grid (M, n/tn), κ pipelined views of one operand, Φ scratch.

    The same operand is passed κ times — each view has its own BlockSpec whose
    index_map picks input block ``tab[ℓ, ·]``, so the pipeline prefetches all
    κ gathered blocks for program (g, j) without any HBM-side gather copy.

    ``n`` may be ragged (``n % tn != 0``): the grid covers ⌈n/tn⌉ column
    tiles and the edge tile rides the Pallas machinery (masked loads/stores
    on TPU, internal pad+slice in interpret mode).  Output columns of the
    edge tile beyond ``n`` are garbage but are dropped by the machinery;
    the contraction is column-local so valid columns are untouched.  The
    operand is NEVER column-padded at trace level.
    """
    grid = (plan.M, -(-n // tn))
    # Φ scratch lives in the MXU compute dtype: identical to the streamed
    # dtype for fp32/bf16, bf16 for the fp8 policies (whose operand tiles
    # are upcast to it in-kernel; ±1/0 entries are exact either way).
    cdt = plan.precision.compute_dtype

    def _gather_map(ell):
        return lambda g, j, tab_ref: (tab_ref[ell, g], j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec(in_block, _gather_map(ell)) for ell in range(plan.kappa)
        ],
        out_specs=pl.BlockSpec(out_block, lambda g, j, tab_ref: (g, j)),
        scratch_shapes=[pltpu.VMEM(phi_shape, cdt)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((out_rows, n), jnp.float32),
        interpret=interpret,
        # j must run sequentially per g (Φ scratch is built at j == 0);
        # g tiles are independent and may be megacore-partitioned.
        compiler_params=_compiler_params(interpret, ("parallel", "arbitrary")),
    )(jnp.asarray(tab), *([operand] * plan.kappa))


def _run_fused_gather(plan, kernel, tab, row_map, operand, out_block,
                      out_rows, n, tn, interpret):
    """Gather launcher: grid (M, ⌈n/tn⌉); operand stays in HBM (ANY memory
    space), masked rows arrive via in-kernel DMA driven by the
    scalar-prefetched ``row_map``; Φ scratch is cached across j as in v2.

    ``n`` may be ragged (``n % tn != 0``): only the OUTPUT is padded to the
    tile grid — the kernel clips the last tile's row DMAs to the valid
    width, so the HBM source is never copied/padded.
    """
    n_pad = ((n + tn - 1) // tn) * tn
    grid = (plan.M, n_pad // tn)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(out_block, lambda g, j, tab_ref, rmap_ref: (g, j)),
        scratch_shapes=[
            # gather scratch holds the raw DMA'd rows — streamed dtype;
            # Φ scratch holds the MXU compute dtype (gat tiles upcast to
            # it at the contraction; identical dtypes except under fp8)
            pltpu.VMEM((plan.kappa * plan.Bc, tn), operand.dtype),
            pltpu.VMEM((out_block[0], plan.kappa * plan.Bc),
                       plan.precision.compute_dtype),      # Φ*
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((out_rows, n_pad), jnp.float32),
        interpret=interpret,
        compiler_params=_compiler_params(interpret, ("parallel", "arbitrary")),
    )(jnp.asarray(tab), jnp.asarray(row_map, jnp.int32), operand)


def _stream(plan: BlockPermPlan, operand: jnp.ndarray) -> jnp.ndarray:
    """Quantize the operand into the plan's streaming dtype.

    THE streaming cast (``core.precision.quantize_stream``): nearest
    rounding for fp32/bf16/fp8 policies, seeded value-keyed stochastic
    rounding for the ``*_sr`` fp8 policies (keyed on ``plan.seed`` so a
    draw's quantization is as reproducible as its wiring).  The kernels
    stream the result from HBM at ``plan.stream_itemsize`` bytes/elem and
    upcast to ``plan.precision.compute_dtype`` in VMEM for the MXU."""
    return precision_mod.quantize_stream(
        operand, plan.precision, seed=plan.seed)


def flashsketch_pallas(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    *,
    tn: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Y = S A via the fused v2 kernel. A must be (d_pad, n); n may be
    ragged (the ⌈n/tn⌉ edge tile is handled by the Pallas machinery)."""
    if interpret is None:
        interpret = _should_interpret()
    d_pad, n = A.shape
    assert d_pad == plan.d_pad, (d_pad, plan.d_pad)
    phi_fn, tab = _fwd_phi_and_table(plan)
    kernel = functools.partial(
        _fused_fwd_kernel, plan=plan, scale=plan.scale, phi_fn=phi_fn
    )
    return _run_fused(
        plan, kernel, tab, _stream(plan, A),
        in_block=(plan.Bc, tn), out_block=(plan.Br, tn),
        phi_shape=(plan.Br, plan.kappa * plan.Bc),
        out_rows=plan.k_pad, n=n, tn=tn, interpret=interpret,
    )


def flashsketch_transpose_pallas(
    plan: BlockPermPlan,
    Y: jnp.ndarray,
    *,
    tn: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """X = Sᵀ Y via the fused v2 kernel. Y must be (k_pad, n); ragged n ok."""
    if interpret is None:
        interpret = _should_interpret()
    k_pad, n = Y.shape
    assert k_pad == plan.k_pad, (k_pad, plan.k_pad)
    phi_fn, tab = _transpose_phi_and_table(plan)
    kernel = functools.partial(_fused_transpose_kernel, plan=plan,
                               scale=plan.scale, phi_fn=phi_fn)
    return _run_fused(
        plan, kernel, tab, _stream(plan, Y),
        in_block=(plan.Br, tn), out_block=(plan.Bc, tn),
        phi_shape=(plan.kappa * plan.Br, plan.Bc),
        out_rows=plan.d_pad, n=n, tn=tn, interpret=interpret,
    )


def flashsketch_pallas_gather(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    row_map: jnp.ndarray,
    *,
    tn: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Y = S · A[row_map, :] in ONE launch — the gather-fused v2 kernel.

    Args:
      plan: frozen plan for the *masked* input dim (``plan.d`` = rows kept).
      A: ``(d_src, n)`` source matrix; ``n`` may be ragged (``n % tn != 0``
        — the kernel handles the last tile in-kernel, A is NEVER padded or
        copied).  Stays in HBM; the kernel DMAs only the masked rows.
      row_map: ``(d_pad,)`` int32 — source row of A feeding each padded
        masked row.  Entries beyond ``plan.d`` may point at any valid row
        (``lowering.row_map_for`` uses 0); the kernel zeroes those gather-
        scratch rows before the contraction.

    Returns:
      ``(k_pad, ⌈n/tn⌉·tn)`` fp32 — the caller slices off the padded
      output columns (they are exact zeros).
    """
    if interpret is None:
        interpret = _should_interpret()
    _, n = A.shape
    assert row_map.shape == (plan.d_pad,), (row_map.shape, plan.d_pad)
    phi_fn, tab = _fwd_phi_and_table(plan)
    kernel = functools.partial(
        _fused_gather_kernel, plan=plan, scale=plan.scale, phi_fn=phi_fn,
        tn=tn, n_rem=n % tn,
    )
    return _run_fused_gather(
        plan, kernel, tab, row_map, _stream(plan, A),
        out_block=(plan.Br, tn), out_rows=plan.k_pad, n=n, tn=tn,
        interpret=interpret,
    )


def blockrow_pallas_gather(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    row_map: jnp.ndarray,
    *,
    tn: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """FLASHBLOCKROW over gathered rows: Y = S_row · A[row_map, :], fused.

    Ragged ``n`` handled in-kernel like ``flashsketch_pallas_gather`` —
    only the output is tile-padded, never the HBM source.
    """
    if interpret is None:
        interpret = _should_interpret()
    _, n = A.shape
    assert row_map.shape == (plan.d_pad,), (row_map.shape, plan.d_pad)
    scale = plan.scale * math.sqrt(plan.d_pad / plan.k_pad)
    kernel = functools.partial(
        _fused_gather_kernel, plan=plan, scale=scale, phi_fn=_phi_rows_tile,
        tn=tn, n_rem=n % tn,
    )
    return _run_fused_gather(
        plan, kernel, _blockrow_table(plan), row_map, _stream(plan, A),
        out_block=(plan.Br, tn), out_rows=plan.k_pad, n=n, tn=tn,
        interpret=interpret,
    )


def blockrow_pallas(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    *,
    tn: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """FLASHBLOCKROW forward via the fused v2 kernel. A: (d_pad, n); ragged n ok."""
    if interpret is None:
        interpret = _should_interpret()
    d_pad, n = A.shape
    assert d_pad == plan.d_pad
    h_np = _blockrow_table(plan)                            # (κ, M) static
    scale = plan.scale * math.sqrt(plan.d_pad / plan.k_pad)
    kernel = functools.partial(
        _fused_fwd_kernel, plan=plan, scale=scale, phi_fn=_phi_rows_tile
    )
    return _run_fused(
        plan, kernel, h_np, _stream(plan, A),
        in_block=(plan.Bc, tn), out_block=(plan.Br, tn),
        phi_shape=(plan.Br, plan.kappa * plan.Bc),
        out_rows=plan.k_pad, n=n, tn=tn, interpret=interpret,
    )


def flashsketch_pallas_partial(
    plan: BlockPermPlan,
    A_local: jnp.ndarray,
    tables: jnp.ndarray,
    *,
    tn: int = 128,
    rows_pattern: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-ℓ UNSCALED partial sketch of a contiguous block slab.

    Args:
      plan: the frozen GLOBAL plan (full M-block grid).
      A_local: ``(M_loc·B_c, n)`` slab of the padded input owned by this
        device (a contiguous range of ``M_loc`` of the M input blocks);
        ``n`` may be ragged (``n % tn != 0`` — the edge column tile rides
        the Pallas machinery, the slab is never column-padded).  Streamed
        in ``plan.stream_dtype``.
      tables: from ``repro.distributed.sharded_apply.partial_tables`` —
        ``(2, κ, M_loc)`` int32 ``[global g, global h]`` for the default
        COMPACT owned-pair kernel, or ``(3, κ, M)`` ``[local gather index,
        global h, owned]`` for the masked FLASHBLOCKROW form
        (``rows_pattern=True``).  May be traced arrays — ownership depends
        on ``lax.axis_index`` under ``shard_map``.
      tn: column-tile width.
      rows_pattern: use the FLASHBLOCKROW per-row Φ pattern (iid wiring ⇒
        masked full-grid kernel instead of the compact one).

    Returns:
      fp32 per-ℓ partials, UNSCALED: compact ``(κ, M_loc·B_r, n)`` for the
      default path (caller scatters rows ``m`` to output blocks
      ``tables[0, ℓ, m]``), or global ``(κ, k_pad, n)`` with exact zeros
      at non-owned positions for ``rows_pattern``.  Either way, ``psum``
      over the shard axis then an ℓ-ordered fold recovers the full
      ``S·A / scale`` bit-exactly (one nonzero contributor per element).
    """
    if interpret is None:
        interpret = _should_interpret()
    rows_loc, n = A_local.shape
    assert rows_loc % plan.Bc == 0, (rows_loc, plan.Bc)
    M_loc = rows_loc // plan.Bc
    assert plan.M % M_loc == 0, (plan.M, M_loc)
    n_tiles = -(-n // tn)
    operand = _stream(plan, A_local)
    if rows_pattern:
        assert tables.shape == (3, plan.kappa, plan.M), tables.shape
        kernel = functools.partial(
            _partial_masked_kernel, plan=plan, phi_fn=_phi_rows_tile)
        grid = (plan.M, plan.kappa, n_tiles)
        in_spec = pl.BlockSpec(
            (plan.Bc, tn), lambda g, l, j, tab_ref: (tab_ref[0, l, g], j))
        out_rows = plan.k_pad
        out_map = lambda g, l, j, tab_ref: (l, g, j)       # noqa: E731
    else:
        assert tables.shape == (2, plan.kappa, M_loc), tables.shape
        kernel = functools.partial(
            _partial_fwd_kernel, plan=plan, phi_fn=_phi_tile)
        grid = (M_loc, plan.kappa, n_tiles)
        in_spec = pl.BlockSpec(
            (plan.Bc, tn), lambda m, l, j, tab_ref: (m, j))
        out_rows = M_loc * plan.Br
        out_map = lambda m, l, j, tab_ref: (l, m, j)       # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[in_spec],
        out_specs=pl.BlockSpec((1, plan.Br, tn), out_map),
        scratch_shapes=[pltpu.VMEM((plan.Br, plan.Bc),
                                   plan.precision.compute_dtype)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (plan.kappa, out_rows, n), jnp.float32),
        interpret=interpret,
        # j must run sequentially per (block, ℓ) — the Φ scratch is built
        # at j == 0; block tiles are independent.
        compiler_params=_compiler_params(
            interpret, ("parallel", "arbitrary", "arbitrary")),
    )(jnp.asarray(tables, jnp.int32), operand)


# ---------------------------------------------------------------------------
# v1 kernels — output-revisiting grid reduction.  Reference oracle for the
# equivalence tests and the baseline for kernel_bench; always fp32.
# ---------------------------------------------------------------------------

def _fwd_kernel_v1(tab_ref, a_ref, o_ref, *, plan: BlockPermPlan, scale,
                   phi_fn=_phi_tile):
    g = pl.program_id(1)
    ell = pl.program_id(2)
    h = tab_ref[ell, g]
    phi = phi_fn(plan, g, h)
    contrib = jnp.dot(
        phi, a_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale

    @pl.when(ell == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(ell > 0)
    def _acc():
        o_ref[...] += contrib


def _transpose_kernel_v1(tab_ref, y_ref, o_ref, *, plan: BlockPermPlan,
                         scale, phi_fn=_phi_tile):
    hb = pl.program_id(1)               # input block index (output of Sᵀ)
    ell = pl.program_id(2)
    g = tab_ref[ell, hb]                # g = f^{-ℓ}(hb)
    phi = phi_fn(plan, g, hb)           # (Br, Bc)
    contrib = jnp.dot(
        phi.T, y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale

    @pl.when(ell == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(ell > 0)
    def _acc():
        o_ref[...] += contrib


def _blockrow_kernel_v1(tab_ref, a_ref, o_ref, *, plan: BlockPermPlan, scale):
    g = pl.program_id(1)
    ell = pl.program_id(2)
    h = tab_ref[ell, g]
    phi = _phi_rows_tile(plan, g, h)
    contrib = jnp.dot(
        phi, a_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale

    @pl.when(ell == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(ell > 0)
    def _acc():
        o_ref[...] += contrib


def flashsketch_pallas_v1(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    *,
    tn: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Y = S A via the v1 grid-reduction kernel (fp32 only; ragged n ok)."""
    if interpret is None:
        interpret = _should_interpret()
    d_pad, n = A.shape
    assert d_pad == plan.d_pad, (d_pad, plan.d_pad)
    phi_fn, tab = _fwd_phi_and_table(plan)
    kernel = functools.partial(_fwd_kernel_v1, plan=plan, scale=plan.scale,
                               phi_fn=phi_fn)
    return _run_v1(
        plan, kernel, tab, A,
        in_block=(plan.Bc, tn), out_block=(plan.Br, tn),
        out_rows=plan.k_pad, n=n, tn=tn, interpret=interpret,
    )


def flashsketch_transpose_pallas_v1(
    plan: BlockPermPlan,
    Y: jnp.ndarray,
    *,
    tn: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """X = Sᵀ Y via the v1 grid-reduction kernel (fp32 only; ragged n ok)."""
    if interpret is None:
        interpret = _should_interpret()
    k_pad, n = Y.shape
    assert k_pad == plan.k_pad, (k_pad, plan.k_pad)
    phi_fn, tab = _transpose_phi_and_table(plan)
    kernel = functools.partial(_transpose_kernel_v1, plan=plan,
                               scale=plan.scale, phi_fn=phi_fn)
    return _run_v1(
        plan, kernel, tab, Y,
        in_block=(plan.Br, tn), out_block=(plan.Bc, tn),
        out_rows=plan.d_pad, n=n, tn=tn, interpret=interpret,
    )


def blockrow_pallas_v1(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    *,
    tn: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """FLASHBLOCKROW forward via the v1 grid-reduction kernel (fp32 only;
    ragged n ok)."""
    if interpret is None:
        interpret = _should_interpret()
    d_pad, n = A.shape
    assert d_pad == plan.d_pad
    h_np = _blockrow_table(plan)                            # (κ, M) static
    scale = plan.scale * math.sqrt(plan.d_pad / plan.k_pad)
    kernel = functools.partial(_blockrow_kernel_v1, plan=plan, scale=scale)
    return _run_v1(
        plan, kernel, h_np, A,
        in_block=(plan.Bc, tn), out_block=(plan.Br, tn),
        out_rows=plan.k_pad, n=n, tn=tn, interpret=interpret,
    )
