"""FLASHSKETCH Pallas/TPU kernel (paper §5, adapted per DESIGN.md §2).

Grid ``(n/T_n, M, κ)`` with the κ axis as an arbitrary-order reduction:
program ``(j, g, ℓ)`` owns output tile ``Y[g·B_r:(g+1)B_r, j·T_n:(j+1)T_n]``
(resident in VMEM across the κ revisits — the TPU analogue of the paper's
"one thread-block owns one output tile, single global write") and streams
input block ``h = π_{ℓ+1}(g)`` through VMEM.  The block wiring is evaluated
*inside the BlockSpec index_map* from precomputed affine constants — the
paper's App. D on-the-fly generation, moved to the scalar core.

The intra-block scatter-add is re-expressed as an on-the-fly one-hot
contraction on the MXU: Φ_{g,h} is built in VMEM from ``broadcasted_iota`` +
counter-based hashes (bit-identical to ``ref.py``) and contracted with the
input tile.  No atomics exist or are needed.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing
from repro.core.blockperm import BlockPermPlan
from repro.kernels import ref as kref


# ---------------------------------------------------------------------------
# Static wiring tables:  π_ℓ(g) = A_ℓ·g + B_ℓ (mod M)  for ℓ = 1..κ,
# plus the inverse maps for the transpose kernel.
# ---------------------------------------------------------------------------

def _wiring_tables(plan: BlockPermPlan) -> Tuple[np.ndarray, np.ndarray]:
    A_tab = np.empty(plan.kappa, np.int32)
    B_tab = np.empty(plan.kappa, np.int32)
    a_l, b_l = 1, 0
    for ell in range(plan.kappa):
        # f^{ell+1} = f ∘ f^{ell}:  a_{l+1} = a·a_l, b_{l+1} = a·b_l + b.
        a_l = (plan.a * a_l) % plan.M
        b_l = (plan.a * b_l + plan.b) % plan.M
        A_tab[ell], B_tab[ell] = a_l, b_l
    return A_tab, B_tab


def _inverse_wiring_tables(plan: BlockPermPlan) -> Tuple[np.ndarray, np.ndarray]:
    A_tab, B_tab = _wiring_tables(plan)
    Ai = np.empty_like(A_tab)
    Bi = np.empty_like(B_tab)
    for ell in range(plan.kappa):
        a_inv = pow(int(A_tab[ell]), -1, plan.M) if plan.M > 1 else 0
        Ai[ell] = a_inv % plan.M
        Bi[ell] = (-a_inv * int(B_tab[ell])) % plan.M
    return Ai, Bi


# ---------------------------------------------------------------------------
# In-kernel Φ construction (must match ref._phi_all_blocks bit-for-bit).
# ---------------------------------------------------------------------------

def _phi_tile(plan: BlockPermPlan, g, h) -> jnp.ndarray:
    """Φ_{g,h} ∈ (Br, Bc), entries ±1/0, built from hashes. g,h traced scalars."""
    u = jax.lax.broadcasted_iota(jnp.int32, (1, plan.Bc), 1)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (plan.Br, plan.Bc), 0)
    chunk = plan.chunk
    phi = jnp.zeros((plan.Br, plan.Bc), jnp.float32)
    for i in range(plan.s):
        hsh = hashing.hash_words(
            np.uint32(plan.seed),
            g.astype(jnp.uint32),
            h.astype(jnp.uint32),
            u.astype(jnp.uint32),
            np.uint32(i),
        )                                              # (1, Bc)
        rows = i * chunk + hashing.hash_mod(hsh, chunk)
        signs = hashing.hash_to_unit_sign(hsh)
        phi = phi + jnp.where(r_iota == rows, signs, 0.0)
    return phi


def _phi_rows_tile(plan: BlockPermPlan, g, h) -> jnp.ndarray:
    """FLASHBLOCKROW pattern: s ±1 entries per *row*. Matches ref._phi_rows_all_blocks."""
    r = jax.lax.broadcasted_iota(jnp.int32, (plan.Br, 1), 0)
    c_iota = jax.lax.broadcasted_iota(jnp.int32, (plan.Br, plan.Bc), 1)
    phi = jnp.zeros((plan.Br, plan.Bc), jnp.float32)
    for t in range(plan.s):
        hsh = hashing.hash_words(
            np.uint32(plan.seed),
            np.uint32(0x5EED),
            g.astype(jnp.uint32),
            h.astype(jnp.uint32),
            r.astype(jnp.uint32),
            np.uint32(t),
        )                                              # (Br, 1)
        cols = hashing.hash_mod(hsh, plan.Bc)
        signs = hashing.hash_to_unit_sign(hsh)
        phi = phi + jnp.where(c_iota == cols, signs, 0.0)
    return phi


# ---------------------------------------------------------------------------
# Kernel bodies.  The (κ, M) wiring table arrives as a *scalar-prefetch*
# operand (pltpu.PrefetchScalarGridSpec): the TPU scalar core reads it ahead
# of the grid loop so BlockSpec index_maps can do data-dependent block
# selection — the Pallas-idiomatic realization of the paper's on-the-fly
# wiring (App. D).  The table itself is κ·M int32s (a few KB), generated from
# the affine full-cycle map.
# ---------------------------------------------------------------------------

def _fwd_kernel(tab_ref, a_ref, o_ref, *, plan: BlockPermPlan, scale):
    g = pl.program_id(1)
    ell = pl.program_id(2)
    h = tab_ref[ell, g]
    phi = _phi_tile(plan, g, h)
    contrib = jnp.dot(
        phi, a_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale

    @pl.when(ell == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(ell > 0)
    def _acc():
        o_ref[...] += contrib


def _transpose_kernel(tab_ref, y_ref, o_ref, *, plan: BlockPermPlan, scale):
    hb = pl.program_id(1)               # input block index (output of Sᵀ)
    ell = pl.program_id(2)
    g = tab_ref[ell, hb]                # g = f^{-ℓ}(hb)
    phi = _phi_tile(plan, g, hb)        # (Br, Bc)
    contrib = jnp.dot(
        phi.T, y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale

    @pl.when(ell == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(ell > 0)
    def _acc():
        o_ref[...] += contrib


def _blockrow_kernel(tab_ref, a_ref, o_ref, *, plan: BlockPermPlan, scale):
    g = pl.program_id(1)
    ell = pl.program_id(2)
    h = tab_ref[ell, g]
    phi = _phi_rows_tile(plan, g, h)
    contrib = jnp.dot(
        phi, a_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale

    @pl.when(ell == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(ell > 0)
    def _acc():
        o_ref[...] += contrib


# ---------------------------------------------------------------------------
# pallas_call wrappers (raw; user-facing API with padding/custom_vjp in ops.py)
# ---------------------------------------------------------------------------

def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _compiler_params(interpret: bool):
    if interpret:
        return None
    try:
        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    except AttributeError:  # older jax spelling
        return pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )


def _fwd_neighbor_table(plan: BlockPermPlan) -> np.ndarray:
    """(κ, M) table: h = π_{ℓ+1}(g)."""
    A_tab, B_tab = _wiring_tables(plan)
    g = np.arange(plan.M, dtype=np.int64)
    return np.stack(
        [(A_tab[l] * g + B_tab[l]) % plan.M for l in range(plan.kappa)]
    ).astype(np.int32)


def _inv_neighbor_table(plan: BlockPermPlan) -> np.ndarray:
    """(κ, M) table: g = π_{ℓ+1}^{-1}(h)."""
    Ai, Bi = _inverse_wiring_tables(plan)
    h = np.arange(plan.M, dtype=np.int64)
    return np.stack(
        [(int(Ai[l]) * h + int(Bi[l])) % plan.M for l in range(plan.kappa)]
    ).astype(np.int32)


def _run(plan, kernel, tab, operand, in_block, out_block, out_rows, n, tn, interpret):
    grid = (n // tn, plan.M, plan.kappa)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec(in_block, lambda j, g, l, tab_ref: (tab_ref[l, g], j)),
        ],
        out_specs=pl.BlockSpec(out_block, lambda j, g, l, tab_ref: (g, j)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((out_rows, n), jnp.float32),
        interpret=interpret,
        compiler_params=_compiler_params(interpret),
    )(jnp.asarray(tab), operand)


def flashsketch_pallas(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    *,
    tn: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Y = S A via the Pallas kernel. A must already be (d_pad, n) with n % tn == 0."""
    if interpret is None:
        interpret = _should_interpret()
    d_pad, n = A.shape
    assert d_pad == plan.d_pad, (d_pad, plan.d_pad)
    assert n % tn == 0, (n, tn)
    kernel = functools.partial(_fwd_kernel, plan=plan, scale=plan.scale)
    return _run(
        plan, kernel, _fwd_neighbor_table(plan), A,
        in_block=(plan.Bc, tn), out_block=(plan.Br, tn),
        out_rows=plan.k_pad, n=n, tn=tn, interpret=interpret,
    )


def flashsketch_transpose_pallas(
    plan: BlockPermPlan,
    Y: jnp.ndarray,
    *,
    tn: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """X = Sᵀ Y via the Pallas kernel. Y must be (k_pad, n) with n % tn == 0."""
    if interpret is None:
        interpret = _should_interpret()
    k_pad, n = Y.shape
    assert k_pad == plan.k_pad, (k_pad, plan.k_pad)
    assert n % tn == 0, (n, tn)
    kernel = functools.partial(_transpose_kernel, plan=plan, scale=plan.scale)
    return _run(
        plan, kernel, _inv_neighbor_table(plan), Y,
        in_block=(plan.Br, tn), out_block=(plan.Bc, tn),
        out_rows=plan.d_pad, n=n, tn=tn, interpret=interpret,
    )


def blockrow_pallas(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    *,
    tn: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """FLASHBLOCKROW forward via Pallas. A must be (d_pad, n), n % tn == 0."""
    if interpret is None:
        interpret = _should_interpret()
    d_pad, n = A.shape
    assert d_pad == plan.d_pad
    assert n % tn == 0
    h_np = np.asarray(kref.blockrow_wiring(plan))           # (κ, M) static
    scale = plan.scale * math.sqrt(plan.d_pad / plan.k_pad)
    kernel = functools.partial(_blockrow_kernel, plan=plan, scale=scale)
    return _run(
        plan, kernel, h_np, A,
        in_block=(plan.Bc, tn), out_block=(plan.Br, tn),
        out_rows=plan.k_pad, n=n, tn=tn, interpret=interpret,
    )
