"""Distributed sketch-and-precondition least squares on a device mesh.

The multi-device form of ``solvers.sketch_precondition`` (Chen et al.'s
sparse-sign sketch-and-precondition, at Higgins & Boman's too-big-for-one-
device scale):

  1. sketch:   row-sharded ``A`` → ``SA`` via ``sketch_apply_sharded``
     (per-device partial kernels + one psum; ``SA`` lands REPLICATED, and
     bit-exact to the single-device sketch);
  2. factor:   ``R`` from the small replicated ``(k, n)`` sketch — every
     device factors the identical matrix, no collective;
  3. iterate:  LSQR through ``solvers.lsqr_operator`` with INJECTED
     ``shard_map``'d matvec/rmatvec — the forward product stays row-sharded
     (no gather of the (d,) iterate), the adjoint ``psum``s the (n,)
     reduction; per iteration the only collective is one (n,)-sized psum
     plus LSQR's scalar norms.

No step ever materializes all of ``A`` on one device, and the sketch means
the iteration count is O(1) in cond(A) — the whole point of running the
sketch, not the factorization, at scale.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.blockperm import BlockPermPlan
from repro.distributed.sharded_apply import (plan_for_mesh, shard_count,
                                             sketch_apply_sharded)
from repro.kernels import ops
from repro.solvers.sketch_precondition import (SolveResult,
                                               default_sketch_rows,
                                               lsqr_operator)


def sharded_matvec_ops(A: jnp.ndarray, mesh, axis: str):
    """(matvec, rmatvec) closures for a row-sharded tall operator.

    ``matvec(v)``: each device multiplies its row slab by the replicated
    ``(n,)`` vector — output ``(d,)`` stays sharded over ``axis`` (LSQR's
    u-vectors never need gathering; norms reduce them directly).
    ``rmatvec(u)``: per-device ``A_locᵀ u_loc`` followed by a psum — the
    one real collective per iteration, ``(n,)``-sized.

    ``A.shape[0]`` must be divisible by the axis size (see
    ``dist_sketch_precondition_lstsq`` for the zero-row padding that
    guarantees it).
    """
    num = shard_count(mesh, axis)
    if A.shape[0] % num != 0:
        raise ValueError(
            f"row-sharded matvec needs P | d: P={num}, d={A.shape[0]}")

    mv = shard_map(
        lambda Al, v: Al @ v, mesh=mesh,
        in_specs=(P(axis, None), P(None)), out_specs=P(axis),
        check_rep=False)
    rmv = shard_map(
        lambda Al, ul: jax.lax.psum(Al.T @ ul, axis), mesh=mesh,
        in_specs=(P(axis, None), P(axis)), out_specs=P(None),
        check_rep=False)
    return (lambda v: mv(A, v)), (lambda u: rmv(A, u))


def _pad_rows_to(A: jnp.ndarray, b: jnp.ndarray, multiple: int):
    """Append zero rows so the shard axis divides d — appended rows
    contribute 0 to every residual, so argmin ||Ax-b|| is unchanged."""
    d = A.shape[0]
    d_pad = ((d + multiple - 1) // multiple) * multiple
    if d_pad == d:
        return A, b
    A = jnp.pad(A, ((0, d_pad - d), (0, 0)))
    b = jnp.pad(b, (0, d_pad - d))
    return A, b


def dist_sketch_precondition_lstsq(
    A: jnp.ndarray,
    b: jnp.ndarray,
    mesh,
    axis: str,
    plan: Optional[BlockPermPlan] = None,
    *,
    k: Optional[int] = None,
    kappa: int = 4,
    s: int = 2,
    seed: int = 0,
    dtype: str = "float32",
    sampling_factor: float = 4.0,
    factorization: str = "qr",
    tol: float = 1e-6,
    max_iters: int = 100,
    impl: str = "auto",
    guard: bool = False,
    policy: Optional[object] = None,
) -> SolveResult:
    """Solve ``min_x ||A x - b||`` by DISTRIBUTED sketch-and-precondition.

    Args:
      A: (d, n) tall matrix, d >> n; may arrive as a committed row-sharded
        jax.Array (shard_map re-lays it out over ``axis`` either way).
      b: (d,) right-hand side.
      mesh / axis: the device mesh and the axis carrying the row shards;
        ``mesh.shape[axis]`` must divide the plan's block grid M (the
        default plan always satisfies this for power-of-two axis sizes).
      plan: optional pre-built sketch plan (wins over k/kappa/s/seed/dtype).
      k, kappa, s, seed, dtype, sampling_factor, factorization, tol,
        max_iters, impl: as in ``solvers.sketch_precondition_lstsq``.
      guard: run the distributed health guards before iterating — the
        psum'd ``SA`` must be BIT-IDENTICAL on every device (any replica
        deviation means a corrupted collective contribution: zeroed or
        permuted partial, dropped participant), plus the finite and
        triangular-condition guards on ``R``.  A ``failed`` verdict
        re-draws the sketch once (``RedrawPolicy``-derived seed) before
        giving up; the ``HealthReport`` lands on ``.health``.
      policy: optional ``repro.health.policy.RedrawPolicy`` (guard path
        only) controlling the re-draw budget.

    Returns:
      ``SolveResult``; the solution matches the single-device solver to
      iteration-level rounding (the preconditioner never biases the fixed
      point, and the sharded sketch is bit-exact, so R is identical).
    """
    d, n = A.shape
    if plan is None:
        plan = plan_for_mesh(
            d, k or default_sketch_rows(n, sampling_factor),
            shard_count(mesh, axis), kappa=kappa, s=s, seed=seed, dtype=dtype)
    num = shard_count(mesh, axis)

    def sketch_and_factor(p):
        # 1. sketch (psum'd partials -> replicated SA, bit-exact)
        SA = sketch_apply_sharded(p, A.astype(jnp.float32), mesh, axis, impl)
        # 2. factor (tiny n×n problem, replicated)
        return SA, ops.triangular_factor(SA.astype(jnp.float32),
                                         factorization)

    rpt = None
    if not guard:
        _, R = sketch_and_factor(plan)
    else:
        from repro.health import guards
        from repro.health import report as health_report
        from repro.health.policy import RedrawPolicy

        pol = policy if policy is not None else RedrawPolicy()
        rpt = health_report.HealthReport(op="dist_sketch_precondition_lstsq")

        def check(p, SA, R):
            findings = [
                guards.replica_consistency_guard(guards.replica_arrays(SA),
                                                 "SA"),
                guards.finite_guard(SA, "SA"),
                guards.finite_guard(R, "R"),
                guards.r_condition_guard(R, "R"),
            ]
            findings = [f for f in findings if f is not None]
            for f in findings:
                rpt.add(f)
            return health_report.worst_status(
                *[f.status for f in findings]) if findings else \
                health_report.HEALTHY

        R = None
        for attempt in pol.attempts(seed=plan.seed, kappa=plan.kappa,
                                    sampling_factor=sampling_factor):
            p = plan if attempt.index == 0 else plan_for_mesh(
                d, plan.k_req, num, kappa=plan.kappa, s=plan.s,
                seed=attempt.seed, dtype=dtype)
            pol.record(attempt)
            if attempt.index > 0:
                rpt.act(attempt.describe())
            rpt.attempts += 1
            SA, R = sketch_and_factor(p)
            if pol.accepts(check(p, SA, R)):
                break
            # structural bumps don't help a corrupted collective; the
            # ladder here is redraw-only — stop once redraws are spent
            if attempt.index >= pol.max_redraws:
                rpt.act("escalation_budget_exhausted")
                health_report.record("policy.budget_exhausted")
                break
    R = R.astype(b.dtype)
    # 3. iterate with sharded products
    Ap, bp = _pad_rows_to(A, b, num)
    matvec, rmatvec = sharded_matvec_ops(Ap, mesh, axis)
    res = lsqr_operator(matvec, rmatvec, bp, nvars=n, R=R,
                        tol=tol, max_iters=max_iters)
    res.health = rpt
    return res
