"""Multi-device FlashSketch: ``shard_map``-mapped apply over a device mesh.

Three sharding layouts, in decreasing collective cost:

  * **Row-sharded** (``sketch_apply_sharded``) — the d ≫ k regime the paper
    targets, at matrices too large for one device (Higgins & Boman's
    multisketching setting): ``A``'s row axis is partitioned so each of the
    P devices owns a CONTIGUOUS range of ``M_loc = M/P`` of the plan's M
    input blocks (``P | M``).  Each device runs the local partial kernel on
    its block slab and the ``(k, n)`` partials are ``psum``'d — ``S`` is
    never gathered and no device ever materializes all of ``A``.
  * **Column-sharded** (``sketch_apply_colsharded``) — ``n`` partitioned;
    embarrassingly parallel (every device applies the full sketch to its
    column slab, NO collective), output column-sharded.
  * **Batch-sharded** (``sketch_apply_batched_sharded``) — a stack of
    matrices partitioned over its batch axis; each device runs the fused
    batched (optionally gather-fused) launch on its local stack.  This is
    the distributed GraSS featurize layout (``attribution.grass``).

Bit-exactness (tested, fp32 AND bf16): the row-sharded path is
``array_equal`` to single-device ``ops.sketch_apply``, not merely close.
The trick is the reduction layout: each device produces PER-ℓ partials
``(κ, k_pad, n)`` where, for every ``(ℓ, output-block)`` pair, exactly ONE
device holds a nonzero value (block ownership is a partition and π_ℓ is a
permutation).  The ``psum`` therefore only ever adds exact zeros to the
one real contribution — an exact fp32 reduction regardless of device
order — and the κ-fold afterwards runs in the reference oracle's
summation order.  Shipping κ·k·n instead of k·n over ICI is the price of
exactness; ``roofline.sketch_model.dist_sketch_cost`` charges it.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import hashing
from repro.core.blockperm import BlockPermPlan, make_plan
from repro.kernels import flashsketch as fsk
from repro.kernels import lowering
from repro.kernels import ops
from repro.kernels import ref as kref

# The VMEM predicate is single-sourced in the lowering engine (shared with
# ops dispatch); re-exported here because it is part of this package's API.
partial_fits_vmem = lowering.partial_fits_vmem


def shard_count(mesh, axis: str) -> int:
    """Size of one mesh axis (the sketch-shard degree P)."""
    return mesh.shape[axis]


def check_row_partition(plan: BlockPermPlan, num_shards: int) -> int:
    """Validate ``P | M`` and return the per-device block count M_loc."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if plan.M % num_shards != 0:
        raise ValueError(
            f"row-sharding needs the shard count to divide the block grid: "
            f"P={num_shards} does not divide M={plan.M} "
            f"(rebuild the plan with block_rows= so that P | M)")
    return plan.M // num_shards


def plan_for_mesh(
    d: int,
    k: int,
    num_shards: int,
    *,
    kappa: int = 4,
    s: int = 2,
    seed: int = 0,
    dtype: str = "float32",
) -> BlockPermPlan:
    """``make_plan`` with the block grid pinned so ``P | M``.

    The auto planner optimizes M for one chip; row-sharding additionally
    needs the shard count (a power of two) to divide M.  This picks the
    smallest ``B_r`` pin whose resulting grid satisfies both ``M ≥ P`` and
    ``M ≥ κ``.  Tiny sketches (``k < P·s``) cannot host P shards and fail
    ``check_row_partition`` downstream.
    """
    import math as _math

    from repro.core.blockperm import _next_pow2
    m_target = max(_next_pow2(max(1, num_shards)), _next_pow2(max(1, kappa)))
    br = max(_next_pow2(_math.ceil(k / m_target)), _next_pow2(max(1, s)))
    return make_plan(d, k, kappa=kappa, s=s, seed=seed, block_rows=br,
                     dtype=dtype)


def partial_tables(plan: BlockPermPlan, lo, M_loc: int,
                   rows_pattern: bool = False) -> jnp.ndarray:
    """Prefetch/scatter tables for the device-local partial apply.

    ``lo`` (the first owned block index) may be traced — under ``shard_map``
    it is ``axis_index * M_loc``.

    Default (BLOCKPERM): the wiring π_ℓ is a permutation, so each owned
    input block ``h = lo + m`` feeds exactly one output block
    ``g = π_ℓ⁻¹(h)`` per level — returns the COMPACT ``(2, κ, M_loc)``
    ``[global g, global h]`` table driving the owned-pair-only kernel grid
    (per-chip work shards 1/P) and the caller-side scatter.

    ``rows_pattern`` (FLASHBLOCKROW): iid wiring is not a permutation, so
    there is no compact form — returns the MASKED ``(3, κ, M)``
    ``[local gather index, global h, owned flag]`` table for the
    full-grid kernel; non-owned entries keep a VALID clipped gather index
    and their Φ is zeroed by the owned flag.
    """
    lo = jnp.asarray(lo, jnp.int32)
    if rows_pattern:
        h = jnp.asarray(fsk._blockrow_table(plan), jnp.int32)   # (κ, M)
        owned = ((h >= lo) & (h < lo + M_loc)).astype(jnp.int32)
        local = jnp.clip(h - lo, 0, M_loc - 1)
        return jnp.stack([local, h, owned])
    inv = jnp.asarray(fsk._inv_neighbor_table(plan), jnp.int32)  # (κ, M)
    h_of_m = lo + jnp.arange(M_loc, dtype=jnp.int32)             # (M_loc,)
    g_of_m = jnp.take(inv, h_of_m, axis=1)                       # (κ, M_loc)
    h_rows = jnp.broadcast_to(h_of_m[None, :], (plan.kappa, M_loc))
    return jnp.stack([g_of_m, h_rows])


def _phi_pairs(plan: BlockPermPlan, g_of_m: jnp.ndarray,
               h_of_m: jnp.ndarray) -> jnp.ndarray:
    """Φ for explicit (g, h) block pairs: (M_loc, Br, Bc), ±1/0 unscaled.

    The explicit-g generalization of ``kref._phi_all_blocks`` (which fixes
    ``g = arange(M)``): the hashes are elementwise in (g, h, u, i), so
    each slice is bitwise identical to the corresponding row of the
    full-grid build — the property the compact partials' exactness rests
    on.
    """
    g = g_of_m[:, None].astype(jnp.uint32)                # (M_loc, 1)
    h = h_of_m[:, None].astype(jnp.uint32)                # (M_loc, 1)
    u = jnp.arange(plan.Bc, dtype=jnp.uint32)[None, :]    # (1, Bc)
    r_iota = jnp.arange(plan.Br, dtype=jnp.int32)         # (Br,)
    phi = jnp.zeros((g_of_m.shape[0], plan.Br, plan.Bc), jnp.float32)
    chunk = plan.chunk
    for i in range(plan.s):
        hsh = hashing.hash_words(np.uint32(plan.seed), g, h, u, np.uint32(i))
        rows = i * chunk + hashing.hash_mod(hsh, chunk)   # (M_loc, Bc)
        signs = hashing.hash_to_unit_sign(hsh)
        onehot = (r_iota[None, :, None] == rows[:, None, :]).astype(
            jnp.float32)
        phi = phi + onehot * signs[:, None, :]
    return phi


def _partial_oracle(plan: BlockPermPlan, slab: jnp.ndarray,
                    tables: jnp.ndarray,
                    rows_pattern: bool = False) -> jnp.ndarray:
    """Pure-jnp per-ℓ partials, unscaled — the off-TPU twin of
    ``fsk.flashsketch_pallas_partial`` (same compact/masked split).

    Default: COMPACT ``(κ, M_loc·Br, n)`` over owned pairs only — the
    einsum is the batch-split of the single-device oracle's (per-g
    contractions are independent batch elements), so each slice is
    bitwise identical to the corresponding rows of
    ``kref.flashsketch_ref``'s per-ℓ contribution.

    ``rows_pattern``: masked ``(κ, k_pad, n)`` on the full grid (iid
    wiring; non-owned entries computed on junk clipped gathers and masked
    to exact zeros).
    """
    n = slab.shape[1]
    M_loc = slab.shape[0] // plan.Bc
    A_blocks = slab.reshape(M_loc, plan.Bc, n)
    parts = []
    if rows_pattern:
        for ell in range(plan.kappa):
            local, h_of_g, owned = (tables[0, ell], tables[1, ell],
                                    tables[2, ell])
            gathered = A_blocks[local]                    # (M, Bc, n)
            phi = kref._phi_rows_all_blocks(plan, h_of_g)  # (M, Br, Bc)
            contrib = jnp.einsum(
                "gbc,gcn->gbn", phi, gathered,
                precision=jax.lax.Precision.HIGHEST)
            parts.append(jnp.where(owned[:, None, None] == 1, contrib, 0.0))
        return jnp.stack(parts).reshape(plan.kappa, plan.k_pad, n)
    for ell in range(plan.kappa):
        phi = _phi_pairs(plan, tables[0, ell], tables[1, ell])
        contrib = jnp.einsum(
            "gbc,gcn->gbn", phi, A_blocks,
            precision=jax.lax.Precision.HIGHEST)          # (M_loc, Br, n)
        parts.append(contrib)
    return jnp.stack(parts).reshape(plan.kappa, M_loc * plan.Br, n)


def local_partial_apply(
    plan: BlockPermPlan,
    slab: jnp.ndarray,
    lo,
    *,
    impl: str = "auto",
    tn: Optional[int] = None,
    rows_pattern: bool = False,
) -> jnp.ndarray:
    """Device-local per-ℓ partial sketch of one contiguous block slab.

    Args:
      plan: the frozen GLOBAL plan.
      slab: ``(M_loc·B_c, n)`` rows of the PADDED input owned locally.
      lo: first owned block index (``axis_index * M_loc`` under shard_map;
        may be traced).
      impl: ``"auto" | "pallas" | "xla"`` — ``auto`` picks the fused
        partial Pallas kernel on TPU, the jnp oracle elsewhere (matching
        ``ops`` dispatch so sharded and single-device runs use the same
        backend family).
      tn: Pallas column-tile width (``None`` → the fwd shape-class tile).
      rows_pattern: FLASHBLOCKROW Φ pattern instead of BLOCKPERM.

    Returns:
      ``(κ, k_pad, n)`` fp32 per-ℓ partials, UNSCALED, in the GLOBAL
      output-block layout — exact zeros at every non-owned position (see
      ``sketch_apply_sharded`` for the exact-reduction protocol).  The
      compact kernel/oracle results are scattered into that layout here.
    """
    M_loc = slab.shape[0] // plan.Bc
    n = slab.shape[1]
    tables = partial_tables(plan, lo, M_loc, rows_pattern)
    if impl not in ("auto", "pallas", "xla"):
        raise ValueError(
            f"impl must be 'auto', 'pallas' or 'xla', got {impl!r}")
    # The launch decision — impl dispatch, tile resolution, the
    # shrink-then-oracle VMEM fallback — comes from the SAME lowering
    # engine as the single-device ops entry points (shard="row" selects
    # the partial formulation); only the shard_map plumbing lives here.
    lw = lowering.lower(plan, lowering.LaunchSpec(
        op="blockrow" if rows_pattern else "fwd", n=n, impl=impl, tn=tn,
        shard="row", devices=plan.M // M_loc))
    if lw.impl == "xla":
        # match ops' xla path: the oracle sees the stream-rounded input —
        # seeded precision emulation so stochastic-rounding policies stay
        # bit-identical to the kernel's in-flight quantization
        from repro.core import precision as precision_mod
        slab32 = slab.astype(jnp.float32)
        if plan.dtype != "float32":
            slab32 = precision_mod.emulate_stream(
                slab32, plan.precision, seed=plan.seed)
        parts = _partial_oracle(plan, slab32, tables, rows_pattern)
    else:
        # ragged n is handled in-kernel — the slab is never column-padded
        parts = fsk.flashsketch_pallas_partial(
            plan, slab, tables, tn=lw.tn,
            rows_pattern=rows_pattern)[:, :, :n]
    if rows_pattern:
        return parts                                      # already global
    # scatter the compact owned-pair rows into the zero global layout —
    # π_ℓ is a permutation, so the per-ℓ indices are collision-free
    compact = parts.reshape(plan.kappa, M_loc, plan.Br, n)
    out = jnp.zeros((plan.kappa, plan.M, plan.Br, n), jnp.float32)
    for ell in range(plan.kappa):
        out = out.at[ell, tables[0, ell]].set(compact[ell])
    return out.reshape(plan.kappa, plan.k_pad, n)


def _fold_scale_truncate(parts: jnp.ndarray, plan: BlockPermPlan,
                         scale: float) -> jnp.ndarray:
    """Σ_ℓ parts[ℓ] in the ORACLE's left-to-right order, then scale and
    truncate to the logical k — the last mile of the exactness argument."""
    Y = parts[0]
    for ell in range(1, plan.kappa):
        Y = Y + parts[ell]
    return (Y * scale)[: plan.k]


def sketch_apply_sharded(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    mesh,
    axis: str,
    impl: str = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    *,
    rows_pattern: bool = False,
):
    """Row-sharded ``Y = S A`` on a device mesh: psum'd partials, S never
    gathered, no device holds all of A.

    Args:
      plan: frozen plan; ``P = mesh.shape[axis]`` must divide ``plan.M``.
      A: ``(d, n)`` float array (a global/committed jax.Array is fine —
        ``shard_map`` re-lays it out row-sharded over ``axis``).
      mesh: a ``jax.sharding.Mesh`` (see ``launch.mesh.make_mesh``).
      axis: mesh axis name carrying the row shards.
      impl / tn / dtype: as in ``ops.sketch_apply`` (``pallas_v1`` has no
        partial formulation — ``impl`` here is ``auto | pallas | xla``).
      rows_pattern: apply the FLASHBLOCKROW sketch instead (the
        ``blockrow_apply`` analogue, including its extra √(d_pad/k_pad)
        scale).

    Returns:
      ``(k, n)`` fp32, REPLICATED across the mesh — ``array_equal`` to the
      single-device ``ops.sketch_apply(plan, A)`` / ``blockrow_apply`` at
      both streaming dtypes (the per-ℓ psum protocol; see module
      docstring).
    """
    if dtype is not None and dtype != plan.dtype:
        plan = plan.with_dtype(dtype)
    num = shard_count(mesh, axis)
    M_loc = check_row_partition(plan, num)
    n = A.shape[1]
    Ap = kref.pad_input(plan, A)                          # (d_pad, n)
    scale = plan.scale
    if rows_pattern:
        import math
        scale = plan.scale * math.sqrt(plan.d_pad / plan.k_pad)
        # pre-warm the lru-cached iid wiring table OUTSIDE the shard_map
        # trace (its concrete-eval guard cannot run under a tracer)
        fsk._blockrow_table(plan)

    def shard_fn(A_loc):
        lo = jax.lax.axis_index(axis) * M_loc
        parts = local_partial_apply(
            plan, A_loc, lo, impl=impl, tn=tn, rows_pattern=rows_pattern)
        parts = jax.lax.psum(parts, axis)   # exact: one contributor/element
        return _fold_scale_truncate(parts, plan, scale)

    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis, None),), out_specs=P(None, None),
        check_rep=False,
    )(Ap)


def sketch_apply_colsharded(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    mesh,
    axis: str,
    impl: str = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
):
    """Column-sharded ``Y = S A``: embarrassingly parallel, NO collective.

    Every device applies the full sketch to its ``n / P`` column slab
    (``P`` must divide ``n``); the output stays column-sharded over
    ``axis``.  Columns are independent in ``S A``, so this is
    ``array_equal`` to the single-device apply.
    """
    num = shard_count(mesh, axis)
    if A.shape[1] % num != 0:
        raise ValueError(
            f"column sharding needs P | n: P={num}, n={A.shape[1]}")

    def shard_fn(A_loc):
        return ops.sketch_apply(plan, A_loc, impl, tn, dtype)

    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(None, axis),), out_specs=P(None, axis),
        check_rep=False,
    )(A)


def sketch_apply_batched_sharded(
    plan: BlockPermPlan,
    A: jnp.ndarray,
    mesh,
    axis: str,
    impl: str = "auto",
    tn: Optional[int] = None,
    dtype: Optional[str] = None,
    *,
    row_index: Optional[jnp.ndarray] = None,
):
    """Batch-sharded ``out[b] = S @ A[b]``: the distributed GraSS layout.

    The leading batch axis of ``A (B, d, n)`` is partitioned over ``axis``
    (``P | B``); each device runs ONE fused batched (optionally
    gather-fused via ``row_index``) launch on its local stack — no
    collective, output batch-sharded.
    """
    num = shard_count(mesh, axis)
    if A.ndim < 3:
        raise ValueError(
            f"batched sharding expects a (B, ..., d, n) stack, got {A.shape}")
    if A.shape[0] % num != 0:
        raise ValueError(
            f"batch sharding needs P | B: P={num}, B={A.shape[0]}")

    if row_index is None:
        def shard_fn(A_loc):
            return ops.sketch_apply_batched(plan, A_loc, impl, tn, dtype)
        in_specs = (P(axis),)
        args = (A,)
    else:
        def shard_fn(A_loc, ri):
            return ops.sketch_apply_batched(plan, A_loc, impl, tn, dtype,
                                            row_index=ri)
        in_specs = (P(axis), P(None))
        args = (A, jnp.asarray(row_index, jnp.int32))

    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=in_specs, out_specs=P(axis),
        check_rep=False,
    )(*args)
