"""Multi-device FlashSketch: shard_map-mapped sketching + distributed
RandNLA on top of the ``sharding``/``launch.mesh`` substrate.

  sharded_apply — row- / column- / batch-sharded sketch application.  The
                  row-sharded path psums per-ℓ partials so the result is
                  BIT-EXACT against the single-device kernels (fp32 and
                  bf16); S is never gathered and no device materializes
                  all of A.
  dist_solvers  — distributed sketch-and-precondition least squares:
                  sharded sketch → replicated R → LSQR with shard_map'd
                  matvec/rmatvec injected into ``solvers.lsqr_operator``.

Cost model: ``roofline.sketch_model.dist_sketch_cost`` /
``modeled_dist_speedup`` charge the psum at ``hw.ICI_BW``;
``benchmarks/dist_bench.py`` gates exactness and modeled scaling.
"""
from repro.distributed.sharded_apply import (  # noqa: F401
    check_row_partition,
    local_partial_apply,
    partial_fits_vmem,
    partial_tables,
    plan_for_mesh,
    sketch_apply_batched_sharded,
    sketch_apply_colsharded,
    sketch_apply_sharded,
)
from repro.distributed.dist_solvers import (  # noqa: F401
    dist_sketch_precondition_lstsq,
    sharded_matvec_ops,
)
