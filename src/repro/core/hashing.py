"""Counter-based 32-bit mixing hashes, shared by ref oracle and Pallas kernel.

The paper (§5.2, App. D) generates all sketch randomness on the fly from a
fast 32-bit mixing hash of ``(seed, g, h, u, i)``.  We implement a murmur3 /
splitmix-style finalizer over uint32 lanes.  The *same* jnp function is used
by the pure-jnp reference (vectorized over index grids) and inside the Pallas
kernel body (vectorized over ``broadcasted_iota`` tiles), so the two produce
bit-identical streams — this is asserted in tests.

All ops are uint32 with wrap-around semantics (JAX guarantees modular
arithmetic for unsigned ints).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Golden-ratio derived odd constants (splitmix32 / murmur3 finalizer).
# NOTE: numpy scalars, not jnp arrays — Pallas kernel bodies must not capture
# array constants, and numpy scalars trace as literals.
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_GAMMA = np.uint32(0x9E3779B9)


def _u32(x):
    """Cast to uint32, preferring numpy scalars for python/numpy inputs."""
    if isinstance(x, (int, np.integer)):
        return np.uint32(x)
    return jnp.asarray(x).astype(jnp.uint32)


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """Murmur3 fmix32 finalizer: bijective mixing of a uint32 lane."""
    x = _u32(x)
    if isinstance(x, np.uint32):  # pure-python path (static tables)
        x = np.uint32(x) ^ np.uint32(int(x) >> 16)
        x = np.uint32((int(x) * int(_C1)) & 0xFFFFFFFF)
        x = np.uint32(x) ^ np.uint32(int(x) >> 13)
        x = np.uint32((int(x) * int(_C2)) & 0xFFFFFFFF)
        x = np.uint32(x) ^ np.uint32(int(x) >> 16)
        return x
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 13)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def combine(h: jnp.ndarray, v) -> jnp.ndarray:
    """Fold one more word into a running hash (boost::hash_combine flavor)."""
    h = _u32(h)
    v = _u32(v)
    MASK = 0xFFFFFFFF
    if isinstance(h, np.uint32) and isinstance(v, np.uint32):
        # Pure-python path, exact same arithmetic mod 2^32.
        vm = int(mix32(np.uint32((int(v) + int(_GAMMA)) & MASK)))
        x = int(h) ^ ((vm + int(_GAMMA) + ((int(h) << 6) & MASK) + (int(h) >> 2)) & MASK)
        return mix32(np.uint32(x & MASK))
    if isinstance(v, np.uint32):
        # Pre-fold v's mixing (and the +GAMMA) in python ints so no
        # numpy-scalar adds can overflow-warn; identical mod 2^32.
        vm = int(mix32(np.uint32((int(v) + int(_GAMMA)) & MASK)))
        v_plus = np.uint32((vm + int(_GAMMA)) & MASK)
    else:
        v_plus = mix32(v + _GAMMA) + _GAMMA
    return mix32(h ^ (v_plus + (h << 6) + (h >> 2)))


def hash_words(*words) -> jnp.ndarray:
    """Hash a sequence of uint32 words (scalars or broadcastable arrays)."""
    h = _u32(words[0])
    if isinstance(h, np.uint32):
        h = mix32(np.uint32((int(h) + int(_GAMMA)) & 0xFFFFFFFF))
    else:
        h = mix32(h + _GAMMA)
    for w in words[1:]:
        h = combine(h, w)
    return h


def hash_to_unit_sign(h: jnp.ndarray, bit: int = 31):
    """Extract a Rademacher ±1 (float32) from bit ``bit`` of a hash."""
    b = (h >> np.uint32(bit)) & np.uint32(1)
    return jnp.where(b == 0, 1.0, -1.0).astype(jnp.float32)


def hash_mod(h: jnp.ndarray, modulus) -> jnp.ndarray:
    """Reduce a hash to ``[0, modulus)`` as int32.

    ``modulus`` is a python int (static).  For power-of-two moduli this is a
    mask; otherwise a true mod (slightly biased for huge moduli; fine for
    sketching randomness — the bias is ≤ modulus/2^32).
    """
    m = int(modulus)
    if m & (m - 1) == 0:
        return (h & np.uint32(m - 1)).astype(jnp.int32)
    return (h % np.uint32(m)).astype(jnp.int32)


def hash_gaussian_pair(h: jnp.ndarray):
    """Two approximately-N(0,1) floats from one hash via Box-Muller.

    Used only by the on-the-fly dense-Gaussian baseline; sketch quality does
    not depend on tail perfection.
    """
    u1 = (mix32(h) >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))
    u2 = (mix32(h ^ _C1) >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))
    u1 = jnp.maximum(u1, 1e-7)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    theta = (2.0 * jnp.pi) * u2
    return r * jnp.cos(theta), r * jnp.sin(theta)
