"""Core BLOCKPERM-SJLT library (the paper's primary contribution).

Public API:

    from repro.core import make_plan, BlockPermPlan
    from repro.core.variants import make_sketch
    from repro.kernels.ops import sketch_apply, sketch_apply_t
"""
from repro.core.blockperm import BlockPermPlan, make_plan  # noqa: F401
