"""BLOCKPERM-SJLT parameterization (paper §4) and shared randomness helpers.

A plan freezes every static quantity of the sketch: logical dims (d, k),
padded dims, block grid (M, B_r, B_c), wiring params (a, b), intra-block
sparsity s, degree κ, and the seed.  The plan is hashable/pytree-static so it
can parameterize jitted functions and Pallas kernels.

Intra-block construction (row-partitioned SJLT, Kane–Nelson "block
construction", used by the paper's theory in App. A.3): the B_r rows of a
block are divided into s chunks of size B_r/s; nonzero i ∈ [s] of column u
lands in chunk i at row  ``i·(B_r/s) + hash(seed,g,h,u,i) mod (B_r/s)`` with
sign from an independent hash bit.  Exactly s nonzeros per column, one per
chunk ⇒ exactly κs nonzeros per column of S, magnitude 1/√(κs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import hashing, wiring


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class BlockPermPlan:
    """Static description of one BLOCKPERM-SJLT draw.

    Per the paper (§4, "we deal with general cases in practice by padding"),
    a requested sketch dimension ``k_req`` is rounded *up* to ``k = M·B_r``:
    the effective sketch has exactly κs nonzeros per column and is unbiased
    (truncating rows instead would break both properties).  The input dim d
    is zero-padded to ``d_pad = M·B_c`` (exact — padded coordinates are 0).
    """

    d: int                 # logical input dim
    k: int                 # effective sketch dim (= k_pad = M * Br)
    k_req: int             # sketch dim the caller asked for (k >= k_req)
    d_pad: int             # padded input dim  = M * Bc
    k_pad: int             # padded sketch dim = M * Br (== k)
    M: int                 # number of blocks per side (power of two)
    Br: int                # output block rows
    Bc: int                # input block cols
    kappa: int             # block degree (number of permutations)
    s: int                 # intra-block nonzeros per column (divides Br)
    seed: int
    a: int                 # wiring LCG multiplier
    b: int                 # wiring LCG offset

    @property
    def nnz_per_col(self) -> int:
        return self.kappa * self.s

    @property
    def scale(self) -> float:
        return 1.0 / math.sqrt(self.kappa * self.s)

    @property
    def chunk(self) -> int:
        """Row-partition chunk height B_r / s."""
        return self.Br // self.s

    def neighbors(self, g: int) -> Tuple[int, ...]:
        return tuple(
            wiring.neighbor_fused(g, ell + 1, self.a, self.b, self.M)
            for ell in range(self.kappa)
        )

    def describe(self) -> str:
        return (
            f"BlockPermPlan(d={self.d}->pad{self.d_pad}, k={self.k}->pad{self.k_pad}, "
            f"M={self.M}, Br={self.Br}, Bc={self.Bc}, kappa={self.kappa}, s={self.s}, "
            f"nnz/col={self.nnz_per_col}, seed={self.seed})"
        )


def make_plan(
    d: int,
    k: int,
    *,
    kappa: int = 4,
    s: int = 2,
    seed: int = 0,
    block_rows: Optional[int] = None,
    max_block_rows: int = 256,
) -> BlockPermPlan:
    """Choose a hardware-aligned block grid for (d, k) and freeze the plan.

    Strategy: pick M as a power of two so that B_r = k/M is ≤ max_block_rows
    (keeps the one-hot MXU contraction below the v5e ridge point, see
    DESIGN.md §2) while M ≥ κ (edge-disjointness needs κ ≤ M) and B_r ≥ s.
    d and k are padded up to M·B_c and M·B_r.
    """
    if d <= 0 or k <= 0:
        raise ValueError("d and k must be positive")
    if kappa < 1 or s < 1:
        raise ValueError("kappa and s must be >= 1")

    if block_rows is not None:
        Br = _next_pow2(block_rows)
    else:
        Br = min(_next_pow2(max(s, min(max_block_rows, k))), max_block_rows)
        Br = max(Br, _next_pow2(s))
    M = _next_pow2(max(1, math.ceil(k / Br)))
    # Ensure κ ≤ M: grow M (shrinking Br) until the wiring is realizable.
    while M < kappa:
        M *= 2
    Br = max(_next_pow2(math.ceil(k / M)), _next_pow2(s))
    if Br % s != 0:
        # s must divide Br for the row partition; round s down to a divisor.
        raise ValueError(f"s={s} must divide Br={Br} (both powers of two ok)")
    Bc = max(1, math.ceil(d / M))
    # Lane-align Bc when the block is big enough to care (TPU lane = 128).
    if Bc > 128:
        Bc = ((Bc + 127) // 128) * 128
    k_pad = M * Br
    d_pad = M * Bc
    a, b = wiring.derive_affine_params(seed, M)
    return BlockPermPlan(
        d=d, k=k_pad, k_req=k, d_pad=d_pad, k_pad=k_pad, M=M, Br=Br, Bc=Bc,
        kappa=kappa, s=s, seed=seed, a=a, b=b,
    )


# ---------------------------------------------------------------------------
# Shared randomness: destination rows and signs for the intra-block SJLT.
# These functions are used verbatim by ref.py and by the Pallas kernel body;
# tests assert bit-identical streams.
# ---------------------------------------------------------------------------

def block_rows_signs(plan: BlockPermPlan, g, h, u, i):
    """Destination row in [Br] and sign for nonzero i of column u of block (g,h).

    All of (g, h, u, i) may be arrays (broadcastable); returns (rows int32,
    signs float32).
    """
    hsh = hashing.hash_words(
        np.uint32(plan.seed),
        jnp.asarray(g, jnp.uint32),
        jnp.asarray(h, jnp.uint32),
        jnp.asarray(u, jnp.uint32),
        jnp.asarray(i, jnp.uint32),
    )
    chunk = plan.chunk
    rows = jnp.asarray(i, jnp.int32) * chunk + hashing.hash_mod(hsh, chunk)
    signs = hashing.hash_to_unit_sign(hsh)
    return rows, signs


def dense_block(plan: BlockPermPlan, g, h) -> jnp.ndarray:
    """Materialize Φ_{g,h} ∈ R^{Br×Bc} (entries ±1, unscaled) via one-hot sum.

    Used by the reference oracle and (tile-wise) inside the Pallas kernel.
    """
    u = jnp.arange(plan.Bc, dtype=jnp.int32)            # (Bc,)
    i = jnp.arange(plan.s, dtype=jnp.int32)             # (s,)
    rows, signs = block_rows_signs(
        plan, g, h, u[None, :], i[:, None]
    )                                                    # (s, Bc) each
    row_iota = jnp.arange(plan.Br, dtype=jnp.int32)      # (Br,)
    onehot = (row_iota[None, :, None] == rows[:, None, :]).astype(jnp.float32)
    phi = jnp.sum(onehot * signs[:, None, :], axis=0)    # (Br, Bc)
    return phi


def materialize_sketch_matrix(plan: BlockPermPlan) -> jnp.ndarray:
    """Full S ∈ R^{k_pad × d_pad} (dense), for tests and tiny benchmarks only."""
    pi = wiring.wiring_table(plan.seed, plan.M, plan.kappa)  # (κ, M)
    S = jnp.zeros((plan.k_pad, plan.d_pad), dtype=jnp.float32)
    for g in range(plan.M):
        for ell in range(plan.kappa):
            h = int(pi[ell, g])
            phi = dense_block(plan, g, h)
            S = S.at[
                g * plan.Br:(g + 1) * plan.Br,
                h * plan.Bc:(h + 1) * plan.Bc,
            ].add(phi)
    return S * plan.scale
