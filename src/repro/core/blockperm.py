"""BLOCKPERM-SJLT parameterization (paper §4) and shared randomness helpers.

A plan freezes every static quantity of the sketch: logical dims (d, k),
padded dims, block grid (M, B_r, B_c), wiring params (a, b), intra-block
sparsity s, degree κ, and the seed.  The plan is hashable/pytree-static so it
can parameterize jitted functions and Pallas kernels.

Intra-block construction (row-partitioned SJLT, Kane–Nelson "block
construction", used by the paper's theory in App. A.3): the B_r rows of a
block are divided into s chunks of size B_r/s; nonzero i ∈ [s] of column u
lands in chunk i at row  ``i·(B_r/s) + hash(seed,g,h,u,i) mod (B_r/s)`` with
sign from an independent hash bit.  Exactly s nonzeros per column, one per
chunk ⇒ exactly κs nonzeros per column of S, magnitude 1/√(κs).

Competitor GLOBAL families (``plan.family``): CountSketch (Higgins & Boman,
arXiv:2508.14209) and sparse-graph sketches (Hu et al., arXiv:2102.05758)
place their s nonzeros per column anywhere in [k_pad] — no block-permutation
structure.  They are realized on the SAME plan record by forcing κ = M (every
input block may feed every output block; the wiring table is all-blocks) with
a global row partition: nonzero i of global column u lands in global chunk i
at row ``i·(k_pad/s) + hash(seed, TAG, u, i) mod (k_pad/s)``, magnitude 1/√s.
CountSketch is the s = 1 case; the sparse-graph family is a column-degree-s
bipartite expander (s = 4 default).  Because κ == M, every downstream
consumer — kernels, VMEM ladders, tuner keys, the roofline — prices the
all-blocks structure honestly with zero family-specific branches.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import hashing, wiring
from repro.core import precision as precision_mod


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


# VMEM working-set budget for the v2 fused kernel (out of ~16 MB/core),
# shared with kernels.tune — the planner sizes blocks against it, the tuner
# sizes tiles against it.
VMEM_BUDGET_BYTES = 12 * 2**20

# Smallest column-tile width the tuner/planner will consider; the planner's
# fit loop and kernels.tune's sweeps must agree on it.
MIN_TILE_N = 8

# The kernel variants (single source; tune/sketch_model/benchmarks reuse it).
SKETCH_VARIANTS = ("fwd", "transpose", "blockrow")

# Sketch families a plan can describe.  "blockperm" is the paper's
# BLOCKPERM-SJLT; the GLOBAL families (CountSketch / sparse-graph) have no
# block-permutation structure and are realized as κ == M plans (see module
# docstring).  The blockrow op and the row-sharded partial are
# blockperm-wiring-specific and reject global-family plans in lowering.
GLOBAL_FAMILIES = ("countsketch", "graph")
FAMILIES = ("blockperm",) + GLOBAL_FAMILIES

# Canonical per-column nonzero count of each family (the construction the
# name PROMISES: CountSketch is s=1 by definition, the sparse-graph sketch
# is the s=4 expander).  Single source for the variants registry and the
# family-parametric solver entry points — a caller asking for
# family="countsketch" without pinning s must get THE CountSketch, not a
# blockperm-default s riding a global plan.
FAMILY_DEFAULT_S = {"blockperm": 2, "countsketch": 1, "graph": 4}

# Hash tag separating the global-family row/sign stream from every other
# stream in the repo (0xB10C blockrow wiring, 0x5EED blockrow rows,
# 0x5117 unstructured SJLT, 0xFAD/0x5A3 SRHT).
GLOBAL_FAMILY_TAG = 0x610B

# Gather-fused variants: the input stays in HBM and masked rows are DMA'd
# straight into a VMEM gather scratch (no A[mask] intermediate), so the
# pipelined input blocks are replaced by one (κ·B_c, tn) scratch buffer.
GATHER_VARIANTS = ("fwd_gather", "blockrow_gather")


def fused_variant_bytes(kappa: int, Br: int, Bc: int, tn: int,
                        itemsize: int = 4, variant: str = "fwd",
                        phi_itemsize: Optional[int] = None) -> int:
    """v2 VMEM footprint of one kernel variant: stacked Φ scratch +
    double-buffered pipelined input blocks (or the row-gather scratch for
    the ``*_gather`` variants) + output tile.  Must track the
    scratch/pipeline layout in kernels/flashsketch.py.

    ``itemsize`` is the streamed-operand width (``precision.itemsize``);
    ``phi_itemsize`` the Φ-scratch width, which differs under fp8
    policies (Φ is held in the compute dtype the fp8 stream is upcast
    to, ``precision.compute_itemsize``) — defaults to ``itemsize``."""
    phi = kappa * Br * Bc * (itemsize if phi_itemsize is None
                             else phi_itemsize)
    if variant == "transpose":
        ins = 2 * kappa * Br * tn * itemsize
        out = Bc * tn * 4
    elif variant in GATHER_VARIANTS:
        # input lives in HBM; rows are DMA'd into a single-buffered
        # (κ·Bc, tn) gather scratch
        ins = kappa * Bc * tn * itemsize
        out = Br * tn * 4
    else:                                   # fwd / blockrow
        ins = 2 * kappa * Bc * tn * itemsize
        out = Br * tn * 4
    return phi + ins + out


def fused_working_set_bytes(kappa: int, Br: int, Bc: int, tn: int,
                            itemsize: int = 4,
                            phi_itemsize: Optional[int] = None) -> int:
    """Worst case of ``fused_variant_bytes`` over all kernel variants."""
    return max(
        fused_variant_bytes(kappa, Br, Bc, tn, itemsize, v, phi_itemsize)
        for v in ("fwd", "transpose")
    )


def _aligned_bc(d: int, M: int) -> int:
    """Input block width for M blocks, lane-aligned (TPU lane = 128)."""
    Bc = max(1, math.ceil(d / M))
    if Bc > 128:
        Bc = ((Bc + 127) // 128) * 128
    return Bc


@dataclasses.dataclass(frozen=True)
class BlockPermPlan:
    """Static description of one BLOCKPERM-SJLT draw.

    Per the paper (§4, "we deal with general cases in practice by padding"),
    a requested sketch dimension ``k_req`` is rounded *up* to ``k = M·B_r``:
    the effective sketch has exactly κs nonzeros per column and is unbiased
    (truncating rows instead would break both properties).  The input dim d
    is zero-padded to ``d_pad = M·B_c`` (exact — padded coordinates are 0).
    """

    d: int                 # logical input dim
    k: int                 # effective sketch dim (= k_pad = M * Br)
    k_req: int             # sketch dim the caller asked for (k >= k_req)
    d_pad: int             # padded input dim  = M * Bc
    k_pad: int             # padded sketch dim = M * Br (== k)
    M: int                 # number of blocks per side (power of two)
    Br: int                # output block rows
    Bc: int                # input block cols
    kappa: int             # block degree (number of permutations)
    s: int                 # intra-block nonzeros per column (divides Br)
    seed: int
    a: int                 # wiring LCG multiplier
    b: int                 # wiring LCG offset
    dtype: str = "float32"  # streaming-precision POLICY (canonical name in
                            # core.precision.POLICIES: "float32", "bfloat16",
                            # "fp8_e4m3", "fp8_e5m2", "fp8_e4m3_sr",
                            # "fp8_e5m2_sr"; accumulation is always fp32 —
                            # low-precision streams justified by Jeendgar
                            # et al., PAPERS.md arXiv 2606.20195)
    family: str = "blockperm"  # "blockperm" | "countsketch" | "graph";
                               # global families carry kappa == M (all-blocks
                               # wiring) and a k_pad-wide row partition.

    @property
    def is_global(self) -> bool:
        """Whether the plan is a global (non-block-permutation) family."""
        return self.family in GLOBAL_FAMILIES

    @property
    def nnz_per_col(self) -> int:
        # global families: exactly s nonzeros per column of the FULL S
        # (one per k_pad/s chunk); blockperm: κ·s (s per participating block).
        return self.s if self.is_global else self.kappa * self.s

    @property
    def precision(self) -> precision_mod.Precision:
        """The resolved :class:`~repro.core.precision.Precision` record —
        the single source for every dtype/itemsize/rounding/band question
        about this plan's streaming policy."""
        return precision_mod.resolve(self.dtype)

    @property
    def stream_dtype(self):
        """jnp dtype the input is streamed in (accumulate is always fp32)."""
        return self.precision.stream_dtype

    @property
    def stream_itemsize(self) -> int:
        """Bytes per streamed element (1 for fp8, 2 for bf16, 4 for fp32)."""
        return self.precision.itemsize

    @property
    def scale(self) -> float:
        # 1/√(nnz per column): 1/√s for the global families, 1/√(κs) else.
        return 1.0 / math.sqrt(self.nnz_per_col)

    @property
    def chunk(self) -> int:
        """Row-partition chunk height: B_r/s per block for blockperm,
        k_pad/s globally for the global families."""
        return self.k_pad // self.s if self.is_global else self.Br // self.s

    def neighbors(self, g: int) -> Tuple[int, ...]:
        if self.is_global:
            return tuple(range(self.M))        # every input block feeds g
        return tuple(
            wiring.neighbor_fused(g, ell + 1, self.a, self.b, self.M)
            for ell in range(self.kappa)
        )

    def describe(self) -> str:
        fam = "" if self.family == "blockperm" else f"family={self.family}, "
        return (
            f"BlockPermPlan({fam}d={self.d}->pad{self.d_pad}, k={self.k}->pad{self.k_pad}, "
            f"M={self.M}, Br={self.Br}, Bc={self.Bc}, kappa={self.kappa}, s={self.s}, "
            f"nnz/col={self.nnz_per_col}, dtype={self.dtype}, seed={self.seed})"
        )

    def with_dtype(self, dtype) -> "BlockPermPlan":
        """Same sketch draw, different streaming-precision policy.

        Accepts a canonical policy name, a registered alias (``"fp32"``,
        ``"bf16"``) or a :class:`~repro.core.precision.Precision` record;
        the plan stores the canonical name (tuner-cache/snapshot-stable)."""
        return dataclasses.replace(self, dtype=_check_dtype(dtype))


def _check_dtype(dtype) -> str:
    """Validate a streaming-precision policy; returns its canonical name."""
    return precision_mod.canonical(dtype)


def make_plan(
    d: int,
    k: int,
    *,
    kappa: int = 4,
    s: int = 2,
    seed: int = 0,
    block_rows: Optional[int] = None,
    max_block_rows: int = 256,
    dtype: str = "float32",
    family: str = "blockperm",
) -> BlockPermPlan:
    """Choose a hardware-aligned block grid for (d, k) and freeze the plan.

    Strategy: pick M as a power of two so that B_r = k/M is ≤ max_block_rows
    (keeps the one-hot MXU contraction below the v5e ridge point, see
    DESIGN.md §2) while M ≥ κ (edge-disjointness needs κ ≤ M) and B_r ≥ s.
    d and k are padded up to M·B_c and M·B_r.

    Args:
      d: logical input dimension (rows of the matrices to be sketched).
      k: REQUESTED sketch dimension; the effective ``plan.k`` is rounded
        UP to ``M·B_r`` (never truncated — truncation would break the
        exactly-κs-nonzeros-per-column property and unbiasedness).
      kappa: block degree κ ≥ 1 — number of permuted block patterns whose
        union forms S.  More κ → better embedding, more HBM traffic
        (input streamed κ times).
      s: intra-block nonzeros per column; must divide the resulting B_r
        (powers of two always do).  κ·s is the total nonzeros per column
        of S, each of magnitude 1/√(κs).
      seed: master seed; all randomness (wiring + intra-block hashes)
        derives from it deterministically.
      block_rows: pin B_r explicitly (rounded up to a power of two);
        disables the VMEM-budget auto-shrink.  The pin is HONORED: the
        effective ``plan.Br`` is exactly the rounded pin (M grows as
        needed to keep ``M·B_r ≥ k`` and ``κ ≤ M``), and an unrealizable
        pin (``s`` does not divide the rounded value) raises
        ``ValueError`` instead of being silently clamped.
      max_block_rows: cap on the auto-chosen B_r.
      dtype: streaming-precision policy — any name registered in
        ``repro.core.precision`` (``"float32"`` default, ``"bfloat16"``,
        ``"fp8_e4m3"``, ``"fp8_e5m2"``, ``"fp8_e4m3_sr"``,
        ``"fp8_e5m2_sr"``; aliases ``"fp32"``/``"bf16"`` accepted and
        canonicalized).  Controls only how kernels STREAM the input from
        HBM (``plan.stream_dtype``, rounded per the policy's mode) —
        Φ entries (±1/0) are exact in every policy and accumulation is
        always fp32, so bf16 halves and fp8 quarters the dominant memory
        term at a rounding cost on A.  Unknown policies raise
        ``ValueError``.
      family: ``"blockperm"`` (default), or a GLOBAL family —
        ``"countsketch"`` / ``"graph"``.  Global families place their s
        nonzeros per column anywhere in [k_pad] (no block structure), so
        the plan is frozen with κ = M (all-blocks wiring; the ``kappa``
        argument is ignored) and ``s`` must be a power of two so the
        global row partition k_pad/s is exact.

    Returns:
      A frozen, hashable ``BlockPermPlan`` suitable as a static jit
      argument; pass it to ``repro.kernels.ops.sketch_apply`` (valid
      ``impl=`` values there: ``"auto" | "pallas" | "pallas_v1" | "xla"``).
    """
    if d <= 0 or k <= 0:
        raise ValueError("d and k must be positive")
    if kappa < 1 or s < 1:
        raise ValueError("kappa and s must be >= 1")
    dtype = _check_dtype(dtype)
    if family not in FAMILIES:
        raise ValueError(f"family must be one of {FAMILIES}, got {family!r}")

    if family in GLOBAL_FAMILIES:
        return _make_global_plan(d, k, s=s, seed=seed, block_rows=block_rows,
                                 max_block_rows=max_block_rows, dtype=dtype,
                                 family=family)

    if block_rows is not None:
        # Honor the pin (rounded up to a power of two).  A pin that cannot
        # host the row partition raises — it must never be silently clamped
        # (autotune_plan's B_r sweep relies on distinct pins producing
        # distinct grids).
        Br = _next_pow2(block_rows)
        if Br % s != 0:
            raise ValueError(
                f"block_rows={block_rows} (rounded to Br={Br}) is not "
                f"realizable: s={s} must divide Br")
        M = _next_pow2(max(1, math.ceil(k / Br)))
        # κ ≤ M is required for edge-disjoint wiring; with Br pinned the
        # only degree of freedom is M (k_pad = M·Br grows accordingly).
        while M < kappa:
            M *= 2
    else:
        Br = min(_next_pow2(max(s, min(max_block_rows, k))), max_block_rows)
        Br = max(Br, _next_pow2(s))
        M = _next_pow2(max(1, math.ceil(k / Br)))
        # Ensure κ ≤ M: grow M (shrinking Br) until the wiring is realizable.
        while M < kappa:
            M *= 2
        Br = max(_next_pow2(math.ceil(k / M)), _next_pow2(s))
        if Br % s != 0:
            # s must divide Br for the row partition; round s down to a divisor.
            raise ValueError(f"s={s} must divide Br={Br} (both powers of two ok)")
    Bc = _aligned_bc(d, M)
    # Keep the fused v2 working set (stacked Φ ∝ κ·Br·Bc plus pipelined
    # blocks ∝ Bc, see kernels/flashsketch) resident in VMEM by trading Br
    # for M: halving Br doubles M and halves Bc, shrinking both terms while
    # k_pad = M·Br is unchanged.  Only when the caller did not pin block_rows.
    if block_rows is None:
        while (fused_working_set_bytes(kappa, Br, Bc, tn=MIN_TILE_N)
               > VMEM_BUDGET_BYTES
               and Br // 2 >= max(_next_pow2(s), 1)):
            Br //= 2
            M *= 2
            Bc = _aligned_bc(d, M)
    k_pad = M * Br
    d_pad = M * Bc
    a, b = wiring.derive_affine_params(seed, M)
    return BlockPermPlan(
        d=d, k=k_pad, k_req=k, d_pad=d_pad, k_pad=k_pad, M=M, Br=Br, Bc=Bc,
        kappa=kappa, s=s, seed=seed, a=a, b=b, dtype=dtype,
    )


def _make_global_plan(d: int, k: int, *, s: int, seed: int,
                      block_rows: Optional[int], max_block_rows: int,
                      dtype: str, family: str) -> BlockPermPlan:
    """Grid selection for the GLOBAL families (CountSketch / sparse-graph).

    Same hardware alignment as the blockperm path, but the frozen degree is
    κ = M: the wiring is all-blocks (kernels use a tiled-arange table), so
    the fused working set carries a full-width stacked Φ of (B_r, M·B_c) =
    (B_r, d_pad).  The VMEM shrink loop still converges — halving B_r
    doubles M and halves B_c, shrinking the Φ term — and the downstream
    v2→v1 ladder covers plans it cannot save.  ``s`` must be a power of
    two with ``s ≤ k_pad`` so the global row partition k_pad/s is exact
    (``hash_mod``'s power-of-two mask path then applies everywhere).
    """
    if s & (s - 1):
        raise ValueError(
            f"family={family!r} requires s to be a power of two "
            f"(the global row partition is k_pad/s), got s={s}")
    if block_rows is not None:
        Br = _next_pow2(block_rows)
        M = _next_pow2(max(1, math.ceil(k / Br)))
    else:
        Br = min(_next_pow2(max(1, min(max_block_rows, k))), max_block_rows)
        M = _next_pow2(max(1, math.ceil(k / Br)))
    Bc = _aligned_bc(d, M)
    if block_rows is None:
        # κ = M tracks the split: the working set is evaluated at the
        # CURRENT M each iteration (Φ = M·Br·Bc shrinks as Br halves).
        while (fused_working_set_bytes(M, Br, Bc, tn=MIN_TILE_N)
               > VMEM_BUDGET_BYTES and Br // 2 >= 1):
            Br //= 2
            M *= 2
            Bc = _aligned_bc(d, M)
    k_pad = M * Br
    if s > k_pad:
        raise ValueError(
            f"family={family!r}: s={s} exceeds the padded sketch dim "
            f"k_pad={k_pad} — the row partition needs s <= k_pad")
    d_pad = M * Bc
    a, b = wiring.derive_affine_params(seed, M)   # unused by the family,
    return BlockPermPlan(                         # kept for record parity
        d=d, k=k_pad, k_req=k, d_pad=d_pad, k_pad=k_pad, M=M, Br=Br, Bc=Bc,
        kappa=M, s=s, seed=seed, a=a, b=b, dtype=dtype, family=family,
    )


# ---------------------------------------------------------------------------
# Shared randomness: destination rows and signs for the intra-block SJLT.
# These functions are used verbatim by ref.py and by the Pallas kernel body;
# tests assert bit-identical streams.
# ---------------------------------------------------------------------------

def block_rows_signs(plan: BlockPermPlan, g, h, u, i):
    """Destination row in [Br] and sign for nonzero i of column u of block (g,h).

    Args:
      plan: the frozen sketch draw (supplies seed and chunk height B_r/s).
      g, h: output/input block indices in [M].
      u: column index within the block, in [B_c].
      i: nonzero index within the column, in [s] (selects the row chunk).
      All of (g, h, u, i) may be arrays (broadcastable against each other);
      integer dtypes are cast to uint32 for hashing.

    Returns:
      ``(rows, signs)``: int32 rows in ``[0, B_r)`` (nonzero i lands in
      chunk i, i.e. ``rows // (B_r/s) == i``) and float32 signs in {±1}.
      Both the jnp reference oracle and the Pallas kernel body call THIS
      function, so the streams are bit-identical by construction.
    """
    hsh = hashing.hash_words(
        np.uint32(plan.seed),
        jnp.asarray(g, jnp.uint32),
        jnp.asarray(h, jnp.uint32),
        jnp.asarray(u, jnp.uint32),
        jnp.asarray(i, jnp.uint32),
    )
    chunk = plan.chunk
    rows = jnp.asarray(i, jnp.int32) * chunk + hashing.hash_mod(hsh, chunk)
    signs = hashing.hash_to_unit_sign(hsh)
    return rows, signs


def dense_block(plan: BlockPermPlan, g, h) -> jnp.ndarray:
    """Materialize Φ_{g,h} ∈ R^{Br×Bc} via one-hot sum.

    Args:
      plan: the frozen sketch draw.
      g, h: scalar block indices in [M] (python ints or traced scalars).

    Returns:
      ``(Br, Bc)`` float32 array with entries in {-1, 0, +1} — exactly s
      nonzeros per column, one per B_r/s-row chunk — WITHOUT the global
      1/√(κs) scale.  Used by the reference oracle and (tile-wise) inside
      the Pallas kernel; bit-exactness between the two is tested.
    """
    u = jnp.arange(plan.Bc, dtype=jnp.int32)            # (Bc,)
    i = jnp.arange(plan.s, dtype=jnp.int32)             # (s,)
    rows, signs = block_rows_signs(
        plan, g, h, u[None, :], i[:, None]
    )                                                    # (s, Bc) each
    row_iota = jnp.arange(plan.Br, dtype=jnp.int32)      # (Br,)
    onehot = (row_iota[None, :, None] == rows[:, None, :]).astype(jnp.float32)
    phi = jnp.sum(onehot * signs[:, None, :], axis=0)    # (Br, Bc)
    return phi


def global_rows_signs(plan: BlockPermPlan, u, i):
    """Destination GLOBAL row in [k_pad] and sign for nonzero i of global
    column u — the CountSketch / sparse-graph construction.

    Args:
      plan: a GLOBAL-family plan (supplies seed and the global chunk
        height k_pad/s).
      u: GLOBAL column index in [d_pad].
      i: nonzero index within the column, in [s] (selects the row chunk;
        CountSketch is the s = 1 case).
      Both may be arrays (broadcastable against each other).

    Returns:
      ``(rows, signs)``: int32 global rows in ``[0, k_pad)`` (nonzero i
      lands in chunk i: ``rows // (k_pad/s) == i``) and float32 signs in
      {±1}.  The jnp oracle, ``dense_global_block`` and the Pallas kernel
      body all call THIS function — bit-identical streams by construction.
    """
    hsh = hashing.hash_words(
        np.uint32(plan.seed),
        np.uint32(GLOBAL_FAMILY_TAG),
        jnp.asarray(u, jnp.uint32),
        jnp.asarray(i, jnp.uint32),
    )
    chunk = plan.chunk                                   # k_pad // s
    rows = jnp.asarray(i, jnp.int32) * chunk + hashing.hash_mod(hsh, chunk)
    signs = hashing.hash_to_unit_sign(hsh)
    return rows, signs


def dense_global_block(plan: BlockPermPlan, g, h) -> jnp.ndarray:
    """Block (g, h) of the GLOBAL family's S as a dense ``(Br, Bc)`` tile
    (unscaled): the rows of S in ``[g·Br, (g+1)Br)`` restricted to columns
    ``[h·Bc, (h+1)Bc)``.  Nonzeros whose global row lands outside block g
    are masked out by the row comparison — the fused kernel sums these
    tiles over all M values of h, recovering every nonzero exactly once.
    """
    u = h * plan.Bc + jnp.arange(plan.Bc, dtype=jnp.int32)   # global columns
    i = jnp.arange(plan.s, dtype=jnp.int32)                  # (s,)
    rows, signs = global_rows_signs(plan, u[None, :], i[:, None])  # (s, Bc)
    local = rows - g * plan.Br
    row_iota = jnp.arange(plan.Br, dtype=jnp.int32)          # (Br,)
    onehot = (row_iota[None, :, None] == local[:, None, :]).astype(jnp.float32)
    return jnp.sum(onehot * signs[:, None, :], axis=0)       # (Br, Bc)


def materialize_sketch_matrix(plan: BlockPermPlan) -> jnp.ndarray:
    """Full S ∈ R^{k_pad × d_pad} as a DENSE fp32 array — tests and tiny
    benchmarks only (O(k_pad · d_pad) memory defeats the whole point of
    the sketch at real sizes).  Includes the 1/√(κs) scale (1/√s for the
    global families), so ``S @ A_padded`` equals
    ``ops.sketch_apply(plan, A)`` up to fp32 rounding regardless of impl;
    the streaming ``dtype`` knob does not apply here (dense math is fp32
    throughout).
    """
    if plan.is_global:
        u = jnp.arange(plan.d_pad, dtype=jnp.int32)
        i = jnp.arange(plan.s, dtype=jnp.int32)
        rows, signs = global_rows_signs(plan, u[None, :], i[:, None])
        S = jnp.zeros((plan.k_pad, plan.d_pad), dtype=jnp.float32)
        for ii in range(plan.s):
            S = S.at[rows[ii], u].add(signs[ii])
        return S * plan.scale
    pi = wiring.wiring_table(plan.seed, plan.M, plan.kappa)  # (κ, M)
    S = jnp.zeros((plan.k_pad, plan.d_pad), dtype=jnp.float32)
    for g in range(plan.M):
        for ell in range(plan.kappa):
            h = int(pi[ell, g])
            phi = dense_block(plan, g, h)
            S = S.at[
                g * plan.Br:(g + 1) * plan.Br,
                h * plan.Bc:(h + 1) * plan.Bc,
            ].add(phi)
    return S * plan.scale
