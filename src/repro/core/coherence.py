"""Block and neighborhood coherence (paper Defs. 3.2, 6.1, A.3, A.4).

These quantities drive the OSE guarantee (Thm 6.2) and are verified against
the sandwich bound (Lemma A.9) and the κ-smoothing bound (Prop A.11) in the
property tests and in ``benchmarks/theory_validation``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import wiring
from repro.core.blockperm import BlockPermPlan


def _as_blocks(U: np.ndarray, M: int) -> np.ndarray:
    """Partition rows of U (d, r) into M contiguous blocks -> (M, d/M, r)."""
    d = U.shape[0]
    if d % M != 0:
        pad = M * ((d + M - 1) // M) - d
        U = np.concatenate([U, np.zeros((pad, U.shape[1]), U.dtype)], axis=0)
    return U.reshape(M, -1, U.shape[1])


def block_coherence(U: np.ndarray, M: int) -> float:
    """μ_blk(U) = M · max_h ‖U^(h)‖₂²  (Def. 3.2)."""
    blocks = _as_blocks(np.asarray(U), M)
    norms = [np.linalg.norm(b, 2) ** 2 for b in blocks]
    return float(M * max(norms))


def neighborhood_coherence(U: np.ndarray, pi: np.ndarray) -> float:
    """μ_nbr(U;π) = (M/κ) · max_g ‖U_N(g)‖₂²  (Def. 6.1).

    ``pi``: (κ, M) wiring table (π_ℓ(g) = pi[ℓ-1, g]).
    """
    kappa, M = pi.shape
    blocks = _as_blocks(np.asarray(U), M)
    worst = 0.0
    for g in range(M):
        stacked = np.concatenate([blocks[pi[ell, g]] for ell in range(kappa)], axis=0)
        worst = max(worst, np.linalg.norm(stacked, 2) ** 2)
    return float(M / kappa * worst)


def neighborhood_coherence_plan(U: np.ndarray, plan: BlockPermPlan) -> float:
    pi = wiring.wiring_table(plan.seed, plan.M, plan.kappa)
    return neighborhood_coherence(U, pi)


def vector_block_coherence(x: np.ndarray, M: int) -> float:
    """μ_blk(x) for vectors (Def. A.3)."""
    x = np.asarray(x).reshape(-1)
    blocks = _as_blocks(x[:, None], M)[..., 0]
    nx = float(np.sum(x ** 2))
    return float(M * max(np.sum(b ** 2) for b in blocks) / nx)


def smoothing_bound(mu_blk: float, kappa: int, M: int, r: int,
                    delta: float = 0.1, C: float = 1.0) -> float:
    """Prop. A.11 upper bound: 1 + C(√(μ_blk·L/κ) + μ_blk·L/κ), L=log(2Mr/δ)."""
    L = np.log(2.0 * M * max(r, 1) / delta)
    t = mu_blk * L / kappa
    return float(1.0 + C * (np.sqrt(t) + t))


def ose_sketch_dim_bound(mu_nbr: float, eps: float, r: int,
                         delta: float = 0.05, C: float = 1.0) -> float:
    """Thm 6.2 condition (5): k ≥ C·μ_nbr·ε⁻²·(r + log 1/δ)."""
    t = r + np.log(1.0 / delta)
    return float(C * mu_nbr / (eps ** 2) * t)


def ose_sparsity_bound(eps: float, r: int, delta: float = 0.05,
                       C: float = 1.0) -> float:
    """Thm 6.2 condition (5): κs ≥ C·ε⁻¹·(r + log 1/δ)."""
    t = r + np.log(1.0 / delta)
    return float(C / eps * t)


def ose_spectral_error(U: np.ndarray, SU: np.ndarray) -> float:
    """‖Uᵀ Sᵀ S U − I‖₂ for orthonormal U (Def. 3.1 / §F.1.2)."""
    G = np.asarray(SU).T @ np.asarray(SU)
    r = G.shape[0]
    return float(np.linalg.norm(G - np.eye(r), 2))


def gram_rel_error(A: np.ndarray, SA: np.ndarray) -> float:
    """‖(SA)ᵀSA − AᵀA‖_F / ‖AᵀA‖_F (paper §F.1.1)."""
    A = np.asarray(A)
    SA = np.asarray(SA)
    G = A.T @ A
    Gh = SA.T @ SA
    denom = np.linalg.norm(G, "fro")
    err = np.linalg.norm(Gh - G, "fro")
    return float(err / denom) if denom > 0 else float(err)
