"""Baseline sketch families from the paper's evaluation (§7.1) plus ablation
variants.  All are implemented in JAX so every paper table/figure can be
reproduced:

  1. Dense Gaussian (cuBLAS baseline)       -> ``DenseGaussianSketch``
  2. Dense Rademacher                        -> ``DenseRademacherSketch``
  3. Unstructured SJLT (cuSPARSE / GraSS)    -> ``SJLTSketch`` (scatter-add
     semantics, s nonzeros per column at uniform rows of the FULL output)
  4. Subsampled randomized Hadamard (SRHT)   -> ``SRHTSketch`` (FWHT-based)
  5. BLOCKPERM-SJLT (ours)                   -> ``BlockPermSketch``
  6. Localized / block-diagonal SJLT (κ=1)   -> ``BlockPermSketch(kappa=1)``
  7. FLASHBLOCKROW (App. C)                  -> ``BlockRowSketch``
  8. CountSketch (Higgins & Boman, fused)    -> ``CountSketch`` (a GLOBAL
     family: 1 nonzero per column anywhere in [k], lowered through the
     engine as a κ=M plan — same kernels, ladders, tuner, snapshot)
  9. Sparse-graph sketch (Hu et al.)         -> ``GraphSketch`` (global,
     s nonzeros per column = a column-degree-s bipartite expander)

Each sketch exposes ``apply(A) -> (k, n)`` for ``A: (d, n)`` and reports its
cost model (flops, bytes moved, whether it needs S materialized) so the
roofline benchmarks can model TPU execution.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.blockperm import (BlockPermPlan, FAMILY_DEFAULT_S,
                                  make_plan)
from repro.kernels import lowering as klowering
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.roofline import sketch_model


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Idealized TPU cost terms for one application Y = S A (fp32)."""

    flops: float           # useful MACs*2
    hbm_bytes: float       # A reads + Y writes + S reads (if materialized)
    materializes_S: bool


class SketchBase:
    name: str = "base"
    # Distributional contract: E[SᵀS] = I over the seed draw.  Part of the
    # registry-wide conformance battery (tests/test_variant_conformance.py);
    # a family that deliberately trades unbiasedness away (blockrow's
    # single-pass gather) declares it here instead of special-casing tests.
    unbiased: bool = True

    def __init__(self, d: int, k: int, seed: int = 0):
        self.d = int(d)
        self.k = int(k)
        self.seed = int(seed)

    def apply(self, A: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def apply_gather(self, A: jnp.ndarray, row_index) -> jnp.ndarray:
        """``Y = S @ A[row_index, :]`` for ``A (d_src, n)``.

        Base implementation materializes the gather; families with fused
        index-streamed kernels (blockperm, blockrow) override it so the
        GraSS sparsify→sketch step never writes the intermediate.
        """
        return self.apply(A[jnp.asarray(row_index)])

    def apply_batched(self, A: jnp.ndarray) -> jnp.ndarray:
        """``out[b] = S @ A[b]`` for a stack ``(..., d, n)``.

        Every family's apply is column-wise linear, so the batch folds into
        the column axis of ONE apply — no per-example launches.
        """
        batch = A.shape[:-2]
        d, n = A.shape[-2:]
        flat = jnp.moveaxis(A.reshape((-1, d, n)), 0, 1).reshape(d, -1)
        Y = self.apply(flat)
        return jnp.moveaxis(Y.reshape(Y.shape[0], -1, n), 1, 0).reshape(
            *batch, Y.shape[0], n)

    def cost_model(self, n: int) -> CostModel:
        raise NotImplementedError

    def lowering_for(self, n: int, **spec_kwargs):
        """The ``kernels.lowering.Lowering`` this family would launch for
        a width-``n`` apply, or ``None`` for families without a FlashSketch
        kernel (dense/SJLT/SRHT baselines run as plain XLA ops)."""
        return None

    def describe(self) -> str:
        return f"{self.name}(d={self.d}, k={self.k})"


class DenseGaussianSketch(SketchBase):
    """S_ij ~ N(0, 1/k); applied as a dense GEMM (the cuBLAS baseline)."""

    name = "dense_gaussian"

    def __init__(self, d, k, seed=0):
        super().__init__(d, k, seed)
        key = jax.random.PRNGKey(seed)
        self._S = jax.random.normal(key, (self.k, self.d), jnp.float32) / math.sqrt(self.k)

    def apply(self, A):
        return self._S @ A

    def cost_model(self, n: int) -> CostModel:
        return CostModel(
            flops=2.0 * self.k * self.d * n,
            hbm_bytes=4.0 * (self.d * n + self.k * n + self.k * self.d),
            materializes_S=True,
        )


class DenseRademacherSketch(SketchBase):
    name = "dense_rademacher"

    def __init__(self, d, k, seed=0):
        super().__init__(d, k, seed)
        key = jax.random.PRNGKey(seed)
        self._S = jax.random.rademacher(key, (self.k, self.d), jnp.float32) / math.sqrt(self.k)

    def apply(self, A):
        return self._S @ A

    def cost_model(self, n: int) -> CostModel:
        return CostModel(
            flops=2.0 * self.k * self.d * n,
            hbm_bytes=4.0 * (self.d * n + self.k * n + self.k * self.d),
            materializes_S=True,
        )


class SJLTSketch(SketchBase):
    """Unstructured SJLT: s nonzeros per column at uniform rows of [k].

    Matches the GraSS CUDA kernel / cuSPARSE semantics (global scatter-add).
    In JAX we implement the scatter with segment_sum; the cost model charges
    the global-atomic traffic the paper attributes to this pattern.
    """

    name = "sjlt"

    def __init__(self, d, k, s: int = 8, seed: int = 0):
        super().__init__(d, k, seed)
        self.s = int(s)
        u = jnp.arange(self.d, dtype=jnp.uint32)[:, None]
        i = jnp.arange(self.s, dtype=jnp.uint32)[None, :]
        hsh = hashing.hash_words(np.uint32(seed), np.uint32(0x5117), u, i)
        self._rows = hashing.hash_mod(hsh, self.k)            # (d, s)
        self._signs = hashing.hash_to_unit_sign(hsh)          # (d, s)

    def apply(self, A):
        # Y[r] += sign * A[u]  for each (u, i) — the scatter-add pattern.
        n = A.shape[1]
        contrib = (self._signs[..., None] * A[:, None, :]).reshape(-1, n)
        rows = self._rows.reshape(-1)
        Y = jax.ops.segment_sum(contrib, rows, num_segments=self.k)
        return Y / math.sqrt(self.s)

    def cost_model(self, n: int) -> CostModel:
        # Global scatter: every input element issues s read-modify-writes.
        return CostModel(
            flops=2.0 * self.s * self.d * n,
            hbm_bytes=4.0 * (self.d * n + 2.0 * self.s * self.d * n + self.k * n),
            materializes_S=True,  # index structure lives in memory
        )


class SRHTSketch(SketchBase):
    """Subsampled randomized Hadamard transform: P·H·D (FWHT-based)."""

    name = "srht"

    def __init__(self, d, k, seed=0):
        super().__init__(d, k, seed)
        self.d_pad = 1 << max(0, (d - 1).bit_length())
        u = jnp.arange(self.d_pad, dtype=jnp.uint32)
        self._signs = hashing.hash_to_unit_sign(
            hashing.hash_words(np.uint32(seed), np.uint32(0xFAD), u)
        )
        r = jnp.arange(self.k, dtype=jnp.uint32)
        hsh = hashing.hash_words(np.uint32(seed), np.uint32(0x5A3), r)
        self._rows = hashing.hash_mod(hsh, self.d_pad)        # (k,) subsample

    @staticmethod
    def fwht(x: jnp.ndarray) -> jnp.ndarray:
        """Fast Walsh-Hadamard transform along axis 0 (length power of two)."""
        d = x.shape[0]
        h = 1
        while h < d:
            x = x.reshape(d // (2 * h), 2, h, -1)
            a = x[:, 0]
            b = x[:, 1]
            x = jnp.stack([a + b, a - b], axis=1).reshape(d, -1)
            h *= 2
        return x

    def apply(self, A):
        n = A.shape[1]
        Ap = jnp.pad(A, ((0, self.d_pad - self.d), (0, 0)))
        HDx = self.fwht(self._signs[:, None] * Ap).reshape(self.d_pad, n)
        scale = 1.0 / math.sqrt(self.k * self.d_pad)
        return HDx[self._rows] * math.sqrt(self.d_pad) * scale

    def cost_model(self, n: int) -> CostModel:
        logd = max(1, int(math.log2(self.d_pad)))
        return CostModel(
            flops=2.0 * self.d_pad * logd * n,
            # The butterfly is log₂(d) sequential passes, each reading and
            # writing the full (d_pad, n) operand — exactly what ``fwht``
            # above does, and why FHT-based sketches lose the memory race
            # in practice despite the O(d log d) flop count.  (A fused
            # multi-stage FHT could amortize a few passes, but not below
            # the paper's measured gap.)
            hbm_bytes=4.0 * (2.0 * self.d_pad * n * logd + self.k * n),
            materializes_S=False,
        )


class BlockPermSketch(SketchBase):
    """BLOCKPERM-SJLT applied via FlashSketch (Pallas on TPU, XLA on CPU).

    ``kernel_version`` selects the cost-model generation ("v2" fused
    single-write vs "v1" κ-revisiting) and the Pallas impl dispatched on
    TPU; ``dtype`` selects the streaming precision ("bfloat16" halves the
    dominant HBM term, accumulation stays fp32).
    """

    name = "blockperm"

    def __init__(self, d, k, kappa: int = 4, s: int = 2, seed: int = 0,
                 impl: str = "auto", plan: Optional[BlockPermPlan] = None,
                 block_rows: Optional[int] = None, dtype: Optional[str] = None,
                 kernel_version: str = "v2"):
        super().__init__(d, k, seed)
        if plan is not None:
            # an explicit plan (e.g. from tune.autotune_plan) wins on the
            # structural knobs, but dtype is re-appliable precision
            self.plan = plan.with_dtype(dtype) if dtype is not None else plan
        else:
            self.plan = make_plan(d, k, kappa=kappa, s=s, seed=seed,
                                  block_rows=block_rows,
                                  dtype=dtype or "float32")
        self.k = self.plan.k        # effective (padded-up) sketch dim
        self.kernel_version = kernel_version
        if impl == "auto" and kernel_version == "v1":
            impl = "pallas_v1" if jax.default_backend() == "tpu" else "xla"
        self.impl = impl

    def apply(self, A):
        return kops.sketch_apply(self.plan, A, self.impl)

    def apply_gather(self, A, row_index):
        # gather-fused kernel: no A[row_index] intermediate
        return kops.sketch_apply(self.plan, A, self.impl, row_index=row_index)

    def apply_batched(self, A, row_index=None):
        # one launch for the whole stack (batch folded into the column axis)
        return kops.sketch_apply_batched(self.plan, A, self.impl,
                                         row_index=row_index)

    def apply_t(self, Y):
        return kops.sketch_apply_t(self.plan, Y, self.impl)

    def lowering_for(self, n: int, **spec_kwargs):
        """The Lowering record of this family's width-``n`` apply.

        For cost modeling the request pins the kernel GENERATION the
        family stands for (``pallas_v1`` for the v1 family, ``pallas``
        otherwise) rather than the backend-dependent ``self.impl`` — the
        modeled hardware is a TPU even when the host traces on CPU.  Any
        downgrade (e.g. v2 → v1 on VMEM overflow) is resolved by the
        engine and lands in the record, so ``cost_model`` charges what
        would actually launch.
        """
        impl = spec_kwargs.pop(
            "impl",
            "pallas_v1" if self.kernel_version == "v1" else "pallas")
        return klowering.lower(self.plan, klowering.LaunchSpec(
            op="fwd", n=n, impl=impl, **spec_kwargs))

    def cost_model(self, n: int) -> CostModel:
        kc = sketch_model.cost_of(self.lowering_for(n))
        return CostModel(
            # MXU one-hot contraction FLOPs (TPU adaptation); the *useful*
            # scatter flops are 2·κs·d·n — both are below the memory term.
            flops=kc.mxu_flops,
            # A streamed κ times (each input block feeds κ output blocks);
            # v2 writes Y once (bf16-aware), v1 charges the κ revisits.
            # No atomics, no S materialization.
            hbm_bytes=kc.hbm_bytes,
            materializes_S=False,
        )

    @property
    def name_full(self) -> str:
        p = self.plan
        tag = f"blockperm(k={p.kappa},s={p.s}"
        if p.dtype != "float32":
            tag += f",{p.dtype}"
        return tag + ")"


class BlockPermBf16Sketch(BlockPermSketch):
    """bf16-streaming BLOCKPERM-SJLT, registered as its own family so
    mixed-precision rows stay labeled in benchmark tables and are never
    silently selected as the fp32 "ours" in Table-1 aggregation."""

    name = "blockperm_bf16"

    def __init__(self, d, k, kappa: int = 4, s: int = 2, seed: int = 0,
                 impl: str = "auto", **kw):
        super().__init__(d, k, kappa=kappa, s=s, seed=seed, impl=impl,
                         dtype="bfloat16", **kw)


class BlockPermFp8Sketch(BlockPermSketch):
    """fp8-streaming BLOCKPERM-SJLT (e4m3 + seeded stochastic rounding,
    the ``fp8_e4m3_sr`` precision policy) registered as its own family:
    1 byte/elem HBM streams — the ROADMAP-item-3 rung below bf16 — with
    fp32 accumulate, labeled so precision rows never masquerade as the
    fp32 "ours" in benchmark aggregation."""

    name = "blockperm_fp8"

    def __init__(self, d, k, kappa: int = 4, s: int = 2, seed: int = 0,
                 impl: str = "auto", **kw):
        super().__init__(d, k, kappa=kappa, s=s, seed=seed, impl=impl,
                         dtype="fp8_e4m3_sr", **kw)


class LocalizedSketch(BlockPermSketch):
    """κ=1 block-diagonal SJLT (Srinivasa et al. 2020) — paper's base case."""

    name = "localized"

    def __init__(self, d, k, s: int = 2, seed: int = 0, impl: str = "auto"):
        super().__init__(d, k, kappa=1, s=s, seed=seed, impl=impl)


class CountSketch(BlockPermSketch):
    """CountSketch (Higgins & Boman, arXiv:2508.14209) as a first-class
    engine family.

    One ±1 nonzero per column, hashed anywhere in ``[k]`` — realized as a
    GLOBAL-family plan (``family="countsketch"``, κ=M: every input block
    feeds every output block), so the fused Pallas kernels, VMEM downgrade
    ladders, gather/batched paths, tuner, and golden snapshot all apply
    with zero new kernel code.  The plan seed is drawn from the family's
    disjoint ``multisketch.derive_seed`` stream, so mixing families under
    one master seed never collides hash streams.
    """

    name = "countsketch"
    default_s = FAMILY_DEFAULT_S["countsketch"]

    def __init__(self, d, k, s: Optional[int] = None, seed: int = 0,
                 impl: str = "auto", block_rows: Optional[int] = None,
                 dtype: Optional[str] = None):
        # core must not import solvers at module load (layering); the seed
        # derivation is the one shared utility, pulled in lazily.
        from repro.solvers.multisketch import derive_seed, family_stream
        s = self.default_s if s is None else int(s)
        plan = make_plan(
            d, k, s=s,
            seed=derive_seed(seed, 0, 0, stream=family_stream(self.name)),
            block_rows=block_rows, dtype=dtype or "float32",
            family=self.name)
        super().__init__(d, k, seed=seed, impl=impl, plan=plan)

    @property
    def name_full(self) -> str:
        p = self.plan
        tag = f"{self.name}(s={p.s}"
        if p.dtype != "float32":
            tag += f",{p.dtype}"
        return tag + ")"


class GraphSketch(CountSketch):
    """Sparse-graph sketch (Hu et al., arXiv:2102.05758): a column-degree-s
    bipartite expander with ±1/√s entries — CountSketch's construction with
    s independent per-chunk hashes per column, same global lowering."""

    name = "graph"
    default_s = FAMILY_DEFAULT_S["graph"]


class BlockRowSketch(SketchBase):
    """FLASHBLOCKROW (App. C): gather-only, reads A once, fragile.

    ``unbiased = False``: the iid block choices collide across the κ
    revisits (identical Φ patterns add coherently — certain at M = 1,
    probability (κ-1)/M per pair otherwise), inflating E[SᵀS] above I.
    That is the App.-C tradeoff the paper documents: single-pass reads,
    no column-regularity, no OSE guarantee.
    """

    name = "blockrow"
    unbiased = False

    def __init__(self, d, k, kappa: int = 4, s: int = 2, seed: int = 0,
                 impl: str = "auto", dtype: str = "float32"):
        super().__init__(d, k, seed)
        self.plan = make_plan(d, k, kappa=kappa, s=s, seed=seed, dtype=dtype)
        self.k = self.plan.k
        self.impl = impl

    def apply(self, A):
        return kops.blockrow_apply(self.plan, A, self.impl)

    def apply_gather(self, A, row_index):
        return kops.blockrow_apply(self.plan, A, self.impl,
                                   row_index=row_index)

    def lowering_for(self, n: int, **spec_kwargs):
        impl = spec_kwargs.pop("impl", "pallas")
        return klowering.lower(self.plan, klowering.LaunchSpec(
            op="blockrow", n=n, impl=impl, **spec_kwargs))

    def cost_model(self, n: int) -> CostModel:
        p = self.plan
        return CostModel(
            flops=2.0 * p.kappa * p.Br * p.d_pad * n,
            # Key App.-C advantage: A is read ~once (κ blocks per output
            # block, but block choices are iid => coverage ~ (1-1/e) of A
            # per column tile; we charge the worst case of one full read).
            # NOTE: this is the *family-level* model (the paper's native
            # GPU gather, for Table-1 comparability across families);
            # roofline.sketch_model charges the TPU kernel as launched
            # (κ pipelined views) — see kernel_cost(variant="blockrow").
            hbm_bytes=float(p.stream_itemsize) * p.d_pad * n + 4.0 * p.k_pad * n,
            materializes_S=False,
        )


SKETCH_FAMILIES = {
    "dense_gaussian": DenseGaussianSketch,
    "dense_rademacher": DenseRademacherSketch,
    "sjlt": SJLTSketch,
    "srht": SRHTSketch,
    "blockperm": BlockPermSketch,
    "blockperm_bf16": BlockPermBf16Sketch,
    "blockperm_fp8": BlockPermFp8Sketch,
    "localized": LocalizedSketch,
    "blockrow": BlockRowSketch,
    "countsketch": CountSketch,
    "graph": GraphSketch,
}


def make_sketch(name: str, d: int, k: int, seed: int = 0, **kw) -> SketchBase:
    return SKETCH_FAMILIES[name](d, k, seed=seed, **kw)
