"""Precision as a first-class lowering axis (ROADMAP item 3).

One frozen :class:`Precision` record answers every question the stack
used to re-derive from the ``plan.dtype`` string with scattered
``"bfloat16" ? 2 : 4`` ternaries: what dtype the operand streams from
HBM in, what the MXU contraction inputs are, what dtype accumulates,
how the stream cast rounds, how many bytes a streamed element costs the
roofline, and how far the health guards' isometry/OSE bands must widen
before a draw is blamed on the sketch rather than on the quantizer.

Registered policies (canonical name → record)::

    float32      fp32 stream,  fp32 MXU,    nearest    4 B/elem
    bfloat16     bf16 stream,  bf16 MXU,    nearest    2 B/elem
    fp8_e4m3     e4m3 stream,  bf16 MXU,    nearest    1 B/elem
    fp8_e5m2     e5m2 stream,  bf16 MXU,    nearest    1 B/elem
    fp8_e4m3_sr  e4m3 stream,  bf16 MXU,    stochastic 1 B/elem
    fp8_e5m2_sr  e5m2 stream,  bf16 MXU,    stochastic 1 B/elem

``"fp32"`` and ``"bf16"`` are accepted as aliases.  The canonical
spelling of the two legacy policies is kept as ``"float32"`` /
``"bfloat16"`` on purpose: ``plan.dtype`` (and therefore
``tune.cache_key`` and the golden lowering snapshot) stores the
canonical name, so tuner caches and snapshots saved before this module
existed keep resolving.

Accumulation is fp32 for every policy (the kernels pin
``preferred_element_type``); fp8 operands are upcast to bf16 *inside*
the kernel — exact, since every e4m3/e5m2 value is representable in
bf16 — so HBM pays 1 byte/elem while the MXU runs at its bf16 rate.

Stochastic rounding is **value-keyed**: the uniform draw deciding
whether ``x`` rounds up or down is a counter hash of ``(seed, tag,
bits(x))`` (``core.hashing``, the same splitmix/murmur mix the sketch
itself uses).  For a fixed seed the quantizer is a deterministic pure
function of the value — bit-identical regardless of array shape,
batching, gather order or which kernel streams it — while across seeds
``E[quantize(x)] ≈ x`` (unbiased), which is what makes SR the right
rounding for iterative refinement (Jeendgar/Flint/Anzt, PAPERS.md
arXiv 2606.20195).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing

# Hash domain tag separating the SR draws from the sketch's own hashes.
_SR_TAG = np.uint32(0xF80D)

# jnp dtypes by stream-dtype name — the ONLY place in the repo mapping a
# policy string to a jnp dtype / itemsize (grep-clean criterion, ISSUE 9).
_JNP = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float8_e4m3fn": jnp.float8_e4m3fn,
    "float8_e5m2": jnp.float8_e5m2,
}
_ITEMSIZE = {"float32": 4, "bfloat16": 2,
             "float8_e4m3fn": 1, "float8_e5m2": 1}


@dataclasses.dataclass(frozen=True)
class Precision:
    """A named streaming-precision policy: stream/accumulate dtypes, the
    rounding mode of the HBM cast, and the guard tolerance bands the
    policy is entitled to.  Frozen and hashable — safe to hang off the
    (pytree-static) :class:`~repro.core.blockperm.BlockPermPlan`."""

    name: str                     # canonical registry name
    stream: str                   # dtype name the operand streams in
    accum: str = "float32"        # accumulation dtype (MXU preferred type)
    rounding: str = "nearest"     # "nearest" | "stochastic"
    # guard tolerance bands (health/guards.py defaults come FROM here)
    isometry_tol: float = 0.5     # healthy: ‖SA‖_F/‖A‖_F within 1 ± tol
    isometry_fail: float = 0.9    # failed: outside 1 ± fail
    ose_min_healthy: float = 0.5  # σ_min(SU) healthy floor
    ose_min_failed: float = 0.1   # σ_min(SU) failed floor
    exactness_atol: float = 5e-4  # kernel-vs-oracle comparison tolerance

    # -- dtype accessors ----------------------------------------------------
    @property
    def stream_dtype(self):
        """jnp dtype the operand is stored/streamed in (HBM side)."""
        return _JNP[self.stream]

    @property
    def accum_dtype(self):
        """jnp dtype of the MXU accumulator (``preferred_element_type``)."""
        return _JNP[self.accum]

    @property
    def compute_dtype(self):
        """jnp dtype of the MXU *inputs*: the in-kernel upcast target.

        fp8 operands are widened to bf16 before the contraction (exact —
        e4m3/e5m2 ⊂ bf16); fp32/bf16 streams feed the MXU directly."""
        return _JNP["bfloat16"] if self.is_fp8 else self.stream_dtype

    @property
    def itemsize(self) -> int:
        """Bytes per streamed element — the roofline's HBM charge."""
        return _ITEMSIZE[self.stream]

    @property
    def compute_itemsize(self) -> int:
        """Bytes per MXU input element (selects the modeled MXU rate)."""
        return 2 if self.is_fp8 else self.itemsize

    @property
    def is_fp8(self) -> bool:
        return self.stream.startswith("float8")

    @property
    def stochastic(self) -> bool:
        return self.rounding == "stochastic"

    def isometry_band(self) -> Dict[str, float]:
        """kwargs for :func:`repro.health.guards.isometry_guard`."""
        return {"tol": self.isometry_tol, "fail": self.isometry_fail}

    def ose_band(self) -> Dict[str, float]:
        """kwargs for :func:`repro.health.guards.ose_probe`."""
        return {"min_healthy": self.ose_min_healthy,
                "min_failed": self.ose_min_failed}


_FP8_BAND = dict(isometry_tol=0.6, isometry_fail=0.95,
                 ose_min_healthy=0.4, ose_min_failed=0.05,
                 exactness_atol=5e-3)

# Canonical registry. Insertion order = documentation order.
POLICIES: Dict[str, Precision] = {
    p.name: p for p in (
        Precision("float32", "float32", exactness_atol=1e-5),
        Precision("bfloat16", "bfloat16"),
        Precision("fp8_e4m3", "float8_e4m3fn", **_FP8_BAND),
        Precision("fp8_e5m2", "float8_e5m2", **_FP8_BAND),
        Precision("fp8_e4m3_sr", "float8_e4m3fn", rounding="stochastic",
                  **_FP8_BAND),
        Precision("fp8_e5m2_sr", "float8_e5m2", rounding="stochastic",
                  **_FP8_BAND),
    )
}

# Validated string shorthands (legacy spellings stay canonical, see module
# docstring; the short forms are conveniences for CLIs and configs).
ALIASES: Dict[str, str] = {"fp32": "float32", "bf16": "bfloat16"}


def names() -> Tuple[str, ...]:
    """All accepted policy spellings (canonical names + aliases)."""
    return tuple(POLICIES) + tuple(ALIASES)


def resolve(policy: Union[str, Precision]) -> Precision:
    """Policy name/alias (or an already-resolved record) → ``Precision``."""
    if isinstance(policy, Precision):
        return policy
    key = ALIASES.get(policy, policy)
    try:
        return POLICIES[key]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown precision policy {policy!r}; registered: "
            f"{', '.join(names())}") from None


def canonical(policy: Union[str, Precision]) -> str:
    """Canonical registry name for a policy/alias (validates)."""
    return resolve(policy).name


# ---------------------------------------------------------------------------
# Quantization: the streaming cast.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _finite_grid(stream: str) -> np.ndarray:
    """Sorted ascending array of every finite value of an 8-bit float.

    256 bit patterns → ≤ 253 distinct finite values; tiny enough to hold
    as a literal table, which sidesteps every next-representable
    bit-twiddling trap (sign-magnitude order, subnormals, e4m3's missing
    inf encoding)."""
    dt = np.dtype(_JNP[stream])
    vals = np.arange(256, dtype=np.uint8).view(dt).astype(np.float32)
    return np.unique(vals[np.isfinite(vals)])


def fp8_max(policy: Union[str, Precision]) -> float:
    """Largest finite value of an fp8 policy's stream dtype."""
    p = resolve(policy)
    if not p.is_fp8:
        raise ValueError(f"{p.name} is not an fp8 policy")
    return float(_finite_grid(p.stream)[-1])


def _uniform_from_bits(seed, x32: jnp.ndarray) -> jnp.ndarray:
    """Value-keyed U[0,1) draw: hash of (seed, tag, bitpattern of x)."""
    bits = jax.lax.bitcast_convert_type(x32, jnp.uint32)
    h = hashing.hash_words(np.uint32(int(seed) & 0xFFFFFFFF), _SR_TAG, bits)
    return (h >> np.uint32(8)).astype(jnp.float32) * np.float32(1.0 / (1 << 24))


def quantize_stream(x: jnp.ndarray, policy: Union[str, Precision],
                    *, seed: int = 0) -> jnp.ndarray:
    """Cast ``x`` to the policy's streaming dtype — THE streaming cast.

    ``nearest`` policies round to nearest-even (clamped to the finite
    range first: overflow must saturate, not produce e4m3's nan).
    ``stochastic`` policies round each value up with probability equal
    to its fractional position between its two fp8 neighbors, using the
    value-keyed seeded draw described in the module docstring: exact
    passthrough for representable values, unbiased over seeds,
    bit-deterministic for a fixed seed.
    """
    p = resolve(policy)
    x32 = x.astype(jnp.float32)
    if not p.is_fp8:
        return x.astype(p.stream_dtype)
    grid = jnp.asarray(_finite_grid(p.stream))
    x32 = jnp.clip(x32, grid[0], grid[-1])
    if not p.stochastic:
        return x32.astype(p.stream_dtype)
    lo_idx = jnp.clip(jnp.searchsorted(grid, x32, side="right") - 1,
                      0, grid.shape[0] - 2)
    lo = grid[lo_idx]
    hi = grid[lo_idx + 1]
    frac = jnp.where(hi > lo, (x32 - lo) / (hi - lo), 0.0)
    up = _uniform_from_bits(seed, x32) < frac
    return jnp.where(up, hi, lo).astype(p.stream_dtype)


def emulate_stream(x: jnp.ndarray, policy: Union[str, Precision],
                   *, seed: int = 0) -> jnp.ndarray:
    """Round ``x`` through the streaming dtype, returned as fp32.

    What the XLA oracle / fp32 v1 kernels apply so their results carry
    the SAME stream quantization as the v2 kernels (which receive the
    ``quantize_stream`` output directly)."""
    p = resolve(policy)
    if p.name == "float32":
        return x.astype(jnp.float32)
    return quantize_stream(x, p, seed=seed).astype(jnp.float32)
