"""Block-level wiring: union of κ edge-disjoint permutations of [M].

Paper App. D: rather than materializing κ permutation tables, neighbors are
generated on the fly by iterating a *full-cycle* affine map

    f(x) = (a·x + b) mod M,        π_ℓ(g) = f^ℓ(g),  ℓ = 1..κ.

Full period (Hull & Dobell 1962) requires gcd(b, M)=1, (a−1) divisible by
every prime factor of M, and 4 | (a−1) if 4 | M.  We restrict M to powers of
two (the plan pads d, k so this always holds), where the conditions reduce to
``a ≡ 1 (mod 4)`` and ``b`` odd — both trivially derivable from a hash.

Because f is a single M-cycle, f^j has no fixed point for 1 ≤ j < M, hence
π_1..π_κ are pairwise derangements (edge-disjoint) for any κ ≤ M, and the
block bipartite graph is exactly κ-regular on both sides.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import hashing


def derive_affine_params(seed: int, M: int) -> Tuple[int, int]:
    """Derive full-cycle LCG params (a, b) for modulus M (power of two).

    Returned as *python ints* so they can be baked into kernels and
    BlockSpec index_maps as static constants.
    """
    if M & (M - 1) != 0:
        raise ValueError(f"wiring modulus M={M} must be a power of two")
    h1 = int(hashing.hash_words(np.uint32(seed), np.uint32(0xA11CE)))
    h2 = int(hashing.hash_words(np.uint32(seed), np.uint32(0xB0B)))
    if M <= 2:
        # Degenerate moduli: identity-ish cycle; a=1 keeps full period.
        a = 1
        b = 1 % max(M, 1)
        if M == 2:
            b = 1
        return a, b
    a = (4 * (h1 % (M // 4)) + 1) % M if M >= 4 else 1
    if a == 1 and M >= 8:
        a = 5  # avoid the identity multiplier when we can mix more
    b = (2 * (h2 % (M // 2)) + 1) % M  # odd => coprime with 2^m
    return int(a), int(b)


def affine_step(x, a: int, b: int, M: int):
    """One application of f(x) = (a x + b) mod M. Works on ints or arrays."""
    return (a * x + b) % M


def neighbor(g, ell: int, a: int, b: int, M: int):
    """π_ℓ(g) = f^ℓ(g) via iterated affine map (ℓ static, small)."""
    x = g
    for _ in range(ell):
        x = affine_step(x, a, b, M)
    return x


def neighbor_fused(g, ell: int, a: int, b: int, M: int):
    """Closed form f^ℓ(g) = a^ℓ g + b(a^{ℓ-1}+…+1) mod M.

    Matches :func:`neighbor`; preferred inside index_maps (constant folding).
    """
    a_l = pow(a, ell, M)
    if a == 1:
        geo = ell % M
    else:
        # sum_{t<ell} a^t mod M. M is 2^m and a is odd => (a-1) may share
        # factors with M, so compute the geometric sum iteratively mod M.
        geo = 0
        term = 1
        for _ in range(ell):
            geo = (geo + term) % M
            term = (term * a) % M
    return (a_l * g + (b * geo) % M) % M


def wiring_table(seed: int, M: int, kappa: int) -> np.ndarray:
    """Materialize π as a (κ, M) int32 table (tests / reference only)."""
    a, b = derive_affine_params(seed, M)
    g = np.arange(M, dtype=np.int64)
    out = np.empty((kappa, M), dtype=np.int32)
    x = g.copy()
    for ell in range(kappa):
        x = (a * x + b) % M
        out[ell] = x
    return out


def check_edge_disjoint(pi: np.ndarray) -> bool:
    """Every output block's κ neighbors are distinct (pairwise derangements)."""
    kappa, M = pi.shape
    for g in range(M):
        if len(set(pi[:, g].tolist())) != kappa:
            return False
    return True


def check_biregular(pi: np.ndarray) -> bool:
    """Each input block appears in exactly κ neighborhoods."""
    kappa, M = pi.shape
    counts = np.zeros(M, dtype=np.int64)
    for ell in range(kappa):
        np.add.at(counts, pi[ell], 1)
    return bool(np.all(counts == kappa))


def wiring_jnp(seed: int, M: int, kappa: int) -> jnp.ndarray:
    """(κ, M) wiring table as a traced jnp computation (for ref apply)."""
    a, b = derive_affine_params(seed, M)
    g = jnp.arange(M, dtype=jnp.int32)
    rows = []
    x = g
    for _ in range(kappa):
        x = (a * x + b) % M
        rows.append(x)
    return jnp.stack(rows, axis=0)
