"""Sharding context + activation constraints + parameter partition rules.

The model code calls ``shard_residual`` / ``shard_kv`` / ``shard_logits`` at
key points; these are **no-ops unless a ShardingContext is active** (so CPU
smoke tests and single-device runs are untouched).  The launcher / dry-run
activates a context describing the mesh axes:

    with partition.activate(partition.ShardingContext(batch_axes=("pod","data"),
                                                      model_axis="model",
                                                      zero3=cfg.zero3)):
        lowered = jax.jit(step, in_shardings=...).lower(...)

Parameter partition specs come from ``param_pspecs`` which pattern-matches
parameter tree paths (Megatron TP splits + optional ZeRO-3 FSDP axis + EP for
expert-stacked weights).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingContext:
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    zero3: bool = False
    seq_shard_residual: bool = True   # Megatron-SP: residual seq over model
    model_size: int = 1               # mesh axis sizes (for divisibility)
    data_size: int = 1


_STATE = threading.local()


def current() -> Optional[ShardingContext]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def activate(ctx: ShardingContext):
    prev = current()
    _STATE.ctx = ctx
    try:
        yield ctx
    finally:
        _STATE.ctx = prev


def _wsc(x, spec):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no mesh context (e.g. eager smoke test)


# ---------------------------------------------------------------------------
# activation constraints
# ---------------------------------------------------------------------------

def shard_residual(x: jnp.ndarray) -> jnp.ndarray:
    """Residual stream (B, S, D): batch over data axes, seq over model (SP)."""
    ctx = current()
    if ctx is None or x.ndim != 3:
        return x
    seq = ctx.model_axis if ctx.seq_shard_residual else None
    return _wsc(x, P(ctx.batch_axes, seq, None))


def shard_logits(x: jnp.ndarray) -> jnp.ndarray:
    """Logits (B, S, V): vocab over model axis."""
    ctx = current()
    if ctx is None or x.ndim != 3:
        return x
    return _wsc(x, P(ctx.batch_axes, None, ctx.model_axis))


def shard_kv(x: jnp.ndarray) -> jnp.ndarray:
    """KV cache (..., B, Hkv, S, hd): batch over data, kv-heads or seq over model."""
    ctx = current()
    if ctx is None or x.ndim < 4:
        return x
    lead = (None,) * (x.ndim - 4)
    return _wsc(x, P(*lead, ctx.batch_axes, None, None, None))


def gather_seq(x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, D) gathered over seq (batch stays sharded).

    Placed on the *bf16* tensor right before attention projections so the
    SP→TP all-gather moves bf16 — XLA otherwise fuses the RMSNorm f32
    upcast into the gathered value and ships f32 (measured 2× collective
    bytes on deepseek train_4k, §Perf iteration 1c).
    """
    ctx = current()
    if ctx is None or x.ndim != 3:
        return x
    return _wsc(x, P(ctx.batch_axes, None, None))


def shard_moe_buf(x: jnp.ndarray) -> jnp.ndarray:
    """MoE dispatch buffer (G, E, C, D): groups over data, experts over model.

    Pinning this is the EP all-to-all: tokens move from data-sharded groups
    to model-sharded experts exactly once, instead of whatever mix of
    gathers propagation picks."""
    ctx = current()
    if ctx is None or x.ndim != 4:
        return x
    e = x.shape[1]
    m = ctx.model_axis if ctx.model_size > 1 and e % ctx.model_size == 0 else None
    return _wsc(x, P(ctx.batch_axes, m, None, None))


def gather_experts(x: jnp.ndarray) -> jnp.ndarray:
    """MoE combine path (G, E, C, D): experts gathered, groups data-sharded —
    the reverse all-to-all, placed before the per-group un-dispatch gather."""
    ctx = current()
    if ctx is None or x.ndim != 4:
        return x
    return _wsc(x, P(ctx.batch_axes, None, None, None))


def shard_heads(x: jnp.ndarray) -> jnp.ndarray:
    """Attention q/k/v (B, S, H, hd): heads over model, seq UNsharded.

    This is the SP→TP transition: the residual stream is seq-sharded, the
    attention core is head-sharded.  Pinning it here makes q-block slicing
    device-local (otherwise XLA reshards per block — measured +115 GB/dev of
    collective-permute on deepseek train_4k, §Perf iteration 1a).
    """
    ctx = current()
    if ctx is None or x.ndim != 4:
        return x
    h = x.shape[2]
    m = ctx.model_axis if ctx.model_size > 1 and h % ctx.model_size == 0 else None
    return _wsc(x, P(ctx.batch_axes, None, m, None))


# ---------------------------------------------------------------------------
# parameter partition rules
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return "/".join(out)


def _spec_for(path: str, ndim: int, ctx: ShardingContext) -> P:
    """Partition spec for one parameter, from its tree path + rank.

    Conventions (leading stacked layer axes are never sharded):
      embed/lm_head (V, D)       -> (model, fsdp)
      attention wq/wk/wv (D, H)  -> (fsdp, model)       [col-parallel]
      attention wo (H, D)        -> (model, fsdp)       [row-parallel]
      ffn wi_* (D, F)            -> (fsdp, model)
      ffn wo (F, D)              -> (model, fsdp)
      moe expert stacks (E,D,F)  -> (model, fsdp, None) [EP on experts]
      mamba in_proj (D, X)       -> (fsdp, model);  out_proj (X, D) -> (model, fsdp)
      rwkv wr/wk/wv/wg/ck (D,·)  -> (fsdp, model);  wo/cv -> (model, fsdp)
      norms / scalars            -> replicated
    """
    m = ctx.model_axis
    f = ctx.batch_axes[-1] if ctx.zero3 else None   # FSDP over innermost data axis
    leaf = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    def lead(spec2: Tuple) -> P:
        return P(*([None] * (ndim - len(spec2))), *spec2)

    if leaf in ("embed", "lm_head"):
        return P(m, f)
    if parent == "moe":
        # expert-stacked weights live DIRECTLY under "moe": (L, E, D, F).
        # (dense_residual and router fall through to the generic rules.)
        if leaf in ("wi_gate", "wi_up", "wo") and ndim >= 4:
            return lead((m, f, None))
        if leaf == "router":
            return lead((f, None))
    if leaf in ("wq", "wk", "wv", "wg", "wr", "in_proj", "wi_gate", "wi_up",
                "ck", "cr", "wA"):
        return lead((f, m))
    if leaf in ("wo", "out_proj", "cv", "wB"):
        return lead((m, f))
    if leaf in ("conv_w",):
        return lead((None, m))
    return P()  # norms, biases, scalars: replicated


def param_pspecs(params, ctx: ShardingContext):
    """PartitionSpec pytree matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_str(path), leaf.ndim, ctx), params)


def batch_pspec(ctx: ShardingContext, rank: int = 2) -> P:
    """Token batches (B, S, ...)."""
    return P(ctx.batch_axes, *([None] * (rank - 1)))


def named_sharding_tree(mesh, spec_tree):
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))
