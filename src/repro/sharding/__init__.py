"""Sharding: activation constraints + parameter partition rules."""
