"""Property-based tests (hypothesis) for the paper's theory claims.

Invariants checked:
  * Lemma A.1 energy identity: Σ_g ‖x_N(g)‖² = κ‖x‖².
  * Lemma A.9 sandwich: μ_blk/κ ≤ μ_nbr ≤ μ_blk.
  * Prop A.11 smoothing trend: μ_nbr decreases (stochastically) with κ.
  * OSE behaviour: distortion shrinks ~1/√k (Thm 6.2 scaling).
  * Hash determinism + uniformity.
"""
import numpy as np
import jax.numpy as jnp
import pytest

# hypothesis gates only the @given property tests — the statistical and
# closed-form checks below run without the 'test' extra installed
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    class _NoStrategy:
        """Placeholder so module-level strategy expressions still build."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NoStrategy()

    def given(*a, **k):
        return pytest.mark.skip(
            reason="property tests need the 'test' extra (hypothesis)")

    def settings(*a, **k):
        return lambda f: f

from repro.core import coherence, hashing, wiring
from repro.core.blockperm import make_plan
from repro.kernels import ref as kref


@given(
    seed=st.integers(0, 2**31 - 1),
    logM=st.integers(1, 8),
    kappa=st.integers(1, 8),
)
@settings(max_examples=40, deadline=None)
def test_energy_identity(seed, logM, kappa):
    M = 1 << logM
    kappa = min(kappa, M)
    pi = wiring.wiring_table(seed, M, kappa)
    rng = np.random.default_rng(seed % 1000)
    x = rng.normal(size=(M, 4))           # one 4-dim block per block index
    total = sum(
        np.sum(x[pi[ell, g]] ** 2) for g in range(M) for ell in range(kappa)
    )
    np.testing.assert_allclose(total, kappa * np.sum(x ** 2), rtol=1e-9)


@given(
    seed=st.integers(0, 10_000),
    logM=st.integers(2, 5),
    kappa=st.integers(1, 8),
    r=st.integers(1, 6),
)
@settings(max_examples=30, deadline=None)
def test_coherence_sandwich(seed, logM, kappa, r):
    M = 1 << logM
    kappa = min(kappa, M)
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(8 * M, r))
    U, _ = np.linalg.qr(X)
    pi = wiring.wiring_table(seed, M, kappa)
    mu_blk = coherence.block_coherence(U, M)
    mu_nbr = coherence.neighborhood_coherence(U, pi)
    assert mu_nbr <= mu_blk * (1 + 1e-9)
    assert mu_nbr >= mu_blk / kappa * (1 - 1e-9)
    assert mu_nbr >= 1.0 - 1e-9  # coherence is always ≥ 1 for orthonormal U


def test_smoothing_with_kappa():
    """Prop A.11: for a coherent subspace, μ_nbr falls as κ grows."""
    M = 64
    rng = np.random.default_rng(0)
    # spiky subspace: energy concentrated in one block => μ_blk ≈ M
    U = np.zeros((M * 8, 4))
    U[:8, :] = np.linalg.qr(rng.normal(size=(8, 4)))[0]
    mu_blk = coherence.block_coherence(U, M)
    assert mu_blk > M / 2
    mus = []
    for kappa in [1, 2, 4, 8, 16]:
        vals = [
            coherence.neighborhood_coherence(U, wiring.wiring_table(s, M, kappa))
            for s in range(5)
        ]
        mus.append(np.mean(vals))
    # monotone decrease (allow tiny noise) and ~1/κ scaling overall
    assert mus[-1] < mus[0] / 4
    for a, b in zip(mus, mus[1:]):
        assert b <= a * 1.05


@pytest.mark.parametrize("k", [128, 512])
def test_ose_error_scaling(k, rng):
    """Thm 6.2: distortion ε ~ √(μ_nbr·t/k) — quadrupling k halves error."""
    d, r = 2048, 8
    U, _ = np.linalg.qr(rng.normal(size=(d, r)))
    errs = []
    for seed in range(4):
        plan = make_plan(d=d, k=k, kappa=4, s=2, seed=seed)
        SU = kref.flashsketch_ref(plan, jnp.asarray(U, jnp.float32))
        errs.append(coherence.ose_spectral_error(U, np.asarray(SU)))
    mean = np.mean(errs)
    bound = 3.0 * np.sqrt(r / k) + 0.1
    assert mean < bound, (mean, bound)


@pytest.mark.slow
def test_countsketch_heavy_tail_vs_blockperm_ose(rng):
    """Family quality ordering behind the Pareto tournament's claimed
    regimes: at MATCHED sketch size on a coherent subspace, CountSketch
    (s = 1, one hashed nonzero per column) is heavy-tailed — often great,
    occasionally catastrophic when heavy rows collide — while
    BlockPerm-SJLT's κs = 8 nonzeros concentrate (Thm 6.2: the κ revisits
    smooth coherence).  The sparse-graph family (s = 4) sits between.

    Fixed seeds keep this deterministic; the margins (1.2× on the q90
    tail, 3× on the std) are far inside the observed ratios (≈1.6× and
    ≈5.8× over these 32 draws), so the test detects a family regression,
    not sampling noise.
    """
    d, r, k, trials = 2048, 8, 128, 32
    # coherent input: all energy in the first 2r rows — the regime where
    # a single-nonzero hash can annihilate a heavy row pair
    U = np.zeros((d, r))
    U[:2 * r, :] = np.linalg.qr(rng.normal(size=(2 * r, r)))[0]
    Uj = jnp.asarray(U, jnp.float32)

    def errs(**kw):
        out = []
        for seed in range(trials):
            plan = make_plan(d=d, k=k, seed=seed, **kw)
            SU = kref.flashsketch_ref(plan, Uj)
            out.append(coherence.ose_spectral_error(U, np.asarray(SU)))
        return np.asarray(out)

    bp = errs(kappa=4, s=2)
    cs = errs(family="countsketch", s=1)
    gr = errs(family="graph", s=4)
    # BlockPerm's worst draw stays an embedding; CountSketch's does not
    assert bp.max() < 0.6, bp.max()
    assert cs.max() > 0.8, cs.max()
    # tail and spread orderings with wide margins
    assert np.quantile(cs, 0.9) > 1.2 * np.quantile(bp, 0.9)
    assert cs.std() > 3.0 * bp.std()
    # s = 4 already tames the tail: graph sits strictly between
    assert gr.std() < cs.std() and gr.max() < cs.max()
    assert gr.max() < 0.7, gr.max()


def test_ose_error_improves_with_k(rng):
    d, r = 2048, 8
    U, _ = np.linalg.qr(rng.normal(size=(d, r)))
    def mean_err(k):
        out = []
        for seed in range(4):
            plan = make_plan(d=d, k=k, kappa=4, s=2, seed=seed)
            SU = kref.flashsketch_ref(plan, jnp.asarray(U, jnp.float32))
            out.append(coherence.ose_spectral_error(U, np.asarray(SU)))
        return np.mean(out)
    assert mean_err(1024) < mean_err(128)


@given(words=st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=6))
@settings(max_examples=50, deadline=None)
def test_hash_python_matches_jnp(words):
    """The pure-python hash path (used for static tables) must equal jnp."""
    py = int(hashing.hash_words(*[np.uint32(w) for w in words]))
    jn = int(np.asarray(hashing.hash_words(
        jnp.uint32(words[0]), *[np.uint32(w) for w in words[1:]]
    )))
    assert py == jn


def test_hash_uniformity():
    """Destination rows should be ~uniform within each chunk."""
    plan = make_plan(d=4096, k=1024, kappa=2, s=2, block_rows=128, seed=0)
    from repro.core.blockperm import block_rows_signs
    u = jnp.arange(plan.Bc, dtype=jnp.int32)
    rows, signs = block_rows_signs(plan, 0, 1, u, 0)
    rows = np.asarray(rows)
    counts = np.bincount(rows, minlength=plan.chunk)
    # chi-square-ish sanity: no row gets > 5x expected mass
    expected = plan.Bc / plan.chunk
    assert counts.max() < 5 * expected + 5
    s = np.asarray(signs)
    assert 0.3 < np.mean(s > 0) < 0.7


def test_smoothing_bound_formula():
    v = coherence.smoothing_bound(mu_blk=16.0, kappa=16, M=64, r=4)
    assert v > 1.0
    v2 = coherence.smoothing_bound(mu_blk=16.0, kappa=64, M=64, r=4)
    assert v2 < v
