"""Deterministic tests for the resilient sketch server.

Every scenario runs on a ``ManualClock`` — arrivals, deadlines, backoff
and breaker cool-downs are all virtual time, so overload/fault replays
are exact and instant.  The one threaded test at the bottom exercises
the real async driver.
"""
import numpy as np
import pytest

from repro.health import report as health_report
from repro.health.inject import adversarial_input, inject_nan
from repro.kernels import ops
from repro.serving import (DEADLINE, DEGRADED, FAILED, OK, SHED,
                           CircuitBreaker, DegradeLadder, ManualClock,
                           SketchRequest, SketchServer, ThreadedServer)
from repro.serving import degrade

D, N, K = 128, 16, 32
PARAMS = dict(d=D, k=K, kappa=2, s=2, seed=11)
ADV_PARAMS = dict(d=D, k=K, kappa=1, s=1, seed=11)   # injectable plans


@pytest.fixture
def rng():
    return np.random.default_rng(42)


def _operand(rng, n=N):
    return rng.standard_normal((D, n)).astype(np.float32)


def _server(**kw):
    kw.setdefault("clock", ManualClock())
    kw.setdefault("max_batch", 4)
    kw.setdefault("batch_wait_s", 0.01)
    return SketchServer(**kw)


def _req(rng, *, params=PARAMS, operand=None, **kw):
    return SketchRequest(tenant=kw.pop("tenant", "t"), kind="sketch",
                         operand=_operand(rng) if operand is None
                         else operand,
                         plan_params=dict(params), **kw)


def _serve_one(srv, req):
    ticket = srv.submit(req)
    if not isinstance(ticket, int):
        return ticket
    # 2× the window: exactly 1× can fall a float-ulp short after the
    # clock has accumulated many advances
    srv.clock.advance(2 * srv.batcher.batch_wait_s)
    srv.run_pending()
    resp = srv.poll(ticket)
    assert resp is not None
    return resp


# -- healthy path ----------------------------------------------------------

def test_healthy_response_bitwise_equals_direct_apply(rng):
    srv = _server()
    A = _operand(rng)
    resp = _serve_one(srv, _req(rng, operand=A))
    assert resp.status == OK and not resp.flagged and resp.attempts == 1
    plan = srv.plans.resolve("t", PARAMS)
    direct = np.asarray(ops.sketch_apply(plan, A))
    assert np.array_equal(resp.result, direct)


def test_coalescing_one_launch_per_plan_shape(rng):
    srv = _server(max_batch=8)
    same = [_req(rng) for _ in range(3)]
    other = _req(rng, params=dict(PARAMS, seed=99))
    tickets = [srv.submit(r) for r in same + [other]]
    srv.clock.advance(0.02)
    srv.run_pending()
    resps = [srv.poll(t) for t in tickets]
    assert [r.batch_size for r in resps] == [3, 3, 3, 1]
    # coalesced results match the per-request direct launch bit-for-bit
    plan = srv.plans.resolve("t", PARAMS)
    for r, req in zip(resps[:3], same):
        assert np.array_equal(r.result,
                              np.asarray(ops.sketch_apply(
                                  plan, req.operand)))


def test_solve_request_served_healthy(rng):
    srv = _server()
    A = _operand(rng, n=8)
    x_true = rng.standard_normal(8).astype(np.float32)
    req = SketchRequest(tenant="t", kind="solve", operand=A, rhs=A @ x_true,
                        plan_params=dict(d=D, k=K, kappa=2, s=2, seed=3))
    resp = _serve_one(srv, req)
    assert resp.status == OK
    assert resp.result.converged
    np.testing.assert_allclose(np.asarray(resp.result.x), x_true,
                               rtol=1e-3, atol=1e-3)


# -- admission / overload --------------------------------------------------

def test_overload_sheds_with_recorded_findings(rng):
    srv = _server(max_queue=4)
    tickets = [srv.submit(_req(rng)) for _ in range(7)]
    shed = [t for t in tickets if not isinstance(t, int)]
    assert len(shed) == 3
    for resp in shed:
        assert resp.status == SHED
        assert resp.health is not None
        assert any(f.guard == "admission" for f in resp.health.findings)
        assert resp.flagged
    assert srv.stats()["shed"] == 3
    assert health_report.counters().get("serve.reject.shed") == 3


def test_hopeless_deadline_rejected_at_admission(rng):
    srv = _server(service_estimate_s=0.05)
    resp = srv.submit(_req(rng, deadline_s=0.01))
    assert not isinstance(resp, int) and resp.status == DEADLINE
    assert health_report.counters().get("serve.reject.deadline") == 1


def test_deadline_expired_in_queue(rng):
    srv = _server(batch_wait_s=0.01)
    ticket = srv.submit(_req(rng, deadline_s=0.02))
    assert isinstance(ticket, int)
    srv.clock.advance(0.05)            # past the deadline before dispatch
    srv.run_pending()
    resp = srv.poll(ticket)
    assert resp.status == DEADLINE and resp.result is None


def test_backpressure_and_degrade_ladder_recorded(rng):
    srv = _server(max_queue=8, max_batch=8)
    tickets = [srv.submit(_req(rng)) for _ in range(8)]
    assert srv.stats()["backpressure"] == 1.0
    assert srv.ladder.level == len(degrade.RUNGS)      # every rung engaged
    srv.run_pending()                  # rung 1 collapses the window: due now
    resps = [srv.poll(t) for t in tickets]
    assert all(r is not None for r in resps)
    for r in resps:
        assert r.status == DEGRADED    # precision rung is a real downgrade
        # the dtype rungs collapse to the deepest engaged one: exactly ONE
        # dtype finding per response, and at full backpressure it is fp8
        dtype_findings = [f for f in r.health.findings
                         if f.guard == "degrade" and f.target == "dtype"]
        assert len(dtype_findings) == 1
        assert "fp8" in dtype_findings[0].detail
        assert r.flagged
    counts = health_report.counters()
    assert counts.get("serve.degrade.dtype") == 1      # once per dispatch
    assert counts.get("serve.ladder.up", 0) >= 1       # one per level step


# -- fault paths -----------------------------------------------------------

def test_nan_operand_fails_fast_without_retries(rng):
    srv = _server()
    A = np.asarray(inject_nan(_operand(rng), count=3, seed=0))
    resp = _serve_one(srv, _req(rng, operand=A))
    assert resp.status == FAILED and resp.flagged
    assert resp.attempts == 1          # unrecoverable: ladder not spent
    assert "unrecoverable_operand" in resp.health.actions
    assert any(f.guard == "finite" and f.target == "operand"
               and f.status == "failed" for f in resp.health.findings)


def test_adversarial_input_recovers_via_redraw(rng):
    srv = _server()
    plan = srv.plans.resolve("t", ADV_PARAMS)
    A = np.asarray(adversarial_input(plan, N, seed=1))
    resp = _serve_one(srv, _req(rng, params=ADV_PARAMS, operand=A))
    assert resp.status == DEGRADED and resp.flagged
    assert resp.attempts >= 2
    assert any(a.startswith("redraw") for a in resp.health.actions)
    # the recovered draw is actually usable
    assert np.all(np.isfinite(resp.result))
    ratio = np.linalg.norm(resp.result) / np.linalg.norm(A)
    assert abs(ratio - 1.0) < 0.9


def test_deadline_exhausted_redraw_returns_least_bad(rng):
    # backoff (0.1s) cannot fit the 50ms deadline budget: the ladder must
    # stop before its first rung and serve the least-bad (initial) draw
    srv = _server(backoff_base_s=0.1)
    plan = srv.plans.resolve("t", ADV_PARAMS)
    A = np.asarray(adversarial_input(plan, N, seed=2))
    resp = _serve_one(srv, _req(rng, params=ADV_PARAMS, operand=A,
                                deadline_s=0.05))
    assert resp.status == FAILED and resp.flagged
    assert resp.attempts == 1
    assert "escalation_budget_exhausted" in resp.health.actions
    assert resp.result is not None     # least-bad draw, explicitly flagged
    assert health_report.counters().get(
        "serve.escalation_budget_exhausted") == 1


def test_breaker_trips_suppresses_retries_then_recovers(rng):
    clock = ManualClock()
    srv = _server(clock=clock,
                  breaker=CircuitBreaker(fail_threshold=2, cooldown_s=1.0))
    plan = srv.plans.resolve("t", ADV_PARAMS)

    def adversarial_resp(seed):
        A = np.asarray(adversarial_input(plan, N, seed=seed))
        return _serve_one(srv, _req(rng, params=ADV_PARAMS, operand=A))

    # the breaker counts INITIAL guard verdicts: two consecutive failed
    # first draws trip it even though redraws recover each request
    adversarial_resp(3)
    adversarial_resp(4)
    assert health_report.counters().get("serve.breaker.trip") == 1
    assert "open" in {s["state"] for s in srv.breaker.snapshot().values()}

    # while open: generous deadline, but retries are suppressed
    A = np.asarray(adversarial_input(plan, N, seed=5))
    resp = _serve_one(srv, _req(rng, params=ADV_PARAMS, operand=A,
                                deadline_s=100.0))
    assert resp.attempts == 1 and resp.flagged
    assert any(f.guard == "breaker" for f in resp.health.findings)

    # after the cool-down a healthy request closes it again
    clock.advance(2.0)
    resp = _serve_one(srv, _req(rng, params=ADV_PARAMS))
    assert resp.status == OK
    assert all(s["state"] == "closed"
               for s in srv.breaker.snapshot().values())
    counts = health_report.counters()
    assert counts.get("serve.breaker.half_open") == 1
    assert counts.get("serve.breaker.close") == 1


def test_no_silent_failures_under_mixed_faults(rng):
    """The acceptance gate in miniature: every fault-touched response is
    flagged or explicitly rejected; clean requests still serve ok."""
    srv = _server(max_batch=4)
    plan = srv.plans.resolve("t", ADV_PARAMS)
    faulty, clean = [], []
    for i in range(12):
        if i % 4 == 1:
            A = np.asarray(inject_nan(_operand(rng), count=2, seed=i))
            faulty.append(srv.submit(_req(rng, operand=A)))
        elif i % 4 == 3:
            A = np.asarray(adversarial_input(plan, N, seed=i))
            faulty.append(srv.submit(
                _req(rng, params=ADV_PARAMS, operand=A)))
        else:
            clean.append(srv.submit(_req(rng)))
    srv.clock.advance(0.02)
    srv.run_pending(force=True)        # drain every group, in batch chunks
    for t in faulty:
        resp = srv.poll(t) if isinstance(t, int) else t
        assert resp.flagged or resp.rejected
    for t in clean:
        resp = srv.poll(t) if isinstance(t, int) else t
        assert resp.served and np.all(np.isfinite(resp.result))


# -- the threaded driver ---------------------------------------------------

def test_threaded_server_round_trip(rng):
    with ThreadedServer(max_batch=4, batch_wait_s=0.001) as srv:
        tickets = [srv.submit(_req(rng)) for _ in range(6)]
        resps = [srv.result(t, timeout=60.0) for t in tickets]
    assert all(r.status == OK for r in resps)
    assert srv.stats()["served"] == 6
