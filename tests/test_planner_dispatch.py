"""Regression tests for the PR-4 planner/dispatch bugfix sweep.

Covers the three fixes:
  1. ``make_plan(block_rows=)`` honors the pin (or raises) — no silent
     clamp — and ``autotune_plan`` dedupes its sweep by effective (M, Br).
  2. The gather-fused path never pads the (d_src, n) HBM operand at
     ragged ``n`` (the ragged last tile is handled in-kernel).
  3. ``sketch_vectors`` threads tn/dtype and resolves its tile via the
     SAME batched tuner shape class as ``sketch_apply_batched``.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.blockperm import make_plan
from repro.kernels import lowering, ops, tune


# ---------------------------------------------------------------------------
# Fix 1: block_rows pin
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_rows,k,want_br", [
    (2048, 1024, 2048),   # the ISSUE's verified silent-clamp case (was 256)
    (256, 64, 256),       # (was 16)
    (8, 64, 8),           # small pin, honored as before
    (100, 1024, 128),     # non-pow2 pin rounds UP, then honored
])
def test_make_plan_honors_block_rows_pin(block_rows, k, want_br):
    plan = make_plan(4096, k, kappa=4, s=2, block_rows=block_rows)
    assert plan.Br == want_br
    assert plan.k_pad == plan.M * plan.Br >= k
    assert plan.M >= 4          # kappa <= M stays realizable


def test_make_plan_pin_roundtrip_distinct_grids():
    """Doubling the pin must produce a DIFFERENT effective grid — the
    property autotune_plan's Br sweep relies on."""
    base = make_plan(4096, 1024, kappa=4, s=2, block_rows=1024)
    doubled = make_plan(4096, 1024, kappa=4, s=2, block_rows=2048)
    assert (base.M, base.Br) != (doubled.M, doubled.Br)
    assert doubled.Br == 2 * base.Br


def test_make_plan_unrealizable_pin_raises():
    with pytest.raises(ValueError, match="not realizable"):
        make_plan(256, 64, kappa=2, s=3, block_rows=8)   # 3 does not divide 8


def test_make_plan_auto_path_unchanged():
    """The auto (unpinned) planner still picks the PR-1 grids."""
    plan = make_plan(4096, 1024, kappa=4, s=2)
    assert plan.Br <= 256 and plan.k_pad >= 1024
    assert plan.M >= plan.kappa


def test_autotune_plan_dedupes_by_effective_grid(monkeypatch):
    timed = []

    def fake_autotune(plan, n, variant="fwd", **kw):
        timed.append((plan.M, plan.Br))
        return tune.TuneResult(tn=8, time_us=float(len(timed)),
                               source="tuned")

    monkeypatch.setattr(tune, "autotune", fake_autotune)
    # 24 and 32 both round to Br=32 -> one timing; 64 is distinct
    tune.autotune_plan(512, 128, 16, kappa=2, s=2,
                       block_rows_candidates=[24, 32, 64])
    assert len(timed) == len(set(timed)) == 2


def test_autotune_plan_default_sweep_has_no_duplicates(monkeypatch):
    timed = []

    def fake_autotune(plan, n, variant="fwd", **kw):
        timed.append((plan.M, plan.Br))
        return tune.TuneResult(tn=8, time_us=1.0, source="tuned")

    monkeypatch.setattr(tune, "autotune", fake_autotune)
    plan, res = tune.autotune_plan(4096, 1024, 16, kappa=1, s=2)
    assert len(timed) == len(set(timed)) == 3   # Br/2, Br, Br*2 all distinct
    assert res.block_rows == plan.Br


def test_autotune_plan_skips_kpad_inflating_candidates(monkeypatch):
    """With the pin honored, a Br*2 candidate can inflate k_pad when M is
    at the kappa floor — such plans sketch a DIFFERENT object and must not
    compete on raw launch time."""
    timed = []

    def fake_autotune(plan, n, variant="fwd", **kw):
        timed.append(plan.k_pad)
        return tune.TuneResult(tn=8, time_us=1.0, source="tuned")

    monkeypatch.setattr(tune, "autotune", fake_autotune)
    # kappa=4: base is (M=4, Br=256, k_pad=1024); br=512 would give
    # (M=4, Br=512, k_pad=2048) -> skipped
    plan, _ = tune.autotune_plan(4096, 1024, 16, kappa=4, s=2)
    assert timed and all(kp == 1024 for kp in timed)
    assert plan.k_pad == 1024


# ---------------------------------------------------------------------------
# Fix 2: ragged-n gather path never pads the source operand
# ---------------------------------------------------------------------------

def _all_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                yield from _all_eqns(v.jaxpr)


@pytest.mark.parametrize("dtype", [None, "bfloat16"])
@pytest.mark.parametrize("n", [33, 17, 7])
def test_gather_ragged_n_bit_exact(n, dtype, rng):
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    A = jnp.asarray(rng.normal(size=(700, n)), jnp.float32)
    idx = jnp.asarray(np.sort(rng.choice(700, 256, replace=False)), jnp.int32)
    fused = ops.sketch_apply(plan, A, "pallas", 16, dtype, row_index=idx)
    ref = ops.sketch_apply(plan, A[idx], "pallas", 16, dtype)
    assert fused.shape == (plan.k, n)
    assert np.array_equal(np.asarray(fused), np.asarray(ref))
    fb = ops.blockrow_apply(plan, A, "pallas", 16, dtype, row_index=idx)
    rb = ops.blockrow_apply(plan, A[idx], "pallas", 16, dtype)
    assert np.array_equal(np.asarray(fb), np.asarray(rb))


def test_gather_ragged_n_jaxpr_has_no_full_A_pad(rng):
    """The no-A-copy contract, checked structurally: at ragged n the jaxpr
    of the fused gather contains NO pad of the (d_src, n) operand."""
    d_src, n = 700, 33
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    A = jnp.asarray(rng.normal(size=(d_src, n)), jnp.float32)
    idx = jnp.asarray(np.sort(rng.choice(d_src, 256, replace=False)),
                      jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda X: ops.sketch_apply(plan, X, "pallas", 16, row_index=idx))(A)
    offending = [
        e for e in _all_eqns(jaxpr.jaxpr)
        if e.primitive.name == "pad"
        and any(getattr(v.aval, "shape", None) == (d_src, n)
                for v in e.invars)
    ]
    assert not offending, offending


def _column_pads(jaxpr, n):
    """pad eqns that widen a width-``n`` operand's column axis — the
    padded-copy pattern the ragged-n fix removed."""
    return [
        e for e in _all_eqns(jaxpr)
        if e.primitive.name == "pad"
        and any(getattr(v.aval, "shape", (0, 0))[-1:] == (n,)
                for v in e.invars)
        and e.outvars[0].aval.shape[-1] > n
    ]


@pytest.mark.parametrize("impl", ["pallas", "pallas_v1"])
def test_apply_ragged_n_jaxpr_has_no_column_pad(impl, rng):
    """sketch_apply / sketch_apply_t / blockrow_apply at ragged n must not
    materialize a column-padded copy of the operand (the remainder tile is
    handled in-kernel, like the gather path).  d == d_pad here, so the
    pallas fwd/blockrow traces contain no pad of the operand AT ALL."""
    n = 33
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    assert plan.d == plan.d_pad                      # no row pad either
    A = jnp.asarray(rng.normal(size=(256, n)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(plan.k, n)), jnp.float32)
    for fn, op in [
        (lambda X: ops.sketch_apply(plan, X, impl, 16), A),
        (lambda X: ops.sketch_apply_t(plan, X, impl, 16), Y),
        (lambda X: ops.blockrow_apply(plan, X, impl, 16), A),
    ]:
        jaxpr = jax.make_jaxpr(fn)(op)
        offending = _column_pads(jaxpr.jaxpr, n)
        assert not offending, offending


@pytest.mark.parametrize("dtype", [None, "bfloat16"])
@pytest.mark.parametrize("n", [33, 17, 7])
def test_apply_ragged_n_matches_oracle(n, dtype, rng):
    """Ragged-n v2/v1 launches agree with the xla oracle on every variant
    (the in-kernel edge tile must be value-identical to the old padded
    launch, whose outputs were sliced back to n)."""
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    A = jnp.asarray(rng.normal(size=(256, n)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(plan.k, n)), jnp.float32)
    for fwd, op in [(ops.sketch_apply, A), (ops.sketch_apply_t, Y),
                    (ops.blockrow_apply, A)]:
        ref = fwd(plan, op, "xla", None, dtype)
        for impl in ("pallas", "pallas_v1"):
            got = fwd(plan, op, impl, 16, dtype)
            assert got.shape == ref.shape
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       atol=1e-4, rtol=1e-4)


def test_gather_ragged_n_vjp(rng):
    """The scatter VJP survives the ragged-tile path."""
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    A = jnp.asarray(rng.normal(size=(700, 13)), jnp.float32)
    idx = jnp.asarray(np.sort(rng.choice(700, 256, replace=False)), jnp.int32)
    W = jnp.asarray(rng.normal(size=(plan.k, 13)), jnp.float32)
    g_fused = jax.grad(lambda A_: jnp.sum(
        W * ops.sketch_apply(plan, A_, "pallas", 16, row_index=idx)))(A)
    g_ref = jax.grad(lambda A_: jnp.sum(
        W * ops.sketch_apply(plan, A_[idx], "xla")))(A)
    np.testing.assert_allclose(np.asarray(g_fused), np.asarray(g_ref),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Fix 3: sketch_vectors == sketch_apply_batched tile resolution
# ---------------------------------------------------------------------------

def _record_lowerings(monkeypatch):
    """Spy on the engine: every LaunchSpec resolved through lower()."""
    calls = []
    orig = lowering.lower

    def spy(plan, spec):
        calls.append(spec)
        return orig(plan, spec)

    monkeypatch.setattr(lowering, "lower", spy)
    return calls


@pytest.mark.parametrize("use_gather", [False, True])
def test_sketch_vectors_resolves_like_batched(use_gather, monkeypatch, rng):
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    B = 6
    if use_gather:
        x = jnp.asarray(rng.normal(size=(B, 700)), jnp.float32)
        idx = jnp.asarray(np.sort(rng.choice(700, 256, replace=False)),
                          jnp.int32)
    else:
        x = jnp.asarray(rng.normal(size=(B, 256)), jnp.float32)
        idx = None
    calls = _record_lowerings(monkeypatch)
    ops.sketch_vectors(plan, x, "pallas", row_index=idx)
    v_specs = [s for s in calls if s.batch > 1]
    calls.clear()
    ops.sketch_apply_batched(plan, x[:, :, None], "pallas", row_index=idx)
    b_specs = [s for s in calls if s.batch > 1]
    # identical batched LaunchSpec: per-matrix width 1, batch folded over
    # B, same gather flag — the two entry points CANNOT resolve different
    # launches because they lower the same spec through the same engine
    assert len(v_specs) == len(b_specs) == 1
    assert v_specs[0] == b_specs[0] == lowering.LaunchSpec(
        op="fwd", n=1, impl="pallas", gather=use_gather, batch=B)


def test_sketch_vectors_threads_tn_and_dtype(rng):
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    x = jnp.asarray(rng.normal(size=(5, 256)), jnp.float32)
    y = ops.sketch_vectors(plan, x, "pallas", 8, "bfloat16")
    want = ops.sketch_apply(plan, x.T, "pallas", 8, "bfloat16").T
    assert np.array_equal(np.asarray(y), np.asarray(want))
    # and the bf16 stream actually changes the result vs fp32
    y32 = ops.sketch_vectors(plan, x, "pallas", 8)
    assert not np.array_equal(np.asarray(y), np.asarray(y32))


def test_sketch_vectors_uses_batched_cache_winner(monkeypatch, rng):
    """A tuned winner cached under the batched shape class must be served
    to BOTH batch entry points."""
    tune.clear_cache()
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    B = 6
    key = tune.cache_key(plan, 1, "fwd", batch=B)
    tune._CACHE[key] = tune.TuneResult(tn=16, time_us=1.0, source="tuned")
    try:
        seen = []
        orig = lowering.execute

        def spy(lw, operand, row_index=None):
            seen.append(lw.tn)
            return orig(lw, operand, row_index=row_index)

        monkeypatch.setattr(lowering, "execute", spy)
        x = jnp.asarray(rng.normal(size=(B, 256)), jnp.float32)
        ops.sketch_vectors(plan, x, "pallas")
        ops.sketch_apply_batched(plan, x[:, :, None], "pallas")
        assert seen == [16, 16]
    finally:
        tune.clear_cache()
