"""Wiring invariants: full-cycle affine maps, edge-disjointness, bi-regularity."""
import numpy as np
import pytest

from repro.core import wiring


@pytest.mark.parametrize("M", [2, 4, 8, 16, 64, 256, 1024])
@pytest.mark.parametrize("seed", [0, 1, 7, 123])
def test_full_cycle(M, seed):
    a, b = wiring.derive_affine_params(seed, M)
    x = 0
    seen = set()
    for _ in range(M):
        x = (a * x + b) % M
        seen.add(x)
    assert len(seen) == M, "affine map must be a single M-cycle"


@pytest.mark.parametrize("M,kappa", [(4, 2), (8, 4), (16, 8), (64, 16), (256, 4)])
@pytest.mark.parametrize("seed", [0, 3, 42])
def test_edge_disjoint_and_biregular(M, kappa, seed):
    pi = wiring.wiring_table(seed, M, kappa)
    assert pi.shape == (kappa, M)
    assert wiring.check_edge_disjoint(pi)
    assert wiring.check_biregular(pi)
    # every row is a permutation
    for ell in range(kappa):
        assert len(set(pi[ell].tolist())) == M


def test_neighbor_fused_matches_iterated():
    M, seed = 64, 5
    a, b = wiring.derive_affine_params(seed, M)
    for g in [0, 1, 17, 63]:
        for ell in range(1, 9):
            assert wiring.neighbor(g, ell, a, b, M) == \
                wiring.neighbor_fused(g, ell, a, b, M)


def test_wiring_jnp_matches_numpy():
    pi_np = wiring.wiring_table(9, 32, 5)
    pi_j = np.asarray(wiring.wiring_jnp(9, 32, 5))
    np.testing.assert_array_equal(pi_np, pi_j)


def test_non_pow2_rejected():
    with pytest.raises(ValueError):
        wiring.derive_affine_params(0, 12)
