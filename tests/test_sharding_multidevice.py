"""Sharded execution on a small host-device mesh (subprocess: the main test
process must keep 1 device per the assignment).  Verifies:
  * the pjit train step RUNS (not just compiles) on a (2,2) mesh,
  * results match the single-device step bit-for-bit-ish,
  * sketched gradient compression works under shard_map with a pod axis.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import smoke_config
    from repro.configs.registry import ARCHS
    from repro.data import pipeline as dp
    from repro.launch import mesh as mesh_lib
    from repro.optim import adamw, grad_compress as gc
    from repro.sharding import partition as pt
    from repro.train import train_step as ts

    cfg = smoke_config(ARCHS["internlm2-1.8b"])
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    step_fn, model = ts.build_train_step(cfg, opt_cfg)

    data_cfg = dp.DataConfig(vocab_size=cfg.vocab_size, global_batch=4,
                             seq_len=16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in dp.make_batch(data_cfg, 0).items()}

    # ---- single-device reference
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init_state(params, opt_cfg)
    p1, o1, _, m1 = jax.jit(step_fn)(params, opt, {}, batch)
    ref_loss = float(m1["loss"])

    # ---- (2,2) mesh pjit run
    mesh = mesh_lib.make_mesh((2, 2), ("data", "model"))
    ctx = ts.sharding_ctx_for(mesh, cfg)
    pspecs = pt.param_pspecs(params, ctx)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda s: isinstance(s, P))
    with mesh, pt.activate(ctx):
        params_sh = jax.device_put(params, ns(pspecs))
        opt_sh = jax.device_put(opt, ns({"m": pspecs, "v": pspecs, "step": P()}))
        batch_sh = jax.device_put(batch, ns({k: P(("data",), None) for k in batch}))
        p2, o2, _, m2 = jax.jit(step_fn)(params_sh, opt_sh, {}, batch_sh)
        sharded_loss = float(m2["loss"])
        # parameters after one step agree with the single-device run
        diffs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            p1, jax.device_get(p2))
        max_diff = max(jax.tree.leaves(diffs))

    # ---- shard_map pod-axis gradient compression
    from jax.experimental.shard_map import shard_map
    pod_mesh = mesh_lib.make_mesh((2,), ("pod",))
    ccfg = gc.CompressConfig(ratio=4, min_bucket=256)
    g_global = {"w": jnp.asarray(np.random.default_rng(0)
                                 .normal(size=(2, 2048)), jnp.float32)}
    err0 = {"w": jnp.zeros((2, 2048), jnp.float32)}

    def per_pod(g, e):
        gh, ne = gc.compress_gradients(
            ccfg, {"w": g[0]}, {"w": e[0]}, pod_axis="pod", step=0)
        return gh["w"][None], ne["w"][None]

    with pod_mesh:
        gh, ne = shard_map(
            per_pod, mesh=pod_mesh,
            in_specs=(P("pod", None), P("pod", None)),
            out_specs=(P("pod", None), P("pod", None)))(
                g_global["w"], err0["w"])
        # both pods must hold the SAME compressed gradient (psum'd in
        # sketch space with a shared-seed sketch)
        gh_np = np.asarray(jax.device_get(gh))
        pod_agree = float(np.max(np.abs(gh_np[0] - gh_np[1])))

    print(json.dumps({
        "ref_loss": ref_loss, "sharded_loss": sharded_loss,
        "max_param_diff": max_diff, "pod_agree": pod_agree,
    }))
""")


@pytest.mark.slow
def test_sharded_step_matches_single_device(tmp_path):
    script = tmp_path / "sharded_run.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["ref_loss"] - res["sharded_loss"]) < 1e-3
    assert res["max_param_diff"] < 5e-2          # bf16-ish tolerance
    assert res["pod_agree"] < 1e-5
