"""Multi-device sketching tests (PR-4 acceptance set).

Two layers:
  * in-process — the per-ℓ partial kernel/oracle building blocks on the
    single test device (interpret-mode Pallas), including the
    exact-reconstruction property a psum relies on;
  * subprocess — the real shard_map paths on 8 forced host devices (the
    ``test_sharding_multidevice`` pattern: the main test process must keep
    1 device): row/col/batch-sharded applies must be ``array_equal`` to
    single-device across κ ∈ {1, 2} and both streaming dtypes, and the
    distributed sketch-and-precondition solver must converge.
"""
import json
import math
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.blockperm import make_plan
from repro.distributed import (check_row_partition, local_partial_apply,
                               partial_tables, plan_for_mesh)
from repro.kernels import ops, ref as kref


# ---------------------------------------------------------------------------
# in-process: partial kernel / oracle building blocks
# ---------------------------------------------------------------------------

def _shard_and_reassemble(plan, A, num_shards, *, impl, rows_pattern=False,
                          tn=8):
    """Emulate the sharded protocol serially: per-shard partials, summed
    (the psum), ℓ-ordered fold, scale, truncate."""
    M_loc = check_row_partition(plan, num_shards)
    Ap = kref.pad_input(plan, A)
    acc = None
    for p in range(num_shards):
        slab = Ap[p * M_loc * plan.Bc:(p + 1) * M_loc * plan.Bc]
        parts = local_partial_apply(plan, slab, p * M_loc, impl=impl, tn=tn,
                                    rows_pattern=rows_pattern)
        acc = parts if acc is None else acc + parts
    Y = acc[0]
    for ell in range(1, plan.kappa):
        Y = Y + acc[ell]
    scale = plan.scale
    if rows_pattern:
        scale *= math.sqrt(plan.d_pad / plan.k_pad)
    return (Y * scale)[: plan.k]


@pytest.mark.parametrize("kappa,dtype", [(1, "float32"), (2, "float32"),
                                         (2, "bfloat16")])
def test_partial_oracle_reassembles_bit_exact(kappa, dtype, rng):
    """Serial shard emulation of the xla partials == single-device xla
    apply, BITWISE — the property that makes the psum'd path exact."""
    plan = make_plan(500, 128, kappa=kappa, s=2, block_rows=16, seed=5,
                     dtype=dtype)
    A = jnp.asarray(rng.normal(size=(500, 9)), jnp.float32)
    Y = _shard_and_reassemble(plan, A, 4, impl="xla")
    ref = ops.sketch_apply(plan, A, "xla")
    assert np.array_equal(np.asarray(Y), np.asarray(ref))


@pytest.mark.parametrize("rows_pattern", [False, True])
def test_partial_pallas_kernel_matches_oracle(rows_pattern, rng):
    """The fused partial Pallas kernel == the jnp partial oracle on each
    shard's slab (interpret mode)."""
    plan = make_plan(500, 128, kappa=2, s=2, block_rows=16, seed=5)
    A = jnp.asarray(rng.normal(size=(500, 8)), jnp.float32)
    Yk = _shard_and_reassemble(plan, A, 2, impl="pallas",
                               rows_pattern=rows_pattern)
    ref_fn = ops.blockrow_apply if rows_pattern else ops.sketch_apply
    ref = ref_fn(plan, A, "pallas", 8)
    np.testing.assert_allclose(np.asarray(Yk), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_partial_tables_partition_covers_every_pair():
    """Ownership across shards is a PARTITION of the κ·M (g, ℓ) pairs —
    exactly one shard owns each — which is what makes psum exact.  The
    compact tables list each shard's owned pairs explicitly; their union
    must tile the full grid with no overlap."""
    plan = make_plan(500, 128, kappa=2, s=2, block_rows=16, seed=5)
    num = 4
    M_loc = check_row_partition(plan, num)
    for ell in range(plan.kappa):
        gs = np.concatenate([
            np.asarray(partial_tables(plan, p * M_loc, M_loc))[0, ell]
            for p in range(num)])
        assert np.array_equal(np.sort(gs), np.arange(plan.M))
    # blockrow's masked tables carry an explicit owned flag instead
    owned_sum = sum(
        np.asarray(partial_tables(plan, p * M_loc, M_loc,
                                  rows_pattern=True))[2]
        for p in range(num))
    assert np.array_equal(owned_sum, np.ones((plan.kappa, plan.M), np.int64))


def test_partial_apply_nonowned_slices_are_exact_zero(rng):
    """local_partial_apply returns the GLOBAL layout with exact zeros at
    every (ℓ, g) pair another shard owns."""
    plan = make_plan(500, 128, kappa=2, s=2, block_rows=16, seed=5)
    M_loc = plan.M // 4
    Ap = kref.pad_input(plan, jnp.asarray(rng.normal(size=(500, 8)),
                                          jnp.float32))
    slab = Ap[: M_loc * plan.Bc]
    parts = local_partial_apply(plan, slab, 0, impl="pallas", tn=8)
    tabs = np.asarray(partial_tables(plan, 0, M_loc))    # (2, kappa, M_loc)
    parts_np = np.asarray(parts).reshape(plan.kappa, plan.M, plan.Br, -1)
    for ell in range(plan.kappa):
        owned_g = set(tabs[0, ell].tolist())
        for g in range(plan.M):
            if g not in owned_g:
                assert np.all(parts_np[ell, g] == 0.0)
            else:
                assert np.any(parts_np[ell, g] != 0.0)


def test_partial_pallas_vmem_overflow_falls_back(rng):
    """A plan whose (Br, Bc) Φ tile busts VMEM at any tile width must not
    launch the partial kernel — impl='pallas' silently degrades to the jnp
    oracle (there is no v1 partial), mirroring ops' fused→v1 fallback."""
    from repro.distributed import partial_fits_vmem
    plan = plan_for_mesh(262_144, 1024, 8, kappa=2)
    assert not partial_fits_vmem(plan, 8)
    A = jnp.asarray(rng.normal(size=(262_144, 4)), jnp.float32)
    Ap = kref.pad_input(plan, A)
    M_loc = plan.M // 8
    slab = Ap[: M_loc * plan.Bc]
    got = local_partial_apply(plan, slab, 0, impl="pallas", tn=None)
    want = local_partial_apply(plan, slab, 0, impl="xla")
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_dist_cost_model_rejects_unsharded_variants():
    from repro.roofline import sketch_model
    plan = plan_for_mesh(4096, 256, 4, kappa=2)
    with pytest.raises(ValueError, match="fwd"):
        sketch_model.dist_sketch_cost(plan, 16, 4, variant="blockrow")


def test_check_row_partition_rejects_bad_split():
    plan = make_plan(500, 128, kappa=2, s=2, block_rows=16, seed=5)  # M=8
    assert check_row_partition(plan, 4) == 2
    with pytest.raises(ValueError, match="divide"):
        check_row_partition(plan, 3)


def test_plan_for_mesh_divisible_grid():
    for num in (2, 4, 8):
        plan = plan_for_mesh(10_000, 200, num, kappa=2)
        assert plan.M % num == 0
        assert plan.k_pad >= 200


def test_lsqr_operator_matches_dense_lsqr(rng):
    """The injected-ops LSQR is the dense solver when fed A's products
    (the refactor contract dist_solvers relies on)."""
    from repro.kernels import ops as kops
    from repro.solvers import lsqr, lsqr_operator

    A = jnp.asarray(rng.normal(size=(400, 12)), jnp.float32)
    b = A @ jnp.asarray(rng.normal(size=(12,)), jnp.float32)
    plan = make_plan(400, 48, kappa=2, s=2, seed=1)
    _, R = kops.sketch_qr(plan, A, "xla")
    dense = lsqr(A, b, R=R, tol=1e-5)
    viaops = lsqr_operator(lambda v: A @ v, lambda u: A.T @ u, b,
                           nvars=12, R=R, tol=1e-5)
    assert viaops.converged and dense.converged
    assert viaops.iterations == dense.iterations
    # same recurrence, but separately-compiled programs: fp32 rounding may
    # differ per iteration — identical to solver precision, not bitwise
    np.testing.assert_allclose(np.asarray(viaops.x), np.asarray(dense.x),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# subprocess: the real shard_map paths on 8 forced host devices
# ---------------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.core.blockperm import make_plan
    from repro.distributed import (dist_sketch_precondition_lstsq,
                                   sketch_apply_batched_sharded,
                                   sketch_apply_colsharded,
                                   sketch_apply_sharded)
    from repro.kernels import ops
    from repro.launch import mesh as mesh_lib

    rng = np.random.default_rng(0)
    mesh = mesh_lib.make_mesh((8,), ("shard",))
    out = {"exact": {}, "solver": {}}

    A = jnp.asarray(rng.normal(size=(3000, 16)), jnp.float32)
    for kappa in (1, 2):
        for dtype in ("float32", "bfloat16"):
            plan = make_plan(3000, 256, kappa=kappa, s=2, seed=3,
                             block_rows=32, dtype=dtype)
            ref = ops.sketch_apply(plan, A)
            key = f"kappa{kappa}_{dtype}"
            out["exact"]["row_" + key] = bool(np.array_equal(
                np.asarray(sketch_apply_sharded(plan, A, mesh, "shard")),
                np.asarray(ref)))
            out["exact"]["col_" + key] = bool(np.array_equal(
                np.asarray(sketch_apply_colsharded(plan, A, mesh, "shard")),
                np.asarray(ref)))
            G = jnp.asarray(rng.normal(size=(8, 3000, 4)), jnp.float32)
            out["exact"]["batch_" + key] = bool(np.array_equal(
                np.asarray(sketch_apply_batched_sharded(
                    plan, G, mesh, "shard")),
                np.asarray(ops.sketch_apply_batched(plan, G))))

    # blockrow row-sharded (the appendix variant shares the partial path)
    plan = make_plan(3000, 256, kappa=2, s=2, seed=3, block_rows=32)
    out["exact"]["row_blockrow"] = bool(np.array_equal(
        np.asarray(sketch_apply_sharded(plan, A, mesh, "shard",
                                        rows_pattern=True)),
        np.asarray(ops.blockrow_apply(plan, A))))

    # batch-sharded gather-fused (the distributed GraSS layout)
    plan_g = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    idx = jnp.asarray(np.sort(rng.choice(3000, 256, replace=False)),
                      jnp.int32)
    out["exact"]["batch_gather"] = bool(np.array_equal(
        np.asarray(sketch_apply_batched_sharded(
            plan_g, G, mesh, "shard", row_index=idx)),
        np.asarray(ops.sketch_apply_batched(plan_g, G, row_index=idx))))

    # batch-sharded GraSS featurize == single-device features
    from repro.attribution import mlp as mlp_lib
    from repro.attribution.grass import GrassPipeline, GrassPipelineConfig
    mcfg = mlp_lib.MLPConfig(d_in=32, hidden=(16,), steps=5)
    xg, yg = mlp_lib.make_synthetic_mnist(32, 32, mcfg.n_classes, seed=0)
    params = mlp_lib.train_mlp(mcfg, xg, yg)
    gcfg = GrassPipelineConfig(sparse_dim=128, sketch_dim=32, chunk=4)
    f_single = GrassPipeline(gcfg, params).featurize(xg, yg)
    f_shard = GrassPipeline(gcfg, params, mesh=mesh, shard_axis="shard")
    f_sharded = f_shard.featurize(xg, yg)
    out["exact"]["grass_featurize"] = bool(np.allclose(
        np.asarray(f_single), np.asarray(f_sharded), atol=1e-5))
    out["exact"]["grass_no_quarantine"] = f_shard.quarantined == 0

    # distributed sketch-and-precondition: converges, matches single-device
    d, n = 4096, 24
    Am = jnp.asarray(rng.normal(size=(d, n)), jnp.float32)
    b = Am @ jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    res = dist_sketch_precondition_lstsq(Am, b, mesh, "shard", tol=1e-5)
    x_np, *_ = np.linalg.lstsq(np.asarray(Am), np.asarray(b), rcond=None)
    out["solver"] = {
        "converged": bool(res.converged),
        "iterations": int(res.iterations),
        "relres": float(res.relres),
        "x_err": float(np.max(np.abs(np.asarray(res.x) - x_np))),
    }

    # guarded distributed solve: the replica-consistency guard must see the
    # psum'd SA bit-identical on all 8 devices and accept draw #1
    resg = dist_sketch_precondition_lstsq(Am, b, mesh, "shard", tol=1e-5,
                                          guard=True)
    out["solver"]["guarded_converged"] = bool(resg.converged)
    out["solver"]["guarded_status"] = resg.health.status
    out["solver"]["guarded_attempts"] = int(resg.health.attempts)
    out["solver"]["guarded_replica_ok"] = any(
        f.guard == "replica_consistency" and f.status == "healthy"
        for f in resg.health.findings)
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_sharded_apply_matches_single_device(tmp_path):
    script = tmp_path / "dist_run.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert all(res["exact"].values()), res["exact"]
    assert res["solver"]["converged"], res["solver"]
    assert res["solver"]["iterations"] <= 40
    assert res["solver"]["x_err"] < 1e-3
    assert res["solver"]["guarded_converged"], res["solver"]
    assert res["solver"]["guarded_status"] in ("healthy", "degraded")
    assert res["solver"]["guarded_attempts"] == 1, res["solver"]
    assert res["solver"]["guarded_replica_ok"], res["solver"]
