"""Registry-wide conformance suite: every sketch family, the same battery.

Parametrized over ``repro.core.variants.SKETCH_FAMILIES`` — a family
enrolls in the FULL battery by registering, with no new test code:

  * unbiasedness      — E[SᵀS] = I over independent seeds;
  * Frobenius band    — ‖SA‖_F/‖A‖_F inside a fixed-seed isometry band;
  * bit-determinism   — two instances from one seed agree bitwise;
  * VJP round-trip    — the apply's VJP equals Sᵀ of the dense-materialized
                        oracle (S recovered by sketching the identity);
  * ragged-n          — a non-tile-aligned column count equals the aligned
                        launch's shared prefix (in-kernel tail masking);
  * gather fusion     — ``apply_gather(A, idx)`` == materialize-then-sketch.

Families whose constructor takes ``impl`` (the engine-lowered ones) run
the exactness checks through the Pallas kernels (interpret mode off-TPU),
so the battery exercises the real launch path, not just the oracle; the
statistical checks use the default (fast) dispatch — they are properties
of the sketch DISTRIBUTION, not of a kernel.

Precision riders: ``blockperm_bf16`` / ``blockperm_fp8`` enroll in the
family battery like any other registration, and a separate
policy-parametrized block runs the isometry check against EACH policy's
own tolerance band from ``core.precision`` — an fp8 draw is judged
against the widened fp8 band, never the fp32 one.  Exactness
comparisons against dense oracles read the per-policy
``exactness_atol`` for the same reason.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision
from repro.core.variants import SKETCH_FAMILIES, make_sketch
from repro.health import guards

D, K, N = 96, 64, 24
FAMILIES = sorted(SKETCH_FAMILIES)
POLICIES = sorted(precision.POLICIES)


def _accepts_impl(name: str) -> bool:
    return "impl" in inspect.signature(SKETCH_FAMILIES[name].__init__).parameters


def _make(name: str, seed: int = 0, kernel: bool = False):
    """One conformance instance; ``kernel=True`` pins the Pallas path for
    families that have one (interpret mode on CPU)."""
    kw = {"impl": "pallas"} if kernel and _accepts_impl(name) else {}
    return make_sketch(name, D, K, seed=seed, **kw)


def _emulate_stream(sk, A: jnp.ndarray) -> jnp.ndarray:
    """Round A through the family's streaming policy (seeded, so the
    stochastic-rounding families reproduce the kernel's exact draws), so
    dense-oracle comparisons see the precision the kernel streams at."""
    plan = getattr(sk, "plan", None)
    if plan is None:
        return A
    return precision.emulate_stream(A, plan.precision, seed=plan.seed)


def _atol(sk, default: float = 5e-4) -> float:
    """Oracle-comparison tolerance: the family's policy band, not fp32's."""
    plan = getattr(sk, "plan", None)
    if plan is None:
        return default
    return max(default, plan.precision.exactness_atol)


def _dense_S(sk) -> jnp.ndarray:
    """The dense (k, d) S recovered by sketching the identity — the oracle
    every exactness check compares against (linearity makes it exact)."""
    return sk.apply(jnp.eye(D, dtype=jnp.float32))


@pytest.mark.parametrize("family", FAMILIES)
def test_unbiasedness_of_StS(family):
    """E[SᵀS] = I_d: mean over independent seeds of the (d, d) Gram."""
    if not SKETCH_FAMILIES[family].unbiased:
        # declared-biased family (blockrow trades E[SᵀS] = I for
        # single-pass reads) — assert the declaration is honest, i.e. the
        # bias is real, so a silently-fixed family must re-enroll.
        S = np.asarray(_make(family, seed=0).apply(
            jnp.eye(D, dtype=jnp.float32)), np.float64)
        assert abs(float(np.trace(S.T @ S)) / D - 1.0) > 0.1
        pytest.skip(f"{family} declares unbiased=False (documented)")
    n_seeds = 48
    acc = np.zeros((D, D), np.float64)
    for seed in range(n_seeds):
        S = np.asarray(_make(family, seed=seed).apply(
            jnp.eye(D, dtype=jnp.float32)), np.float64)
        acc += S.T @ S
    mean = acc / n_seeds
    err = np.abs(mean - np.eye(D))
    # diagonal concentrates like 1/√(k·n_seeds); fixed seeds keep this
    # deterministic, the band is ~4σ for the widest-variance family (dense)
    assert err.max() < 0.25, err.max()
    assert np.abs(np.diag(mean) - 1.0).mean() < 0.05


@pytest.mark.parametrize("family", FAMILIES)
def test_frobenius_isometry_band(family, rng):
    A = jnp.asarray(rng.normal(size=(D, N)), jnp.float32)
    for seed in (0, 1, 2):
        Y = _make(family, seed=seed).apply(A)
        ratio = float(jnp.linalg.norm(Y) / jnp.linalg.norm(A))
        # k = 64 gives √(2/k) ≈ 0.18 one-σ Frobenius fluctuation; the
        # band is wide enough for every family incl. the fragile blockrow
        assert 0.5 < ratio < 1.5, (family, seed, ratio)


@pytest.mark.parametrize("family", FAMILIES)
def test_bit_determinism(family, rng):
    A = jnp.asarray(rng.normal(size=(D, N)), jnp.float32)
    Y1 = np.asarray(_make(family, seed=7, kernel=True).apply(A))
    Y2 = np.asarray(_make(family, seed=7, kernel=True).apply(A))
    assert np.array_equal(Y1, Y2), family
    Y3 = np.asarray(_make(family, seed=8, kernel=True).apply(A))
    assert not np.array_equal(Y1, Y3), f"{family}: seed ignored"


@pytest.mark.parametrize("family", FAMILIES)
def test_vjp_round_trip_vs_dense_oracle(family, rng):
    """d/dA ⟨ct, S A⟩ = Sᵀ ct — the apply's VJP must equal the transpose
    of the dense-materialized S.  Runs the DEFAULT dispatch: the engine
    families' custom_vjp rule fires regardless of impl (the transpose op
    is the rule), and forward-only kernels (blockrow's gather) stay
    differentiable through their oracle."""
    sk = _make(family, seed=3)
    A = jnp.asarray(rng.normal(size=(D, N)), jnp.float32)
    Y, vjp = jax.vjp(sk.apply, A)
    ct = jnp.asarray(rng.normal(size=Y.shape), jnp.float32)
    (got,) = vjp(ct)
    S = _dense_S(sk)
    # bf16-streaming families round the cotangent at the kernel boundary
    want = S.T @ _emulate_stream(sk, ct)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=_atol(sk))


@pytest.mark.parametrize("family", FAMILIES)
def test_ragged_n_exactness(family, rng):
    """A ragged column count (n=19, no tile alignment) must equal the
    shared prefix of the wider launch — tails are masked, never folded."""
    sk = _make(family, seed=5, kernel=True)
    A = jnp.asarray(rng.normal(size=(D, 32)), jnp.float32)
    full = np.asarray(sk.apply(A))
    ragged = np.asarray(sk.apply(A[:, :19]))
    assert ragged.shape[1] == 19
    np.testing.assert_allclose(ragged, full[:, :19], rtol=0, atol=1e-5)


@pytest.mark.parametrize("family", FAMILIES)
def test_gather_fused_matches_materialize(family, rng):
    """apply_gather(A, idx) == apply(A[idx]) — fused row-DMA kernels and
    the base-class materializing fallback meet the same contract."""
    sk = _make(family, seed=9, kernel=True)
    d_src = D + 32
    A = jnp.asarray(rng.normal(size=(d_src, N)), jnp.float32)
    idx = jnp.asarray(rng.choice(d_src, size=D, replace=False), jnp.int32)
    got = np.asarray(sk.apply_gather(A, idx))
    want = np.asarray(sk.apply(A[idx]))
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-5)


@pytest.mark.parametrize("family", FAMILIES)
def test_batched_apply_matches_loop(family, rng):
    """apply_batched folds the stack into one launch; it must equal the
    per-example loop exactly (columnwise linearity)."""
    sk = _make(family, seed=11, kernel=True)
    A = jnp.asarray(rng.normal(size=(3, D, N)), jnp.float32)
    got = np.asarray(sk.apply_batched(A))
    want = np.stack([np.asarray(sk.apply(A[b])) for b in range(3)])
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# precision-policy conformance: each policy judged against ITS OWN band
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_isometry_within_policy_band(policy, rng):
    """The Frobenius ratio of a policy-streamed sketch must sit inside
    that policy's OWN isometry band — the fp8 rows pass the widened fp8
    band (they are healthy fp8 sketches), and the guard invoked with the
    per-policy kwargs agrees."""
    p = precision.resolve(policy)
    A = jnp.asarray(rng.normal(size=(D, N)), jnp.float32)
    for seed in (0, 1, 2):
        sk = make_sketch("blockperm", D, K, kappa=2, s=2, seed=seed,
                         dtype=policy, impl="pallas")
        Y = sk.apply(A)
        ratio = float(jnp.linalg.norm(Y) / jnp.linalg.norm(A))
        assert abs(ratio - 1.0) < p.isometry_tol, (policy, seed, ratio)
        finding = guards.isometry_guard(A, Y, "SA", **p.isometry_band())
        assert finding.status == "healthy", (policy, seed, finding)


@pytest.mark.parametrize("policy", ["fp8_e4m3", "fp8_e4m3_sr",
                                    "fp8_e5m2", "fp8_e5m2_sr"])
def test_fp8_kernel_matches_seeded_oracle(policy, rng):
    """The Pallas launch of an fp8 plan equals the dense oracle applied
    to the seeded stream-quantized operand, within the policy's
    exactness band — the end-to-end statement that the kernel's
    in-flight quantization IS ``precision.quantize_stream``."""
    A = jnp.asarray(rng.normal(size=(D, N)), jnp.float32)
    sk = make_sketch("blockperm", D, K, kappa=2, s=2, seed=4,
                     dtype=policy, impl="pallas")
    got = np.asarray(sk.apply(A))
    S = _dense_S(sk)
    want = np.asarray(S @ _emulate_stream(sk, A))
    np.testing.assert_allclose(got, want, rtol=0, atol=_atol(sk))
