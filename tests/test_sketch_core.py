"""BLOCKPERM-SJLT structural invariants + ref-vs-dense-materialization checks."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import wiring
from repro.core.blockperm import (
    BlockPermPlan, dense_block, make_plan, materialize_sketch_matrix,
)
from repro.kernels import ref as kref


PLANS = [
    dict(d=256, k=64, kappa=1, s=1, seed=0),
    dict(d=256, k=64, kappa=2, s=2, seed=1),
    dict(d=300, k=96, kappa=3, s=2, seed=7, block_rows=16),
    dict(d=512, k=128, kappa=4, s=4, seed=3, block_rows=32),
    dict(d=128, k=128, kappa=2, s=1, seed=5, block_rows=16),
]


@pytest.mark.parametrize("kw", PLANS)
def test_structure(kw):
    plan = make_plan(**kw)
    S = np.asarray(materialize_sketch_matrix(plan))
    # (i) exactly κs nonzeros per column, magnitude 1/√(κs)
    nnz = (np.abs(S) > 0).sum(axis=0)
    assert np.all(nnz == plan.nnz_per_col), "every column must have κs nonzeros"
    mags = np.abs(S[np.abs(S) > 0])
    np.testing.assert_allclose(mags, plan.scale, rtol=1e-6)
    # (ii) block bipartite graph is κ-regular and edge-disjoint
    pi = wiring.wiring_table(plan.seed, plan.M, plan.kappa)
    assert wiring.check_edge_disjoint(pi) and wiring.check_biregular(pi)
    # (iii) block sparsity mask matches the wiring
    for g in range(plan.M):
        row_blk = S[g * plan.Br:(g + 1) * plan.Br]
        live = set()
        for h in range(plan.M):
            if np.any(row_blk[:, h * plan.Bc:(h + 1) * plan.Bc] != 0):
                live.add(h)
        assert live <= set(int(x) for x in pi[:, g]), \
            "nonzero blocks outside the sampled neighborhood"


@pytest.mark.parametrize("kw", PLANS)
@pytest.mark.parametrize("n", [1, 17, 64])
def test_ref_matches_dense(kw, n, rng):
    plan = make_plan(**kw)
    A = jnp.asarray(rng.normal(size=(plan.d, n)), jnp.float32)
    S = materialize_sketch_matrix(plan)
    Y_dense = S @ kref.pad_input(plan, A)
    Y_ref = kref.flashsketch_ref(plan, A)
    np.testing.assert_allclose(np.asarray(Y_ref), np.asarray(Y_dense),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("kw", PLANS[:3])
def test_transpose_matches_dense(kw, rng):
    plan = make_plan(**kw)
    Y = jnp.asarray(rng.normal(size=(plan.k, 9)), jnp.float32)
    S = materialize_sketch_matrix(plan)
    X_dense = (S.T @ Y)[: plan.d]
    X_ref = kref.flashsketch_transpose_ref(plan, Y)
    np.testing.assert_allclose(np.asarray(X_ref), np.asarray(X_dense),
                               atol=1e-4, rtol=1e-4)


def test_kappa1_is_block_diagonal():
    """κ=1 must reduce to the localized (block-diagonal-per-permutation) SJLT."""
    plan = make_plan(d=256, k=128, kappa=1, s=2, seed=11, block_rows=16)
    S = np.asarray(materialize_sketch_matrix(plan))
    pi = wiring.wiring_table(plan.seed, plan.M, plan.kappa)
    for g in range(plan.M):
        row_blk = S[g * plan.Br:(g + 1) * plan.Br]
        for h in range(plan.M):
            blk = row_blk[:, h * plan.Bc:(h + 1) * plan.Bc]
            if h == int(pi[0, g]):
                assert np.any(blk != 0)
            else:
                assert np.all(blk == 0)


def test_row_partition_one_nnz_per_chunk():
    """Row-partitioned SJLT: each column has exactly one nonzero per chunk."""
    plan = make_plan(d=128, k=64, kappa=2, s=4, seed=2, block_rows=16)
    phi = np.asarray(dense_block(plan, 0, plan.neighbors(0)[0]))
    chunk = plan.chunk
    for i in range(plan.s):
        sub = phi[i * chunk:(i + 1) * chunk]
        assert np.all((np.abs(sub) > 0).sum(axis=0) == 1)


def test_unbiased_norm_preservation(rng):
    """E‖Sx‖² = ‖x‖² over sketch draws (paper Lemma A.1 energy identity)."""
    x = jnp.asarray(rng.normal(size=(512, 1)), jnp.float32)
    vals = []
    for seed in range(60):
        p = make_plan(d=512, k=256, kappa=4, s=2, seed=seed)
        y = kref.flashsketch_ref(p, x)
        vals.append(float(jnp.sum(y ** 2) / jnp.sum(x ** 2)))
    mean = np.mean(vals)
    se = np.std(vals) / np.sqrt(len(vals))
    assert abs(mean - 1.0) < 4 * se + 0.02, (mean, se)


def test_grad_is_transpose(rng):
    plan = make_plan(d=96, k=48, kappa=2, s=2, seed=4, block_rows=8)
    from repro.kernels import ops
    A = jnp.asarray(rng.normal(size=(plan.d, 5)), jnp.float32)
    W = jnp.asarray(rng.normal(size=(plan.k, 5)), jnp.float32)
    f = lambda a: jnp.vdot(ops.sketch_apply(plan, a, "xla"), W)
    g = jax.grad(f)(A)
    S = materialize_sketch_matrix(plan)
    expected = (S.T @ W)[: plan.d]
    np.testing.assert_allclose(np.asarray(g), np.asarray(expected), atol=1e-4)
