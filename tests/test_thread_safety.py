"""Concurrency regression tests for the tuner cache and lowering memo.

The serving layer loads/saves/consults the tuner cache and resolves
lowerings from worker threads.  Without the RLock guards these hammers
reliably die with ``RuntimeError: dictionary changed size during
iteration`` (``save_cache`` iterating ``_CACHE`` while ``load_cache``
inserts) or serve stale-tile lowering records across a generation flush.
"""
import json
import threading

import pytest

from repro.core.blockperm import make_plan
from repro.kernels import lowering, tune


def _cache_file(tmp_path, plans, n=256, tn=128):
    """A valid tuner-cache JSON with one row per (plan, variant)."""
    payload = {}
    for plan in plans:
        for variant in ("fwd", "transpose"):
            key = tune.cache_key(plan, n, variant)
            payload[json.dumps(list(key))] = {
                "tn": tn, "block_rows": None, "time_us": 1.0,
                "source": "tuned"}
    path = tmp_path / "winners.json"
    path.write_text(json.dumps(payload))
    return str(path)


def _hammer(workers, iters=60):
    """Run each worker fn iters times on its own thread; re-raise the
    first exception any of them hit."""
    errors = []

    def run(fn):
        try:
            for _ in range(iters):
                fn()
        except Exception as e:        # pragma: no cover - the failure path
            errors.append(e)

    threads = [threading.Thread(target=run, args=(fn,)) for fn in workers]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    if errors:
        raise errors[0]


@pytest.fixture(autouse=True)
def _fresh_caches():
    tune.clear_cache()
    lowering.clear_lowering_cache()
    yield
    tune.clear_cache()
    lowering.clear_lowering_cache()


def test_tuner_cache_concurrent_load_save_clear(tmp_path):
    # vary the SHAPE CLASS (cache_key ignores the seed): d × k × κ
    plans = [make_plan(d, k, kappa=kp, s=2)
             for d in (128, 256, 512) for k in (32, 64)
             for kp in (1, 2, 4)]
    src = _cache_file(tmp_path, plans)
    dst = str(tmp_path / "out.json")
    gen0 = tune.cache_generation()

    _hammer([
        lambda: tune.load_cache(src),
        lambda: tune.load_cache(src, merge=False),
        lambda: tune.save_cache(dst),
        lambda: tune.clear_cache(),
        lambda: [tune.lookup(p, 256, "fwd") for p in plans],
    ])

    # the registry is still coherent: a final load serves every winner
    tune.clear_cache()
    kept = tune.load_cache(src)
    assert kept == 2 * len(plans)
    for plan in plans:
        hit = tune.lookup(plan, 256, "fwd")
        assert hit is not None and hit.tn == 128 and hit.source == "loaded"
    # every mutation bumped the generation (atomically with its flush)
    assert tune.cache_generation() > gen0


def test_lowering_memo_concurrent_with_generation_flushes(tmp_path):
    plans = [make_plan(512, 64, kappa=2, s=2, seed=sd) for sd in range(6)]
    src = _cache_file(tmp_path, plans, tn=128)
    # impl="pallas": the auto path lowers to the tile-less xla oracle on
    # CPU; the pallas (interpret-mode) path exercises tile resolution
    specs = [lowering.LaunchSpec(op="fwd", n=256, impl="pallas", batch=b)
             for b in (1, 4)]

    def lower_all():
        for plan in plans:
            for spec in specs:
                lw = lowering.lower(plan, spec)
                assert lw.tn >= 1

    _hammer([
        lower_all,
        lower_all,
        lambda: tune.load_cache(src),     # bumps the generation → flush
        lambda: tune.clear_cache(),       # bumps it again
    ])

    # post-condition: with the tuned winners loaded last, the memo serves
    # the tuned tile (no stale record survived the flush races)
    tune.clear_cache()
    lowering.clear_lowering_cache()
    tune.load_cache(src)
    for plan in plans:
        assert lowering.lower(plan, specs[0]).tn == 128


def test_save_cache_snapshot_under_concurrent_insert(tmp_path):
    """save_cache must iterate a SNAPSHOT: concurrent inserts used to
    raise 'dictionary changed size during iteration'."""
    plans = [make_plan(256, 8 * (i + 1), kappa=1, s=1) for i in range(16)]
    src = _cache_file(tmp_path, plans)
    tune.load_cache(src)
    dst = str(tmp_path / "snap.json")

    _hammer([
        lambda: tune.save_cache(dst),
        lambda: tune.load_cache(src),
        lambda: tune.load_cache(src, merge=False),
    ], iters=120)

    # the atomically-replaced file is always a complete valid cache
    assert tune.load_cache(dst) > 0
