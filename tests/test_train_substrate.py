"""Training substrate: optimizer, data pipeline, checkpoint, trainer loop,
grad compression (error feedback), fault-tolerance policies."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.configs.registry import ARCHS
from repro.data import pipeline as dp
from repro.optim import adamw
from repro.optim import grad_compress as gc
from repro.train import checkpoint as ckpt
from repro.train import fault_tolerance as ft
from repro.train.trainer import Trainer, TrainerConfig


# ---------------------------------------------------------------------- data

def test_data_determinism_and_sharding():
    cfg = dp.DataConfig(vocab_size=97, global_batch=8, seq_len=16, seed=3)
    b1 = dp.make_batch(cfg, step=5)
    b2 = dp.make_batch(cfg, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = dp.make_batch(cfg, step=6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host slices tile the global batch
    parts = [dp.make_batch(cfg, 5, host_id=h, n_hosts=4)["tokens"]
             for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_data_has_learnable_structure():
    cfg = dp.DataConfig(vocab_size=64, global_batch=16, seq_len=64, seed=0)
    b = dp.make_batch(cfg, 0)
    perm = dp._bigram_next_state(cfg)
    frac = np.mean(perm[b["tokens"]] == b["labels"])
    assert frac > 0.7   # alpha=0.9 bigram transitions dominate


def test_prefetcher():
    cfg = dp.DataConfig(vocab_size=97, global_batch=4, seq_len=8)
    pf = dp.Prefetcher(cfg, start_step=2)
    step, batch = next(pf)
    assert step == 2
    np.testing.assert_array_equal(batch["tokens"],
                                  dp.make_batch(cfg, 2)["tokens"])
    pf.close()


# ----------------------------------------------------------------- optimizer

def test_adamw_reduces_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=100, clip_norm=0.0)
    params = {"w": jnp.ones((4, 4)) * 3.0}
    state = adamw.init_state(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 0.5


def test_adamw_bf16_states():
    cfg = adamw.AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = adamw.init_state(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((8,), jnp.bfloat16)}
    p2, s2, m = adamw.apply_updates(params, g, state, cfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert np.isfinite(float(m["grad_norm"]))


# ---------------------------------------------------------------- compression

def test_grad_compress_error_feedback_reduces_bias():
    """EF: averaged-over-steps compressed grads converge to the true grad."""
    cfg = gc.CompressConfig(ratio=8, min_bucket=64, kappa=4, s=2)
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(4096,)), jnp.float32)}
    err = gc.init_error_state(g_true)
    acc = jnp.zeros_like(g_true["w"])
    T = 32
    for t in range(T):
        g_hat, err = gc.compress_gradients(cfg, g_true, err, step=t)
        acc = acc + g_hat["w"]
    mean_rel = float(jnp.linalg.norm(acc / T - g_true["w"])
                     / jnp.linalg.norm(g_true["w"]))
    # single-shot error for comparison
    g1, _ = gc.compress_gradients(cfg, g_true, gc.init_error_state(g_true))
    one_rel = float(jnp.linalg.norm(g1["w"] - g_true["w"])
                    / jnp.linalg.norm(g_true["w"]))
    assert mean_rel < one_rel * 0.5, (mean_rel, one_rel)
    # error-feedback state stays bounded (contraction; no divergence).
    # EF theory: ‖e‖ ≲ ‖g‖/δ with δ = γ·coverage ≈ k/(k+d) — for ratio 8
    # that allows ~(1/0.11)≈9× with slow transients; 30× is the sanity rail.
    assert float(jnp.linalg.norm(err["w"])) < \
        30 * float(jnp.linalg.norm(g_true["w"]))


def test_grad_compress_ef_diverges_without_damping():
    """Negative control: γ=1 (no damping) + fixed S is NOT contractive."""
    cfg = gc.CompressConfig(ratio=8, min_bucket=64, damping=1.0,
                            n_rotations=1)
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(4096,)), jnp.float32)}
    err = gc.init_error_state(g_true)
    for t in range(12):
        _, err = gc.compress_gradients(cfg, g_true, err, step=t)
    assert float(jnp.linalg.norm(err["w"])) > \
        100 * float(jnp.linalg.norm(g_true["w"]))


def test_grad_compress_small_leaves_passthrough():
    cfg = gc.CompressConfig(ratio=8, min_bucket=1024)
    g = {"small": jnp.ones((10,)), "norm": jnp.ones((3,))}
    err = gc.init_error_state(g)
    g2, _ = gc.compress_gradients(cfg, g, err)
    np.testing.assert_allclose(np.asarray(g2["small"]), 1.0)


def test_wire_bytes_reduction():
    cfg = gc.CompressConfig(ratio=8, min_bucket=1024)
    params = {"a": jnp.zeros((1 << 16,)), "b": jnp.zeros((64,))}
    wb = gc.wire_bytes(cfg, params)
    assert wb["reduction"] > 4.0


# ---------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16)},
            "step": jnp.int32(7)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 100, tree)
    assert ckpt.latest_step(d) == 100
    restored, step = ckpt.restore(d, 100, tree)
    assert step == 100
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == np.dtype("bfloat16") or \
        str(restored["b"]["c"].dtype) == "bfloat16"


def test_checkpoint_async_and_prune(tmp_path):
    d = str(tmp_path / "ck")
    ac = ckpt.AsyncCheckpointer()
    tree = {"w": jnp.zeros((8, 8))}
    for s in (10, 20, 30, 40):
        ac.save_async(d, s, tree)
    ac.wait()
    ckpt.prune_old(d, keep=2)
    assert ckpt.latest_step(d) == 40
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d))
    assert len(steps) == 2


def test_checkpoint_atomic_no_partial(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.zeros((4,))}
    ckpt.save(d, 1, tree)
    # a stale .tmp dir from a crashed writer must not be visible
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert ckpt.latest_step(d) == 1


# ---------------------------------------------------------------- trainer

def test_trainer_loss_decreases_and_restarts(tmp_path):
    cfg = smoke_config(ARCHS["qwen3-0.6b"])
    data_cfg = dp.DataConfig(vocab_size=cfg.vocab_size, global_batch=4,
                             seq_len=32, seed=0)
    opt = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40,
                            weight_decay=0.0)
    tcfg = TrainerConfig(total_steps=30, ckpt_every=10,
                         ckpt_dir=str(tmp_path / "ck"), log_every=1000)
    tr = Trainer(cfg, opt, tcfg, data_cfg, log_fn=lambda s: None)
    out = tr.fit()
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.2, (first, last)
    # restart: resumes from latest checkpoint, runs only remaining steps
    tcfg2 = TrainerConfig(total_steps=35, ckpt_every=10,
                          ckpt_dir=str(tmp_path / "ck"), log_every=1000)
    tr2 = Trainer(cfg, opt, tcfg2, data_cfg, log_fn=lambda s: None)
    out2 = tr2.fit()
    assert out2["steps"] == 5


def test_trainer_with_compression_trains(tmp_path):
    cfg = smoke_config(ARCHS["internlm2-1.8b"])
    data_cfg = dp.DataConfig(vocab_size=cfg.vocab_size, global_batch=4,
                             seq_len=32, seed=0)
    opt = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40,
                            weight_decay=0.0)
    comp = gc.CompressConfig(ratio=4, min_bucket=4096)
    tcfg = TrainerConfig(total_steps=25, ckpt_every=1000, log_every=1000)
    tr = Trainer(cfg, opt, tcfg, data_cfg, compress=comp,
                 log_fn=lambda s: None)
    out = tr.fit()
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5]) - 0.1


# ------------------------------------------------------------ fault tolerance

def test_heartbeat_and_straggler():
    t = [0.0]
    clock = lambda: t[0]
    hb = ft.HeartbeatMonitor(["h0", "h1", "h2"], timeout_s=10, clock=clock)
    t[0] = 5.0
    hb.beat("h0")
    hb.beat("h1")
    t[0] = 12.0
    assert hb.dead_hosts() == ["h2"]
    sd = ft.StragglerDetector(patience=2, k_sigma=1.5)
    for _ in range(5):
        for h in ("h0", "h1", "h2", "h3"):
            sd.record(h, 1.0)
        sd.record("h4", 10.0)
        sd.stragglers()
    assert "h4" in sd.stragglers()


def test_elastic_planner_shrinks_data_axis():
    pl = ft.ElasticPlanner(model_parallel=16, chips_per_host=4,
                           global_batch=256)
    full = pl.plan(alive_hosts=64)       # 256 chips
    assert full.data == 16 and full.model == 16
    degraded = pl.plan(alive_hosts=33)   # 132 chips -> data 8
    assert degraded.data == 8
    assert degraded.chips <= 33 * 4


def test_supervisor_survives_failures(tmp_path):
    calls = {"n": 0}
    saved = {"step": 0}

    def run_segment(plan, start):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("simulated node loss")
        for s in range(start, min(start + 10, 30)):
            saved["step"] = s + 1
        return saved["step"]

    pl = ft.ElasticPlanner(model_parallel=2, chips_per_host=2, global_batch=8)
    hb = ft.HeartbeatMonitor(["h0", "h1"], timeout_s=1e9)
    sup = ft.TrainSupervisor(pl, hb, restore_latest=lambda: saved["step"],
                             run_segment=run_segment)
    rep = sup.run(total_steps=30)
    assert rep.steps_done == 30
    assert rep.restarts == 1
