import os

# Tests run on the single real CPU device (the 512-device override is ONLY
# for launch/dryrun.py, per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _fresh_health_counters():
    """Zero the process-global guard-event counters before every test, so
    counter-delta assertions never depend on which tests ran earlier."""
    from repro.health import report
    report.reset_counters()
    yield
