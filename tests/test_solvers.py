"""Solver-layer correctness: sketch-and-precondition LSQR against the
dense reference, sketch-and-solve residual bounds, sketched SVD, batched
apply, and multisketch restart determinism."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.blockperm import make_plan
from repro.kernels import ops
from repro.configs.flashsketch_paper import SOLVER_PRESETS
from repro.solvers import (
    lsqr,
    multisketch_lstsq,
    pcg_normal,
    sketch_and_solve_lstsq,
    sketch_precondition_lstsq,
    sketched_svd,
    solve_preset,
)

D, N = 2048, 48
COND = 1e3


def _ls_problem(d=D, n=N, cond=COND, seed=0, noise=0.0):
    rng = np.random.default_rng(seed)
    U, _ = np.linalg.qr(rng.normal(size=(d, n)))
    V, _ = np.linalg.qr(rng.normal(size=(n, n)))
    svals = np.logspace(0.0, -np.log10(cond), n)
    A = ((U * svals) @ V.T).astype(np.float32)
    x_true = rng.normal(size=n).astype(np.float32)
    b = A @ x_true
    if noise:
        b = b + noise * rng.normal(size=d).astype(np.float32)
    return jnp.asarray(A), jnp.asarray(b.astype(np.float32))


@pytest.fixture(scope="module")
def problem():
    return _ls_problem()


@pytest.fixture(scope="module")
def unprecond_iters(problem):
    A, b = problem
    return lsqr(A, b, tol=1e-5, max_iters=600).iterations


@pytest.mark.parametrize("kappa", [1, 2, 4])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_precond_lsqr_matches_lstsq(problem, unprecond_iters, kappa, dtype):
    """Preconditioned LSQR reaches the lstsq reference solution, in far
    fewer iterations than unpreconditioned, for every sketch quality."""
    A, b = problem
    res = sketch_precondition_lstsq(
        A, b, kappa=kappa, dtype=dtype, seed=3, tol=1e-5, max_iters=300)
    assert res.converged, (kappa, dtype, res.relres)
    assert res.relres <= 1e-5
    assert res.iterations < unprecond_iters
    x_ref = jnp.linalg.lstsq(A, b)[0]
    # solution error amplification is bounded by cond(A) * relres
    rel_err = float(jnp.linalg.norm(res.x - x_ref)
                    / jnp.linalg.norm(x_ref))
    assert rel_err <= COND * 1e-5 * 5, (kappa, dtype, rel_err)


def test_precond_cg_converges(problem):
    A, b = problem
    res = sketch_precondition_lstsq(A, b, method="cg", tol=1e-8,
                                    max_iters=100)
    # CG's tol is on the normal-equation residual; check the real one
    # against a loose bound and that it actually iterated to convergence.
    assert res.converged
    assert res.relres <= 1e-3
    assert res.iterations < 100


def test_precond_chol_matches_qr(problem):
    A, b = problem
    r_qr = sketch_precondition_lstsq(A, b, factorization="qr",
                                     tol=1e-5, seed=1)
    r_ch = sketch_precondition_lstsq(A, b, factorization="chol",
                                     tol=1e-5, seed=1)
    assert r_qr.converged and r_ch.converged
    np.testing.assert_allclose(np.asarray(r_qr.x), np.asarray(r_ch.x),
                               rtol=0, atol=5e-3)


def test_sketch_qr_factor_identity():
    """R from ops.sketch_qr satisfies SAᵀSA = RᵀR for both factorizations,
    and the two factorizations agree (positive-diagonal convention)."""
    A, _ = _ls_problem(seed=5)
    plan = make_plan(D, 4 * N, kappa=4, s=2, seed=5)
    SA, R_qr = ops.sketch_qr(plan, A, factorization="qr")
    _, R_ch = ops.sketch_qr(plan, A, factorization="chol")
    G = np.asarray(SA.T @ SA)
    np.testing.assert_allclose(np.asarray(R_qr.T @ R_qr), G,
                               rtol=1e-4, atol=1e-4 * np.abs(G).max())
    assert np.allclose(np.asarray(jnp.tril(R_qr, -1)), 0.0)
    np.testing.assert_allclose(np.asarray(R_qr), np.asarray(R_ch),
                               rtol=0, atol=2e-2 * np.abs(G).max() ** 0.5)


def test_batched_apply_matches_loop(rng):
    plan = make_plan(512, 64, kappa=2, s=2, seed=0)
    A = jnp.asarray(rng.normal(size=(3, 512, 17)).astype(np.float32))
    Y = ops.sketch_apply_batched(plan, A)
    assert Y.shape == (3, plan.k, 17)
    for i in range(3):
        np.testing.assert_allclose(
            np.asarray(Y[i]), np.asarray(ops.sketch_apply(plan, A[i])),
            rtol=1e-5, atol=1e-5)


def test_sketch_and_solve_residual_bound():
    """Sketch-and-solve residual is within the (1+eps)/(1-eps) factor of
    the optimum on an INCONSISTENT system (where the bound is non-trivial)."""
    A, b = _ls_problem(cond=10, seed=7, noise=0.05)
    x_ref = jnp.linalg.lstsq(A, b)[0]
    res_opt = float(jnp.linalg.norm(A @ x_ref - b))
    plan = make_plan(D, 8 * (N + 1), kappa=4, s=2, seed=7)
    x_ss = sketch_and_solve_lstsq(plan, A, b)
    res_ss = float(jnp.linalg.norm(A @ x_ss - b))
    # eps ~ sqrt(n/k) ~ 0.35 -> bound ~2; assert with slack but enough to
    # catch a broken sketch (a random x gives residual >> 2x optimal)
    assert res_opt <= res_ss <= 2.0 * res_opt, (res_opt, res_ss)


def test_sketched_svd_exact_on_lowrank(rng):
    r = 8
    L = (rng.normal(size=(D, r)) @ rng.normal(size=(r, 96))).astype(np.float32)
    Lj = jnp.asarray(L)
    plan = make_plan(D, 64, kappa=4, s=2, seed=0)
    U, svals, Vt = sketched_svd(plan, Lj, rank=r)
    assert U.shape == (D, r) and svals.shape == (r,) and Vt.shape == (r, 96)
    err = float(jnp.linalg.norm(U @ jnp.diag(svals) @ Vt - Lj)
                / jnp.linalg.norm(Lj))
    assert err <= 1e-4, err
    # singular values sorted and positive
    sv = np.asarray(svals)
    assert np.all(sv[:-1] >= sv[1:] - 1e-5) and np.all(sv > 0)


def test_sketched_svd_requires_capacity():
    plan = make_plan(D, 16, kappa=2, s=2, seed=0)
    A = jnp.zeros((D, 32), jnp.float32)
    with pytest.raises(ValueError, match="rank"):
        sketched_svd(plan, A, rank=max(plan.k + 1, 30), oversample=8)


def test_multisketch_restart_determinism(problem):
    """Fixed master seed => bitwise-identical trajectory, iterates, and
    restart bookkeeping; different seed => different sketch draws."""
    A, b = problem
    r1 = multisketch_lstsq(A, b, seed=42, tol=1e-5)
    r2 = multisketch_lstsq(A, b, seed=42, tol=1e-5)
    assert r1.seeds == r2.seeds
    assert r1.iterations == r2.iterations
    assert r1.restarts == r2.restarts
    assert np.array_equal(np.asarray(r1.x), np.asarray(r2.x))
    r3 = multisketch_lstsq(A, b, seed=43, tol=1e-5)
    assert r3.seeds != r1.seeds
    # all derived seeds distinct within a run
    flat = [s for round_seeds in r1.seeds for s in round_seeds]
    assert len(set(flat)) == len(flat)


def test_derive_seed_family_streams_never_collide():
    """Mixing sketch families under ONE master seed must draw from
    provably disjoint seed streams — across families AND across the
    redraw/escalation rungs each family may climb (regression: before the
    stream partition, a countsketch draw could reuse a blockperm seed and
    correlate the hash tables)."""
    from repro.solvers.multisketch import (_STREAM_MASK, _STREAM_SHIFT,
                                           derive_seed, family_stream)
    families = ("blockperm", "countsketch", "graph")
    assert len({family_stream(f) for f in families}) == len(families)
    master, seen = 12345, {}
    for family in families:
        stream = family_stream(family)
        for rnd in range(8):          # restart rounds / ladder indices
            for slot in range(4):     # redraw / κ / γ / resketch slots
                s = derive_seed(master, rnd, slot, stream=stream)
                # the stream id is recoverable from the seed's top bits
                assert (s >> _STREAM_SHIFT) & _STREAM_MASK == stream
                assert s not in seen, ((family, rnd, slot), seen.get(s))
                seen[s] = (family, rnd, slot)
    assert len(seen) == len(families) * 8 * 4
    # stream-less derivation inherits the master's stream: raw small
    # master seeds (the historical call sites) stay in stream 0 …
    assert (derive_seed(master, 0, 0) >> _STREAM_SHIFT) & _STREAM_MASK == 0
    # … and re-deriving from an already-derived seed STAYS in-family, so
    # escalation ladders never leak across the partition
    s1 = derive_seed(master, 0, 0, stream=family_stream("graph"))
    s2 = derive_seed(s1, 3, 1)
    assert (s2 >> _STREAM_SHIFT) & _STREAM_MASK == family_stream("graph")
    with pytest.raises(ValueError, match="no seed stream registered"):
        family_stream("nope")


def test_family_solver_builds_the_registered_construction(problem):
    """``sketch_precondition_lstsq(family=...)`` must build THE family the
    registry names — canonical s (countsketch 1, graph 4) and the
    family's stream-derived seed, exactly as ``variants.make_sketch``
    does (regression: the solver used to forward the generic s=2 default
    and the raw seed, making countsketch and graph solves bitwise
    identical)."""
    from repro.core.variants import make_sketch
    from repro.solvers.sketch_precondition import sketch_precondition_lstsq
    A, b = problem
    results = {}
    for family in ("countsketch", "graph"):
        res = sketch_precondition_lstsq(A, b, family=family, seed=3,
                                        tol=1e-6)
        p = res.lowering.plan
        ref = make_sketch(family, A.shape[0], p.k_req, seed=3).plan
        assert (p.family, p.s, p.seed) == (ref.family, ref.s, ref.seed)
        assert res.converged
        results[family] = np.asarray(res.x)
    assert not np.array_equal(results["countsketch"], results["graph"])


def test_multisketch_converges(problem, unprecond_iters):
    A, b = problem
    res = multisketch_lstsq(A, b, seed=0, tol=1e-5)
    assert res.converged
    assert res.relres <= 1e-5
    assert res.iterations < unprecond_iters


def test_lsqr_restart_beats_plain_fp32(problem):
    """The exact-residual restart is load-bearing in fp32: a single long
    chunk (no restart) stalls above what the restarted solver reaches."""
    A, b = problem
    plan = make_plan(D, 4 * N, kappa=4, s=2, seed=0)
    _, R = ops.sketch_qr(plan, A)
    plain = lsqr(A, b, R=R, tol=1e-7, max_iters=120, restart_every=120)
    restarted = lsqr(A, b, R=R, tol=1e-7, max_iters=120, restart_every=40)
    assert restarted.relres <= plain.relres * 1.5
    assert restarted.relres <= 1e-5


@pytest.mark.parametrize("name", sorted(SOLVER_PRESETS))
def test_solver_presets_run(problem, name):
    """Every named preset solves the benchmark problem sensibly.  'precise'
    targets 1e-10 (an f64 tolerance) — in this fp32 suite it reaches the
    precision floor; its iteration spend stays bounded by max_iters."""
    A, b = problem
    res = solve_preset(A, b, name)
    assert res.relres <= 1e-2, (name, res.relres)
    if name == "direct":
        assert res.iterations == 0
    elif name == "precise":
        assert res.relres <= 1e-5
        assert res.iterations <= SOLVER_PRESETS[name].max_iters
    else:
        assert res.converged, (name, res.relres)


def test_invalid_factorization_rejected_everywhere(problem):
    A, b = problem
    with pytest.raises(ValueError, match="factorization"):
        ops.sketch_qr(make_plan(D, 4 * N, seed=0), A,
                      factorization="cholesky")
    with pytest.raises(ValueError, match="factorization"):
        multisketch_lstsq(A, b, seed=0, factorization="cholesky")


def test_pcg_normal_iterates(problem):
    A, b = problem
    plan = make_plan(D, 4 * N, kappa=4, s=2, seed=0)
    _, R = ops.sketch_qr(plan, A)
    res = pcg_normal(A, b, R, tol=1e-10, max_iters=60)
    assert res.iterations > 1
    assert res.relres <= 1e-3
