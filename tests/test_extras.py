"""Additional coverage: SRHT correctness, serve loop, elastic restore,
roofline-table formatting, cost-model sanity."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.variants import SRHTSketch, make_sketch


def _hadamard(n):
    H = np.array([[1.0]])
    while H.shape[0] < n:
        H = np.block([[H, H], [H, -H]])
    return H


def test_fwht_matches_explicit_hadamard(rng):
    for n in (2, 8, 64):
        x = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
        got = np.asarray(SRHTSketch.fwht(x))
        want = _hadamard(n) @ np.asarray(x)
        np.testing.assert_allclose(got, want, atol=1e-4)


def test_srht_norm_preservation(rng):
    d, k = 512, 256
    x = jnp.asarray(rng.normal(size=(d, 1)), jnp.float32)
    ratios = []
    for seed in range(20):
        sk = make_sketch("srht", d, k, seed=seed)
        y = sk.apply(x)
        ratios.append(float(jnp.sum(y ** 2) / jnp.sum(x ** 2)))
    assert abs(np.mean(ratios) - 1.0) < 0.15, np.mean(ratios)


def test_cost_models_are_ordered():
    """Structural sanity of the TPU cost models at paper-regime shapes:
    blockrow reads A once < blockperm (κ reads) < scatter-SJLT (atomics)."""
    d, k, n = 65_536, 2048, 512
    br = make_sketch("blockrow", d, k).cost_model(n).hbm_bytes
    bp = make_sketch("blockperm", d, k).cost_model(n).hbm_bytes
    sj = make_sketch("sjlt", d, k, s=8).cost_model(n).hbm_bytes
    assert br < bp < sj


def test_serve_generate_smoke():
    from repro.configs.base import smoke_config
    from repro.configs.registry import ARCHS
    from repro.launch.generate import generate
    from repro.models.factory import build_model, extra_inputs_concrete

    cfg = smoke_config(ARCHS["internlm2-1.8b"])
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    prompts = jax.random.randint(key, (2, 4), 0, cfg.vocab_size, jnp.int32)
    toks, tps = generate(model, params, prompts, gen=4,
                         extra=extra_inputs_concrete(cfg, 2, 4, key))
    assert toks.shape == (2, 8)
    assert tps > 0
    # greedy decoding is deterministic
    toks2, _ = generate(model, params, prompts, gen=4,
                        extra=extra_inputs_concrete(cfg, 2, 4, key))
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint saved once restores under a *different* sharding target
    (the elastic re-mesh path): device_put onto new shardings."""
    from repro.train import checkpoint as ckpt
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 5, tree)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    restored, step = ckpt.restore(d, 5, tree, shardings={"w": sharding})
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_roofline_table_formats(tmp_path, monkeypatch):
    import benchmarks.roofline_table as rt
    rec = {"status": "ok", "arch": "a", "shape": "train_4k", "mesh": "pod256",
           "chips": 256, "compute_s": 1.0, "memory_s": 2.0,
           "collective_s": 0.5, "bottleneck": "memory",
           "model_flops": 256 * 197e12, "device_flops": 2 * 197e12,
           "device_hbm_bytes": 1.0, "device_coll_bytes": 1.0,
           "coll_breakdown": {}, "useful_ratio": 0.5, "step_time_s": 2.0,
           "arg_bytes_per_device": 2**30, "temp_bytes_per_device": 2**30,
           "fits_hbm": True, "note": ""}
    os.makedirs(tmp_path / "dr", exist_ok=True)
    with open(tmp_path / "dr" / "a_train_4k_pod256.json", "w") as f:
        json.dump(rec, f)
    monkeypatch.setattr(rt, "DRYRUN_DIR", str(tmp_path / "dr"))
    md = rt.table_markdown()
    assert "| a | train_4k | pod256 |" in md
    assert "memory" in md
    # skip rows render the reason
    with open(tmp_path / "dr" / "b_long_500k_pod256.json", "w") as f:
        json.dump({"status": "skip", "arch": "b", "shape": "long_500k",
                   "mesh": "pod256", "reason": "SKIP(full-attn@524k)"}, f)
    md = rt.table_markdown()
    assert "SKIP(full-attn@524k)" in md


def test_sketch_vectors_grad(rng):
    """Gradient flows through the batched vector API (GraSS featurize path)."""
    from repro.core.blockperm import make_plan
    from repro.kernels import ops
    plan = make_plan(d=128, k=32, kappa=2, s=2, block_rows=8, seed=1)
    x = jnp.asarray(rng.normal(size=(3, 128)), jnp.float32)
    g = jax.grad(lambda xx: jnp.sum(ops.sketch_vectors(plan, xx, "xla") ** 2))(x)
    assert np.all(np.isfinite(np.asarray(g)))
    assert float(jnp.linalg.norm(g)) > 0
