"""Guarded sketching tests (PR-6 acceptance set).

Four layers:
  * report/policy units — verdict ordering, HealthReport supersede
    semantics, the deterministic escalation-ladder attempt sequence;
  * guards on manufactured artifacts — every injector class from
    ``repro.health.inject`` must be DETECTED by its guard (NaN operand,
    bad-draw input, corrupt tuner cache, psum corruption, VMEM overflow);
  * recovery — the redraw ladder converges on the adversarially coherent
    input within the escalation budget, deterministically across runs; the
    Cholesky→QR factor downgrade rescues a rank-deficient Gram; corrupted
    caches fall back to the heuristic; non-finite gradient rows are
    quarantined out of the GraSS feature cache;
  * integration — ``sketch_precondition_lstsq(guard=True)`` still
    converges on well-posed problems with attempts == 1, ``HealthReport``
    counters appear on ``SolveResult`` and in ``explain()`` output, and
    the whole injector suite passes end to end.
"""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blockperm import make_plan
from repro.health import guards, inject, report
from repro.health.policy import Attempt, RedrawPolicy
from repro.kernels import lowering, ops, tune
from repro.solvers.sketch_precondition import sketch_precondition_lstsq


# ---------------------------------------------------------------------------
# report / policy units
# ---------------------------------------------------------------------------

def test_worst_status_ordering():
    assert report.worst_status() == report.HEALTHY
    assert report.worst_status("healthy", "degraded") == report.DEGRADED
    assert report.worst_status("degraded", "failed", "healthy") == report.FAILED
    with pytest.raises(ValueError):
        report.worst_status("fine")


def test_health_report_supersede_semantics():
    """A recovered artifact's later finding supersedes the bad draw: the
    report's status reflects the ACCEPTED state, the history stays."""
    rpt = report.HealthReport(op="t")
    rpt.add(report.GuardFinding("isometry", "SA", report.FAILED))
    assert rpt.status == report.FAILED
    rpt.act("redraw(seed=1)")
    rpt.add(report.GuardFinding("isometry", "SA", report.HEALTHY))
    assert rpt.status == report.HEALTHY
    assert len(rpt.findings) == 2 and rpt.actions == ["redraw(seed=1)"]
    j = rpt.to_json()
    assert j["counters"]["isometry.failed"] == 1
    assert j["counters"]["isometry.healthy"] == 1


def test_global_counters_roundtrip():
    report.reset_counters()
    report.record("guard.test.failed", detail="x")
    report.record("guard.test.failed")
    assert report.counters() == {"guard.test.failed": 2}
    assert ("guard.test.failed", "x") in report.recent_events()
    assert json.loads(report.counters_json()) == {"guard.test.failed": 2}
    assert "guard.test.failed=2" in report.summarize_counters()
    report.reset_counters()
    assert report.summarize_counters() == "no guard events recorded"


def test_policy_attempt_sequence_deterministic():
    pol = RedrawPolicy(max_redraws=2, max_kappa_bumps=1, max_sampling_bumps=1)
    seq1 = list(pol.attempts(seed=7, kappa=2, sampling_factor=4.0))
    seq2 = list(pol.attempts(seed=7, kappa=2, sampling_factor=4.0))
    assert seq1 == seq2                       # pure function of the knobs
    assert len(seq1) == pol.budget == 5
    assert [a.action for a in seq1] == [
        "initial", "redraw", "redraw", "kappa_bump", "sampling_bump"]
    assert seq1[0] == Attempt(0, "initial", 7, 2, 4.0)
    # every non-initial attempt uses a FRESH derived seed
    seeds = [a.seed for a in seq1]
    assert len(set(seeds)) == len(seeds)
    assert seq1[3].kappa == 4 and seq1[4].sampling_factor == 8.0


def test_policy_kappa_cap_and_plan_sizing():
    pol = RedrawPolicy(max_redraws=0, max_kappa_bumps=3, kappa_cap=8,
                       max_sampling_bumps=0)
    seq = list(pol.attempts(seed=0, kappa=4, sampling_factor=4.0))
    # 4 -> 8, then capped: only one bump possible
    assert [a.kappa for a in seq] == [4, 8]
    # sampling_bump attempts ignore an explicit k and grow the sketch
    pol2 = RedrawPolicy(max_redraws=0, max_kappa_bumps=0,
                        max_sampling_bumps=1)
    init, bump = pol2.attempts(seed=0, kappa=2, sampling_factor=4.0)
    p0 = pol2.plan_for(init, 512, 16, s=2, k=80)
    p1 = pol2.plan_for(bump, 512, 16, s=2, k=80)
    assert p0.k_req == 80 and p1.k_req == 128      # 8.0 * 16
    assert p1.seed != p0.seed


# ---------------------------------------------------------------------------
# guards: every injector class is DETECTED
# ---------------------------------------------------------------------------

def test_finite_guard_detects_injected_nan_and_inf(rng):
    clean = rng.normal(size=(16, 8)).astype(np.float32)
    assert guards.finite_guard(clean).status == report.HEALTHY
    bad = inject.inject_nan(clean, count=5, seed=3)
    f = guards.finite_guard(bad, "operand")
    assert f.status == report.FAILED and f.value == 5.0
    # deterministic: same (array, seed) poisons the same entries
    assert np.array_equal(np.isnan(bad),
                          np.isnan(inject.inject_nan(clean, count=5, seed=3)))
    f2 = guards.finite_guard(
        inject.inject_nan(clean, count=1, seed=0, value=float("inf")))
    assert f2.status == report.FAILED


def test_guards_skip_under_tracer():
    """Guards return None (check skipped) inside jit instead of crashing —
    guarded entry points stay jit-safe, they just lose coverage there."""
    seen = []

    @jax.jit
    def f(x):
        seen.append(guards.finite_guard(x))
        seen.append(guards.isometry_guard(x, x))
        seen.append(guards.r_condition_guard(x))
        return x

    f(jnp.eye(4))
    assert seen == [None, None, None]


def test_annihilated_direction_is_exact(rng):
    plan = make_plan(512, 64, kappa=1, s=1, seed=0)
    x = inject.annihilated_direction(plan)
    assert np.linalg.norm(x) == pytest.approx(1.0)
    Sx = np.asarray(ops.sketch_apply(plan, jnp.asarray(x[:, None]), "xla"))
    assert np.all(Sx == 0.0)                   # exactly, not approximately
    # a fresh draw breaks the collision: the redraw rung works by design
    plan2 = make_plan(512, 64, kappa=1, s=1, seed=1)
    Sx2 = np.asarray(ops.sketch_apply(plan2, jnp.asarray(x[:, None]), "xla"))
    assert np.linalg.norm(Sx2) > 0.5
    # and a kappa bump defeats it too (collision must repeat at every level)
    plan4 = make_plan(512, 64, kappa=2, s=1, seed=0)
    Sx4 = np.asarray(ops.sketch_apply(plan4, jnp.asarray(x[:, None]), "xla"))
    assert np.linalg.norm(Sx4) > 0.5


def test_bad_draw_detected_by_isometry_and_ose(rng):
    plan = make_plan(512, 64, kappa=1, s=1, seed=0)
    A = inject.adversarial_input(plan, 8, seed=0)
    SA = np.asarray(ops.sketch_apply(plan, jnp.asarray(A), "xla"))
    assert guards.isometry_guard(A, SA).status == report.FAILED
    assert guards.ose_probe(plan, A, impl="xla").status == report.FAILED
    R = ops.triangular_factor(jnp.asarray(SA))
    assert guards.r_condition_guard(R).status == report.FAILED
    # a healthy draw on the same input classifies healthy
    plan2 = make_plan(512, 64, kappa=2, s=2, seed=1)
    SA2 = np.asarray(ops.sketch_apply(plan2, jnp.asarray(A), "xla"))
    assert guards.isometry_guard(A, SA2).status == report.HEALTHY
    pr = guards.ose_probe(plan2, A, impl="xla")
    assert pr.status in (report.HEALTHY, report.DEGRADED)


def test_r_condition_guard_bands():
    R = jnp.diag(jnp.asarray([1.0, 1e-3]))
    assert guards.r_condition_guard(R).status == report.HEALTHY
    R = jnp.diag(jnp.asarray([1.0, 1e-8]))
    assert guards.r_condition_guard(R).status == report.DEGRADED
    R = jnp.diag(jnp.asarray([1.0, 0.0]))
    assert guards.r_condition_guard(R).status == report.FAILED
    R = jnp.asarray([[1.0, jnp.nan], [0.0, 1.0]])
    assert guards.r_condition_guard(R).status == report.FAILED


def test_replica_consistency_detects_all_corruption_modes(rng):
    base = rng.normal(size=(6, 4)).astype(np.float32)
    good = [base.copy() for _ in range(4)]
    assert guards.replica_consistency_guard(good).status == report.HEALTHY
    for mode in ("zero", "permute", "scale"):
        bad = inject.corrupt_replica(good, slot=2, mode=mode, seed=1)
        f = guards.replica_consistency_guard(bad)
        assert f.status == report.FAILED, mode
        # the originals were not modified
        assert np.array_equal(good[2], base)
    # single replica is trivially consistent
    assert guards.replica_consistency_guard([base]).status == report.HEALTHY


def test_vmem_overflow_forces_downgrade_and_counts():
    report.reset_counters()
    lowering.clear_lowering_cache()
    plan, spec = inject.vmem_overflow_request()
    lw = lowering.lower(plan, spec)
    assert lw.downgrade and "vmem" in lw.downgrade
    assert report.counters().get("lowering.downgrade", 0) >= 1
    # the downgrade shows up in explain() alongside the health section
    txt = lowering.explain(plan, spec)
    assert "lowering.downgrade" in txt and "health:" in txt


# ---------------------------------------------------------------------------
# recovery: the ladder, the factor downgrade, the cache fallback
# ---------------------------------------------------------------------------

def test_redraw_ladder_recovers_adversarial_input():
    """Draw #1 fails the OSE probe; the policy converges within the
    escalation budget, deterministically across runs (satellite c)."""
    plan = make_plan(512, 64, kappa=1, s=1, seed=0)
    A = jnp.asarray(inject.adversarial_input(plan, 8, seed=0))
    b = A @ jnp.ones(8, jnp.float32)
    pol = RedrawPolicy(max_redraws=2, max_kappa_bumps=1, max_sampling_bumps=1)

    def run():
        return sketch_precondition_lstsq(
            A, b, k=plan.k_req, kappa=1, s=1, seed=0, impl="xla",
            guard=True, policy=pol, probe=True, tol=1e-5)

    res = run()
    rpt = res.health
    assert rpt is not None and rpt.op == "sketch_precondition_lstsq"
    # draw #1 failed the ground-truth OSE probe...
    first_probe = next(f for f in rpt.findings if f.guard == "ose_probe")
    assert first_probe.status == report.FAILED
    # ...the ladder recovered within budget, and the solve converged
    assert 1 < rpt.attempts <= pol.budget
    assert rpt.status in (report.HEALTHY, report.DEGRADED)
    assert res.converged and res.relres <= 1e-5
    assert any(a.startswith("redraw") for a in rpt.actions)
    # counters surface on the result
    assert rpt.counters()["attempts"] == rpt.attempts
    # deterministic: identical escalation path and verdicts on a re-run
    res2 = run()
    assert res2.health.actions == rpt.actions
    assert [f.status for f in res2.health.findings] == \
        [f.status for f in rpt.findings]
    assert np.allclose(np.asarray(res2.x), np.asarray(res.x))


def test_guarded_solve_accepts_healthy_draw_first_try(rng):
    """On a well-posed problem the guards cost a verdict, not a redraw —
    and the answer matches the unguarded path exactly (same plan)."""
    A = jnp.asarray(rng.normal(size=(1024, 16)), jnp.float32)
    b = A @ jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    res_g = sketch_precondition_lstsq(A, b, seed=3, impl="xla", guard=True)
    res_u = sketch_precondition_lstsq(A, b, seed=3, impl="xla")
    assert res_g.health.attempts == 1 and not res_g.health.actions
    assert res_g.health.status in (report.HEALTHY, report.DEGRADED)
    assert res_g.converged and res_u.converged
    assert np.array_equal(np.asarray(res_g.x), np.asarray(res_u.x))
    assert res_u.health is None


def test_guarded_solve_cg_and_chol_paths(rng):
    A = jnp.asarray(rng.normal(size=(1024, 16)), jnp.float32)
    b = A @ jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    res = sketch_precondition_lstsq(A, b, seed=1, impl="xla", guard=True,
                                    method="cg", factorization="chol")
    assert res.converged and res.health.attempts == 1


def test_chol_fallback_on_rank_deficient_gram():
    """factorization='chol' on a rank-deficient sketch silently yields NaN
    factors; the eager path must detect and downgrade to QR (satellite b)."""
    report.reset_counters()
    # duplicated columns -> exactly singular Gram -> NaN Cholesky
    col = np.arange(1.0, 65.0, dtype=np.float32)
    SA = jnp.asarray(np.stack([col, col, 2 * col], axis=1))
    assert not np.all(np.isfinite(
        np.asarray(jnp.linalg.cholesky(SA.T @ SA))))   # the failure is real
    with pytest.warns(RuntimeWarning, match="non-finite"):
        R = ops.triangular_factor(SA, "chol")
    assert np.all(np.isfinite(np.asarray(R)))          # rescued via QR
    assert report.counters().get("factor.chol_downgrade") == 1
    # the QR fallback is the same factor the qr path produces
    assert np.allclose(np.asarray(R),
                       np.asarray(ops.triangular_factor(SA, "qr")))
    # under jit the values are unreadable: no crash, caller keeps chol
    jitted = jax.jit(lambda m: ops.triangular_factor(m, "chol"))
    _ = jitted(SA)                                     # must not raise


def test_load_cache_survives_corruption(tmp_path):
    """Corrupted/truncated cache JSON warns and falls back instead of
    raising (satellite a)."""
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    for mode in ("truncate", "garbage", "bad_entry"):
        path = str(tmp_path / f"cache_{mode}.json")
        tune.clear_cache()
        tune._CACHE[tune.cache_key(plan, 64, "fwd")] = tune.TuneResult(
            tn=32, time_us=1.0, source="tuned")
        tune.save_cache(path)
        inject.corrupt_cache_file(path, mode)
        tune.clear_cache()
        report.reset_counters()
        with pytest.warns(RuntimeWarning):
            n = tune.load_cache(path)
        assert n == 0, mode
        assert report.counters().get("tune.cache_corrupt", 0) >= 1, mode
        # the tuner still resolves tiles (heuristic fallback)
        assert tune.resolve_tn(plan, 64, "fwd") >= 1
    # a missing file is the same non-event
    with pytest.warns(RuntimeWarning):
        assert tune.load_cache(str(tmp_path / "nope.json")) == 0
    tune.clear_cache()


def test_load_cache_keeps_good_rows_alongside_bad(tmp_path):
    """Row-level corruption skips the bad rows and keeps the good ones."""
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    path = str(tmp_path / "cache.json")
    tune.clear_cache()
    tune._CACHE[tune.cache_key(plan, 64, "fwd")] = tune.TuneResult(
        tn=32, time_us=1.0, source="tuned")
    tune.save_cache(path)
    with open(path) as f:
        payload = json.load(f)
    payload["[broken"] = {"tn": "not an int"}
    payload['["x"]'] = {"no_tn": True}
    with open(path, "w") as f:
        json.dump(payload, f)
    tune.clear_cache()
    report.reset_counters()
    with pytest.warns(RuntimeWarning, match="malformed"):
        assert tune.load_cache(path) == 1          # the good row survived
    hit = tune.lookup(plan, 64, "fwd")
    assert hit is not None and hit.tn == 32
    assert report.counters()["tune.cache_corrupt"] == 2
    tune.clear_cache()


def test_save_cache_is_atomic(tmp_path):
    """save_cache never leaves a partial file: the payload appears via
    rename, and no tmp droppings survive."""
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    path = str(tmp_path / "cache.json")
    tune.clear_cache()
    tune._CACHE[tune.cache_key(plan, 64, "fwd")] = tune.TuneResult(
        tn=32, time_us=1.0, source="tuned")
    assert tune.save_cache(path) == 1
    assert [p.name for p in tmp_path.iterdir()] == ["cache.json"]
    with open(path) as f:
        json.load(f)                               # complete, valid JSON
    tune.clear_cache()
    assert tune.load_cache(path) == 1
    tune.clear_cache()


def test_grass_quarantines_nonfinite_gradient_rows():
    """A NaN-poisoned example is zeroed out of the feature cache and
    counted — it cannot poison its chunk's feature block."""
    from repro.attribution import mlp as mlp_lib
    from repro.attribution.grass import GrassPipeline, GrassPipelineConfig

    mcfg = mlp_lib.MLPConfig(d_in=16, hidden=(8,), steps=3)
    xs, ys = mlp_lib.make_synthetic_mnist(12, 16, mcfg.n_classes, seed=0)
    params = mlp_lib.train_mlp(mcfg, xs, ys)
    cfg = GrassPipelineConfig(sparse_dim=64, sketch_dim=16, chunk=4)
    pipe = GrassPipeline(cfg, params)
    clean = np.asarray(pipe.featurize(xs, ys))
    assert pipe.quarantined == 0

    report.reset_counters()
    x_bad = np.array(xs)
    x_bad[5] = np.nan                              # poison one example
    feats = np.asarray(pipe.featurize(jnp.asarray(x_bad), ys))
    assert pipe.quarantined == 1
    assert report.counters()["grass.quarantined"] == 1
    assert np.all(feats[5] == 0.0)                 # quarantined row
    assert np.all(np.isfinite(feats))              # nothing leaked
    mask = np.ones(12, bool)
    mask[5] = False
    assert np.allclose(feats[mask], clean[mask], atol=1e-6)
    rpt = pipe.health()
    assert rpt.quarantined == 1 and rpt.status == report.DEGRADED
    # build_cache counts through the same path
    pipe2 = GrassPipeline(cfg, params)
    cache, _ = pipe2.build_cache(jnp.asarray(x_bad), ys, batch=8)
    assert pipe2.quarantined == 1 and cache.shape == (12, 16)


# ---------------------------------------------------------------------------
# integration: explain() surface + the whole injector suite
# ---------------------------------------------------------------------------

def test_explain_includes_health_counters():
    report.reset_counters()
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    txt = lowering.explain(plan, op="fwd", n=8, impl="pallas")
    assert "health: no guard events recorded" in txt
    report.record("guard.finite.failed")
    txt = lowering.explain(plan, op="fwd", n=8, impl="pallas")
    assert "guard.finite.failed=1" in txt
    report.reset_counters()


def test_injector_suite_end_to_end(tmp_path):
    """The CI fault-injection gate: every injector detected + recovered,
    counters JSON written."""
    out = str(tmp_path / "HEALTH_counters.json")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        rc = inject.run_injector_suite(out=out, verbose=False)
    assert rc == 0
    with open(out) as f:
        payload = json.load(f)
    assert payload["ok"] is True
    assert set(payload["injectors"]) == {
        "nan_operand_detected", "inf_output_detected", "bad_draw_detected",
        "bad_draw_recovered", "corrupt_cache_recovered",
        "psum_corruption_detected", "vmem_overflow_downgraded"}
    assert all(v == "detected" for v in payload["injectors"].values())
    assert payload["counters"].get("policy.redraw", 0) >= 1
