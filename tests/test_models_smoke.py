"""Per-arch smoke tests (assignment deliverable f): reduced same-family
configs, one forward/train step on CPU, asserting output shapes + no NaNs;
plus decode-vs-prefill consistency (catches every cache/state bug)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.configs.registry import ARCHS
from repro.models.factory import (
    build_model, extra_inputs_concrete, make_train_batch,
)

ARCH_NAMES = sorted(ARCHS)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_smoke(name, key):
    cfg = smoke_config(ARCHS[name])
    model = build_model(cfg)
    params = model.init(key)
    batch = make_train_batch(cfg, batch=2, seq=16, key=key)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    # logits shape check
    logits, _ = model.apply(params, batch["tokens"],
                            {k: v for k, v in batch.items()
                             if k not in ("tokens", "labels")})
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_grads_flow(name, key):
    cfg = smoke_config(ARCHS[name])
    model = build_model(cfg)
    params = model.init(key)
    batch = make_train_batch(cfg, batch=2, seq=8, key=key)
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_prefill(name, key):
    B, S = 2, 8
    cfg = smoke_config(ARCHS[name])
    model = build_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    extra = extra_inputs_concrete(cfg, B, S, key)
    logits_full, _ = jax.jit(model.apply)(params, toks, extra)
    state = model.init_decode_state(params, B, S, extra)
    step = jax.jit(model.decode_step)
    # rwkv6's training path uses bf16 MXU operands in the chunked-parallel
    # wkv (§Perf iteration 2b); decode stays f32-exact — allow bf16 rounding.
    atol = 5e-2 if cfg.ssm_kind == "rwkv6" else 2e-3
    for t in range(S):
        lg, state = step(params, state, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0, :cfg.vocab_size]),
            np.asarray(logits_full[:, t, :cfg.vocab_size]),
            atol=atol, rtol=1e-2)


def test_mamba2_chunk_invariance(key):
    # chunked-SSD intra-chunk einsums use bf16 MXU operands (§Perf) —
    # chunk-size invariance holds to bf16 precision.
    from repro.models import ssm
    cfg = smoke_config(ARCHS["zamba2-7b"])
    p = ssm.init_mamba2(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    y32 = ssm.mamba2_apply(p, cfg, x, chunk=32)
    y8 = ssm.mamba2_apply(p, cfg, x, chunk=8)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32),
                               atol=5e-3, rtol=1e-2)


def test_moe_capacity_drops_are_bounded(key):
    """With cf=1.25 and balanced-ish routing, most tokens survive dispatch."""
    cfg = smoke_config(ARCHS["qwen3-moe-30b-a3b"])
    from repro.models import moe as moe_mod
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 64, cfg.d_model), jnp.float32)
    out, aux = moe_mod.moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    # output should be nonzero for most tokens (not everything dropped)
    frac_nonzero = float(jnp.mean(jnp.any(out != 0, axis=-1)))
    assert frac_nonzero > 0.5


def test_param_counts_match_scale():
    """Full-config param counts are in the right ballpark (±40%)."""
    expect = {
        "deepseek-7b": 7e9, "internlm2-1.8b": 1.9e9, "qwen3-0.6b": 0.8e9,
        "command-r-plus-104b": 104e9, "rwkv6-7b": 7e9,
        "qwen3-moe-30b-a3b": 30e9, "arctic-480b": 480e9,
        "llama-3.2-vision-11b": 10.6e9, "zamba2-7b": 7e9,
        "seamless-m4t-large-v2": 2.3e9,
    }
    for name, target in expect.items():
        got = ARCHS[name].param_count()
        assert 0.6 * target < got < 1.55 * target, (name, got, target)
