"""Roofline HLO walker: parser unit tests + end-to-end on a tiny compile."""
import numpy as np
import pytest

from repro.roofline import hlo_parse, hw


SAMPLE = """\
HloModule jit_f, entry_computation_layout={(f32[128,128])->f32[]}

%body.1 (arg: (s32[], f32[128,128], f32[10,128,128])) -> (s32[], f32[128,128], f32[10,128,128]) {
  %arg = (s32[], f32[128,128]{1,0}, f32[10,128,128]{2,1,0}) parameter(0)
  %g0 = s32[] get-tuple-element(%arg), index=0
  %g1 = f32[128,128]{1,0} get-tuple-element(%arg), index=1
  %g2 = f32[10,128,128]{2,1,0} get-tuple-element(%arg), index=2
  %ds = f32[1,128,128]{2,1,0} dynamic-slice(%g2, %g0), dynamic_slice_sizes={1,128,128}
  %w = f32[128,128]{1,0} bitcast(%ds)
  %dot.0 = f32[128,128]{1,0} dot(%g1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c1 = s32[] constant(1)
  %next = s32[] add(%g0, %c1)
  ROOT %tup = (s32[], f32[128,128]{1,0}, f32[10,128,128]{2,1,0}) tuple(%next, %dot.0, %g2)
}

%cond.1 (arg.1: (s32[], f32[128,128], f32[10,128,128])) -> pred[] {
  %arg.1 = (s32[], f32[128,128]{1,0}, f32[10,128,128]{2,1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg.1), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main.1 (p0: f32[128,128], p1: f32[10,128,128]) -> f32[128,128] {
  %p0 = f32[128,128]{1,0} parameter(0)
  %p1 = f32[10,128,128]{2,1,0} parameter(1)
  %c0 = s32[] constant(0)
  %t = (s32[], f32[128,128]{1,0}, f32[10,128,128]{2,1,0}) tuple(%c0, %p0, %p1)
  %while.1 = (s32[], f32[128,128]{1,0}, f32[10,128,128]{2,1,0}) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  %ar = f32[128,128]{1,0} all-reduce(%p0), replica_groups=[4,2]<=[8], to_apply=%cond.1
  ROOT %out = f32[128,128]{1,0} get-tuple-element(%while.1), index=1
}
"""


def test_shape_bytes():
    assert hlo_parse._shape_bytes("f32[128,128]{1,0}") == 128 * 128 * 4
    assert hlo_parse._shape_bytes("bf16[4,2]") == 16
    assert hlo_parse._shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert hlo_parse._shape_bytes("pred[]") == 1


def test_instr_line_parse():
    line = ("  %while.83 = (s32[], bf16[16,256,2048]{2,1,0}, "
            "/*index=5*/f32[1,2]{1,0}) while(%tuple), condition=%c, body=%b")
    name, type_str, opcode, rest = hlo_parse._parse_instr_line(line)
    assert name == "while.83"
    assert opcode == "while"
    assert "condition=%c" in rest


def test_walker_counts_loop_flops_and_collectives():
    cost = hlo_parse.entry_cost(SAMPLE, devices=8)
    expected_dot = 2 * 128 * 128 * 128 * 10          # 10 loop trips
    assert cost.flops == pytest.approx(expected_dot, rel=0.02)
    # all-reduce: 128*128*4 bytes, ring factor (2-1)/2, x2 for reduce+bcast
    assert cost.coll_bytes["all-reduce"] == 128 * 128 * 4
    assert cost.coll_wire_bytes == pytest.approx(128 * 128 * 4 * 0.5 * 2)
    # dynamic-slice of the stacked weights charges slice bytes, not the stack
    assert cost.hbm_bytes < 10 * (128 * 128 * 4) * 12


def test_refined_fusion_param_bytes():
    comps = hlo_parse.parse_hlo(SAMPLE)
    body = comps["body.1"]
    full = 10 * 128 * 128 * 4
    refined = hlo_parse._refined_param_bytes(body, "g2", full)
    # g2 is used by dynamic-slice AND passed through tuple -> full charge
    assert refined == full


def test_end_to_end_tiny_compile():
    # Regression for a real seed failure: modern XLA dumps inline operand
    # types ("dot(f32[64,64]{1,0} %lhs, ...)"), which the old operand
    # splitter mis-parsed (split on commas inside shapes, took "f32" as the
    # operand name), collapsing dot flops to 2*out_elems.  The walker now
    # recovers operand names from the %-token, so loop flops count per trip.
    import jax
    import jax.numpy as jnp

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    cost = hlo_parse.entry_cost(compiled.as_text(), 1)
    expected = 2 * 64 * 64 * 64 * 7
    assert expected * 0.9 < cost.flops < expected * 1.3
