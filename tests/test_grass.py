"""GraSS attribution pipeline + LDS metric tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attribution import lds as L
from repro.attribution import mlp as M
from repro.attribution.grass import (
    GrassPipeline, GrassPipelineConfig, run_grass_lds, sparsify_mask,
)


def test_spearman_known_values():
    assert L.spearman([1, 2, 3, 4], [1, 2, 3, 4]) == pytest.approx(1.0)
    assert L.spearman([1, 2, 3, 4], [4, 3, 2, 1]) == pytest.approx(-1.0)
    # monotone nonlinear -> still 1.0 (rank correlation)
    x = np.array([1.0, 2.0, 3.0, 10.0])
    assert L.spearman(x, x ** 3) == pytest.approx(1.0)
    # ties handled
    v = L.spearman([1, 1, 2, 3], [1, 2, 3, 4])
    assert 0.8 < v <= 1.0


def test_subsets_and_lds_shapes():
    masks = L.sample_subsets(100, 7, 0.5, seed=1)
    assert masks.shape == (7, 100)
    assert np.all(masks.sum(1) == 50)
    # perfect additive model => LDS = 1
    rng = np.random.default_rng(0)
    tau = rng.normal(size=(3, 100))
    true = (tau @ masks.T.astype(float)).T      # (m, n_test)
    assert L.lds_score(true, tau, masks) == pytest.approx(1.0)


def test_sparsify_mask_deterministic():
    m1 = sparsify_mask(1000, 100, seed=3)
    m2 = sparsify_mask(1000, 100, seed=3)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    assert len(set(np.asarray(m1).tolist())) == 100
    assert np.all(np.diff(np.asarray(m1)) > 0)


def test_sparsify_mask_pinned_regression():
    """The top_k rewrite must keep the selected set bitwise-identical to
    the historical full-argsort implementation (pinned for seed=3)."""
    m = np.asarray(sparsify_mask(1000, 100, seed=3))
    assert m[:10].tolist() == [19, 27, 30, 33, 48, 85, 98, 118, 147, 182]
    assert int(m.sum()) == 50307
    assert m.shape == (100,)


def test_sparsify_mask_topk_equals_argsort():
    """lax.top_k on the uint32 complement == argsort(scores)[:k], exactly
    (complement reverses uint32 order; both tie-break toward lower index)."""
    from repro.core import hashing
    for d_total, d_keep, seed in [(257, 32, 0), (1000, 100, 3), (4096, 512, 9)]:
        u = jnp.arange(d_total, dtype=jnp.uint32)
        scores = hashing.hash_words(np.uint32(seed), np.uint32(0x6A55), u)
        want = np.sort(np.asarray(jnp.argsort(scores))[:d_keep])
        got = np.asarray(sparsify_mask(d_total, d_keep, seed))
        np.testing.assert_array_equal(got, want)


def test_mlp_trains():
    cfg = M.MLPConfig(d_in=64, hidden=(32,), steps=100)
    x, y = M.make_synthetic_mnist(256, 64, seed=0)
    p = M.train_mlp(cfg, x, y)
    acc = float(jnp.mean(jnp.argmax(M.mlp_apply(p, x), -1) == y))
    assert acc > 0.8


def test_feature_cache_shapes_and_determinism():
    cfg = M.MLPConfig(d_in=64, hidden=(16,), steps=20)
    x, y = M.make_synthetic_mnist(32, 64, seed=0)
    p = M.train_mlp(cfg, x, y)
    pc = GrassPipelineConfig(sparse_dim=256, sketch_dim=64)
    pipe = GrassPipeline(pc, p)
    c1, _ = pipe.build_cache(x, y)
    c2, _ = pipe.build_cache(x, y)
    assert c1.shape == (32, pipe.sketch.k)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)


def test_fused_pipeline_matches_unfused():
    """The gather-fused scan-chunked rewrite must reproduce the seed
    pipeline's features (same mask, same sketch) — which pins the LDS
    score: attribution is a deterministic function of the caches."""
    cfg = M.MLPConfig(d_in=64, hidden=(16,), steps=20)
    x, y = M.make_synthetic_mnist(50, 64, seed=0)
    p = M.train_mlp(cfg, x, y)
    fused = GrassPipeline(
        GrassPipelineConfig(sparse_dim=256, sketch_dim=64, chunk=16,
                            fused=True), p)
    unfused = GrassPipeline(
        GrassPipelineConfig(sparse_dim=256, sketch_dim=64, chunk=16,
                            fused=False), p)
    cf, _ = fused.build_cache(x, y)           # 50 % 16 != 0: pad path too
    cu, _ = unfused.build_cache(x, y)
    np.testing.assert_allclose(np.asarray(cf), np.asarray(cu),
                               atol=1e-5, rtol=1e-5)
    # chunking must not leak across examples: a different chunk size
    # reproduces the same features
    rechunked = GrassPipeline(
        GrassPipelineConfig(sparse_dim=256, sketch_dim=64, chunk=7,
                            fused=True), p)
    cr, _ = rechunked.build_cache(x, y)
    np.testing.assert_allclose(np.asarray(cr), np.asarray(cf),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.slow
def test_grass_lds_end_to_end_positive():
    mcfg = M.MLPConfig(d_in=128, hidden=(32, 32), steps=80)
    res = run_grass_lds(
        GrassPipelineConfig(sparse_dim=1024, sketch_dim=256,
                            sketch_family="blockperm"),
        mcfg, n_train=256, n_test=24, m_subsets=24)
    assert res["lds"] > 0.1, res
