"""Tests for the sketch lowering engine (``repro.kernels.lowering``).

Three layers:

  1. Golden snapshot — ``lower()`` over the full decision grid
     (op × impl × dtype × gather × batch × shard × ragged-n) serialized
     against ``tests/data/lowering_snapshot.json``.  ANY dispatch-behavior
     change shows up as an explicit diff of that file; regenerate with
     ``REGEN_LOWERING_SNAPSHOT=1 pytest tests/test_lowering.py`` after
     reviewing the diff.
  2. Cost consistency — ``sketch_model.cost_of(lowering)`` must agree with
     the legacy ``kernel_cost``/``dist_sketch_cost`` entry points on every
     grid point, so the modeled cost is provably computed from the record
     that launches.
  3. Engine unit tests — downgrade ladder, explain() traces, the memoized
     record cache and its tuner-generation invalidation, spec validation,
     and the unified tuner cache key (autotune_plan ↔ resolve_tn round
     trip, incl. batched shapes and JSON persistence).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blockperm import make_plan
from repro.distributed import plan_for_mesh
from repro.kernels import lowering, ops, tune
from repro.roofline import sketch_model

SNAPSHOT = os.path.join(os.path.dirname(__file__), "data",
                        "lowering_snapshot.json")

# Decisions depend on the backend only through impl="auto" (and the tuner
# backend tag); the golden file is generated off-TPU, where CI runs.
pytestmark = pytest.mark.skipif(
    jax.default_backend() == "tpu",
    reason="golden lowering snapshot is generated for the off-TPU backends")


def _plans():
    return {
        # d == d_pad, everything fits: the no-downgrade grid
        "pinned": make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4),
        # stacked Φ busts VMEM at any tile: v2→v1 / gather-materialize rows
        "big": make_plan(65_536, 1024, kappa=4, s=2, block_rows=256),
        # P | M: the sharded rows
        "mesh": plan_for_mesh(4096, 1024, 4, kappa=2),
        # partial fits only below the requested tile: the shrink rung
        "mesh_shrink": plan_for_mesh(65_536, 1024, 8, kappa=4),
        # partial (Br, Bc) Φ tile busts VMEM: row-sharded oracle fallback
        "mesh_big": plan_for_mesh(262_144, 1024, 8, kappa=2),
        # global families (κ = M plans) through the SAME engine: the
        # competitor-family grid (no blockrow, no row-shard — both raise)
        "count": make_plan(256, 64, s=1, block_rows=8, seed=4,
                           family="countsketch"),
        "graph": make_plan(256, 64, s=4, block_rows=8, seed=4,
                           family="graph"),
    }


def _grid():
    """The full decision grid: (plan-name, LaunchSpec kwargs) cases."""
    cases = []
    # single-device product — op × impl × dtype × gather × batch × ragged-n.
    # The dtype axis walks the precision-policy registry: fp32 (None),
    # bf16, and the two fp8 stream formats (stochastic-rounding e4m3 plus
    # nearest e5m2), so every fp8 dispatch decision is snapshot-pinned.
    for op in lowering.OPS:
        for impl in lowering.IMPLS:
            for dtype in (None, "bfloat16", "fp8_e4m3_sr", "fp8_e5m2"):
                for gather in (False, True):
                    if gather and op not in lowering.GATHER_OPS:
                        continue
                    for batch in (1, 8):
                        for n in (64, 33):
                            cases.append(("pinned", dict(
                                op=op, n=n, impl=impl, dtype=dtype,
                                gather=gather, batch=batch)))
    # global families ride the single-device grid untouched: op × impl ×
    # dtype × gather × batch (+ one ragged-n point).  blockrow and
    # shard="row" are validation errors for them, not grid points.
    for plan_name in ("count", "graph"):
        for op in ("fwd", "transpose"):
            for impl in ("pallas", "xla"):
                for dtype in (None, "bfloat16", "fp8_e4m3_sr"):
                    for gather in (False, True):
                        if gather and op not in lowering.GATHER_OPS:
                            continue
                        for batch in (1, 8):
                            cases.append((plan_name, dict(
                                op=op, n=64, impl=impl, dtype=dtype,
                                gather=gather, batch=batch)))
        cases.append((plan_name, dict(op="fwd", n=33, impl="pallas")))
    # the downgrade ladder on the oversized plan
    for spec in (
        dict(op="fwd", n=8, impl="pallas"),               # v2 -> v1
        dict(op="fwd", n=8, impl="pallas", gather=True),  # materialize + v1
        dict(op="fwd", n=8, impl="pallas_v1", gather=True),
        dict(op="transpose", n=8, impl="pallas"),
        dict(op="blockrow", n=8, impl="pallas"),
    ):
        cases.append(("big", spec))
    # sharded rows
    for spec in (
        dict(op="fwd", n=64, impl="pallas", shard="row", devices=4),
        dict(op="fwd", n=33, impl="pallas", shard="row", devices=4),
        dict(op="fwd", n=64, impl="xla", shard="row", devices=4),
        dict(op="fwd", n=64, impl="auto", shard="row", devices=4),
        dict(op="blockrow", n=64, impl="pallas", shard="row", devices=4),
        dict(op="fwd", n=64, impl="pallas", shard="col", devices=4),
        dict(op="fwd", n=64, impl="pallas", shard="batch", devices=4,
             batch=8),
        dict(op="fwd", n=64, impl="pallas", shard="batch", devices=4,
             batch=8, gather=True),
    ):
        cases.append(("mesh", spec))
    # row-sharded explicit tile shrunk by the partial VMEM budget
    cases.append(("mesh_shrink", dict(op="fwd", n=64, impl="pallas",
                                      tn=512, shard="row", devices=8)))
    # row-sharded VMEM fallback to the oracle partial
    cases.append(("mesh_big", dict(op="fwd", n=8, impl="pallas",
                                   shard="row", devices=8)))
    return cases


def _lower_grid():
    tune.clear_cache()
    lowering.clear_lowering_cache()
    plans = _plans()
    out = []
    for plan_name, spec_kwargs in _grid():
        lw = lowering.lower(plans[plan_name],
                            lowering.LaunchSpec(**spec_kwargs))
        out.append({"plan": plan_name, "spec": spec_kwargs,
                    "lowering": lw.to_json()})
    return out


# ---------------------------------------------------------------------------
# 1. Golden snapshot
# ---------------------------------------------------------------------------

def test_lowering_snapshot_matches_golden():
    got = _lower_grid()
    if os.environ.get("REGEN_LOWERING_SNAPSHOT"):
        os.makedirs(os.path.dirname(SNAPSHOT), exist_ok=True)
        with open(SNAPSHOT, "w") as f:
            json.dump(got, f, indent=1, sort_keys=True)
        pytest.skip("snapshot regenerated — review the diff and commit")
    with open(SNAPSHOT) as f:
        want = json.load(f)
    # roundtrip got through JSON so tuple/list and int/float normalize
    got = json.loads(json.dumps(got, sort_keys=True))
    want_by_key = {(w["plan"], json.dumps(w["spec"], sort_keys=True)): w
                   for w in want}
    assert len(want) == len(got), (
        f"grid size changed: snapshot has {len(want)} cases, lower() "
        f"produced {len(got)} — regenerate with REGEN_LOWERING_SNAPSHOT=1")
    for g in got:
        key = (g["plan"], json.dumps(g["spec"], sort_keys=True))
        assert key in want_by_key, f"new grid case {key} not in snapshot"
        assert g["lowering"] == want_by_key[key]["lowering"], (
            f"dispatch behavior changed for {key}:\n"
            f"  was: {want_by_key[key]['lowering']}\n"
            f"  now: {g['lowering']}\n"
            f"If intended, regenerate with REGEN_LOWERING_SNAPSHOT=1.")


# ---------------------------------------------------------------------------
# 2. cost_of(lowering) == legacy cost entry points, every grid point
# ---------------------------------------------------------------------------

def test_cost_of_agrees_with_legacy_kernel_cost():
    plans = _plans()
    checked = 0
    for plan_name, spec_kwargs in _grid():
        lw = lowering.lower(plans[plan_name],
                            lowering.LaunchSpec(**spec_kwargs))
        if lw.shard == "row":
            if lw.op != "fwd":
                # dist_sketch_cost models the compact fwd partial only;
                # cost_of must keep refusing rather than invent 1/P terms
                with pytest.raises(ValueError):
                    sketch_model.cost_of(lw)
                continue
            want = sketch_model.dist_sketch_cost(
                lw.plan, lw.n_eff, lw.devices, variant=lw.op,
                tn=lw.tn if lw.tn is not None else 128)
        else:
            want = sketch_model.kernel_cost(
                lw.plan, lw.n_loc,
                version="v1" if lw.impl == "pallas_v1" else "v2",
                variant=lw.op,
                tn=lw.tn if lw.tn is not None else 128,
                gather=lw.gather_fused, batch=lw.batch_loc)
        got = sketch_model.cost_of(lw)
        assert got == want, (plan_name, spec_kwargs)
        checked += 1
    assert checked > 100          # the grid really was traversed


def test_cost_of_matches_family_cost_model_on_global_grid():
    """The registered family's ``cost_model`` and the engine's
    ``cost_of`` must price the SAME launch for the new global families,
    and the κ = M realization must charge the known closed forms:
    dense-like MXU work (2·k_pad·d_pad·n — every input block feeds every
    output block) and A streamed M times."""
    from repro.core.variants import SKETCH_FAMILIES
    for name in ("countsketch", "graph"):
        sk = SKETCH_FAMILIES[name](256, 64, seed=4, block_rows=8)
        p = sk.plan
        assert p.family == name and p.kappa == p.M
        for n in (8, 64, 33):
            lw = sk.lowering_for(n)
            kc = sketch_model.cost_of(lw)
            cm = sk.cost_model(n)
            assert cm.flops == kc.mxu_flops
            assert cm.hbm_bytes == kc.hbm_bytes
            assert not cm.materializes_S
            assert kc.mxu_flops == 2.0 * p.k_pad * p.d_pad * n
            assert kc.hbm_bytes >= p.stream_itemsize * p.M * p.d_pad * n


# ---------------------------------------------------------------------------
# 3. Engine behavior
# ---------------------------------------------------------------------------

def test_downgrade_v2_to_v1_recorded():
    plan = make_plan(65_536, 1024, kappa=4, s=2, block_rows=256)
    lw = lowering.lower(plan, lowering.LaunchSpec(op="fwd", n=8,
                                                  impl="pallas"))
    assert lw.impl == "pallas_v1" and lw.impl_requested == "pallas"
    assert lw.downgrade and "vmem" in lw.downgrade
    assert lw.tn_source == "v1_default"
    assert lw.version == "v1"


def test_downgrade_gather_materialized_recorded():
    plan = make_plan(65_536, 1024, kappa=4, s=2, block_rows=256)
    lw = lowering.lower(plan, lowering.LaunchSpec(
        op="fwd", n=8, impl="pallas", gather=True))
    assert lw.gather and not lw.gather_fused
    assert "materialized" in lw.downgrade
    # the materialized launch then rides the regular ladder down to v1
    assert lw.impl == "pallas_v1"
    assert lw.variant == "fwd"                 # the kernel that runs


def test_row_shard_vmem_fallback_to_oracle():
    plan = plan_for_mesh(262_144, 1024, 8, kappa=2)
    assert not lowering.partial_fits_vmem(plan, 8)
    lw = lowering.lower(plan, lowering.LaunchSpec(
        op="fwd", n=8, impl="pallas", shard="row", devices=8))
    assert lw.impl == "xla" and lw.downgrade and "Φ tile" in lw.downgrade


def test_row_shard_tile_shrink_is_recorded():
    """An explicit tile shrunk by the partial VMEM budget must not be
    reported as 'the request ran as asked' (review finding)."""
    plan = plan_for_mesh(65_536, 1024, 8, kappa=4)
    lw = lowering.lower(plan, lowering.LaunchSpec(
        op="fwd", n=64, impl="pallas", tn=512, shard="row", devices=8))
    if lw.tn == 512:
        pytest.skip("plan fits at the requested tile — nothing to record")
    assert lw.tn < 512
    assert "vmem_shrunk" in lw.tn_source
    assert lw.downgrade and "shrunk" in lw.downgrade


def test_lowering_cache_does_not_grow_across_generations():
    """Tuner mutations flush the memo instead of stranding dead entries
    keyed by old generations (review finding)."""
    tune.clear_cache()
    lowering.clear_lowering_cache()
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    spec = lowering.LaunchSpec(op="fwd", n=120, impl="pallas")
    for _ in range(5):
        lowering.lower(plan, spec)
        tune._bump_generation()
    lowering.lower(plan, spec)
    assert lowering.lowering_cache_size() == 1
    tune.clear_cache()


def test_no_downgrade_records_none():
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    lw = lowering.lower(plan, lowering.LaunchSpec(op="fwd", n=64,
                                                  impl="pallas"))
    assert lw.downgrade is None
    assert lw.impl == lw.impl_requested == "pallas"
    assert lw.pad_cols == 0


def test_explain_mentions_decisions():
    plan = make_plan(65_536, 1024, kappa=4, s=2, block_rows=256)
    txt = lowering.explain(plan, op="fwd", n=8, impl="pallas")
    assert "pallas_v1" in txt                  # the downgrade
    assert "vmem" in txt
    assert "Lowering(" in txt                  # the final record
    # rejected tile candidates show up for a heuristic resolution
    plan2 = make_plan(4096, 256, kappa=4, s=2)
    txt2 = lowering.explain(plan2, op="fwd", n=4096, impl="pallas")
    assert "tn:" in txt2


def test_lowering_cache_hits_and_tuner_invalidation():
    lowering.clear_lowering_cache()
    tune.clear_cache()
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    spec = lowering.LaunchSpec(op="fwd", n=200, impl="pallas")
    a = lowering.lower(plan, spec)
    b = lowering.lower(plan, spec)
    assert a is b                              # memoized record
    # a tuned winner landing bumps the generation and re-resolves
    key = tune.cache_key(plan, 200, "fwd")
    tune._CACHE[key] = tune.TuneResult(tn=16, time_us=1.0, source="tuned")
    tune._bump_generation()
    c = lowering.lower(plan, spec)
    assert c is not b and c.tn == 16 and c.tn_source == "tuned"
    tune.clear_cache()


def test_spec_validation():
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    mesh_plan = plan_for_mesh(4096, 1024, 4, kappa=2)
    bad = [
        dict(op="nope"),
        dict(impl="cuda"),
        dict(shard="diag"),
        dict(n=0),
        dict(batch=0),
        dict(tn=0),
        dict(op="transpose", gather=True),
        dict(shard="row", op="transpose", devices=4),
        dict(shard="row", gather=True, devices=4),
        dict(shard="row", impl="pallas_v1", devices=4),
        dict(shard="col", n=33, devices=4),
        dict(shard="batch", batch=6, devices=4),
    ]
    for kw in bad:
        with pytest.raises(ValueError):
            lowering.lower(mesh_plan if kw.get("shard") == "row" else plan,
                           lowering.LaunchSpec(**{"n": 64, **kw}))
    # row-sharding P must divide M
    with pytest.raises(ValueError, match="divide"):
        lowering.lower(plan, lowering.LaunchSpec(
            op="fwd", n=64, shard="row", devices=3))


def test_global_family_spec_validation():
    """Global families have no blockrow formulation and no compact
    row-sharded partial — the engine must refuse, not mislower."""
    cplan = make_plan(256, 64, s=1, block_rows=8, seed=4,
                      family="countsketch")
    with pytest.raises(ValueError, match="blockrow"):
        lowering.lower(cplan, lowering.LaunchSpec(op="blockrow", n=64))
    gplan = make_plan(4096, 1024, s=4, block_rows=256, seed=4,
                      family="graph")
    assert gplan.M % 4 == 0          # the divide check is not what fires
    with pytest.raises(ValueError, match="compact partial"):
        lowering.lower(gplan, lowering.LaunchSpec(
            op="fwd", n=64, shard="row", devices=4))
    # col/batch sharding needs no partial reduction — still allowed
    lw = lowering.lower(gplan, lowering.LaunchSpec(
        op="fwd", n=64, shard="col", devices=4))
    assert lw.shard == "col"


def test_tuner_cache_key_distinguishes_families():
    """Identical geometry, different family ⇒ different tuner key: a
    blockperm winner must never be served to a countsketch launch."""
    bp = make_plan(256, 64, kappa=8, s=1, block_rows=8, seed=4)
    cs = make_plan(256, 64, s=1, block_rows=8, seed=4,
                   family="countsketch")
    geom = lambda p: (p.d_pad, p.k_pad, p.M, p.Br, p.kappa, p.s, p.dtype)
    assert geom(bp) == geom(cs)      # the families differ ONLY by family
    assert tune.cache_key(bp, 64, "fwd") != tune.cache_key(cs, 64, "fwd")


def test_execute_guards():
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    A = jnp.zeros((256, 16), jnp.float32)
    lw = lowering.lower(plan, lowering.LaunchSpec(op="fwd", n=16,
                                                  impl="xla"))
    with pytest.raises(ValueError, match="non-gather"):
        lowering.execute(lw, A, row_index=jnp.zeros((256,), jnp.int32))
    lwg = lowering.lower(plan, lowering.LaunchSpec(
        op="fwd", n=16, impl="xla", gather=True))
    with pytest.raises(ValueError, match="row_index"):
        lowering.execute(lwg, A)
    with pytest.raises(ValueError, match="plan.d"):
        lowering.execute(lwg, A, row_index=jnp.zeros((100,), jnp.int32))
    mesh_plan = plan_for_mesh(4096, 1024, 4, kappa=2)
    lwr = lowering.lower(mesh_plan, lowering.LaunchSpec(
        op="fwd", n=16, impl="xla", shard="row", devices=4))
    with pytest.raises(ValueError, match="shard"):
        lowering.execute(lwr, A)


def test_ops_entry_points_route_through_engine(monkeypatch, rng):
    """Every public apply goes through lower(): the structural guarantee
    behind 'no inline dispatch in ops'."""
    specs = []
    orig = lowering.lower

    def spy(plan, spec):
        specs.append(spec)
        return orig(plan, spec)

    monkeypatch.setattr(lowering, "lower", spy)
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    A = jnp.asarray(rng.normal(size=(256, 16)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(plan.k, 16)), jnp.float32)
    idx = jnp.arange(256, dtype=jnp.int32)
    ops.sketch_apply(plan, A, "pallas", 8)
    ops.sketch_apply_t(plan, Y, "pallas", 8)
    ops.blockrow_apply(plan, A, "pallas", 8)
    ops.sketch_apply(plan, A, "pallas", 8, row_index=idx)
    ops.sketch_apply_batched(plan, A[None], "pallas")
    ops.sketch_vectors(plan, A.T, "pallas")
    assert len(specs) >= 6
    assert {s.op for s in specs} == {"fwd", "transpose", "blockrow"}


# ---------------------------------------------------------------------------
# 4. Unified tuner cache key (satellite): autotune_plan ↔ resolve_tn,
#    batched shapes, JSON round trip.
# ---------------------------------------------------------------------------

def test_autotune_plan_winner_served_to_batched_resolve(monkeypatch):
    tune.clear_cache()

    def fake_autotune(plan, n, variant="fwd", batch=1, **kw):
        res = tune.TuneResult(tn=32, time_us=1.0, source="tuned")
        tune._CACHE[tune.cache_key(plan, n, variant, batch=batch)] = res
        tune._bump_generation()
        return res

    monkeypatch.setattr(tune, "autotune", fake_autotune)
    B = 16
    plan, res = tune.autotune_plan(512, 128, 4, kappa=2, s=2, batch=B)
    # the winner must be visible through the SAME key builder the readers
    # use — batched consult included
    assert tune.resolve_tn(plan, 4, "fwd", batch=B) == res.tn == 32
    hit = tune.lookup(plan, 4, "fwd", batch=B)
    assert hit is not None and hit.tn == 32
    tune.clear_cache()


def test_batched_cache_key_roundtrips_through_json(tmp_path):
    tune.clear_cache()
    plan = make_plan(512, 128, kappa=2, s=2, block_rows=32, seed=1)
    key = tune.cache_key(plan, 4, "fwd_gather", batch=16)
    tune._CACHE[key] = tune.TuneResult(tn=64, time_us=2.5, source="tuned")
    path = str(tmp_path / "cache.json")
    assert tune.save_cache(path) == 1
    tune.clear_cache()
    gen_before = tune.cache_generation()
    assert tune.load_cache(path) == 1
    assert tune.cache_generation() > gen_before   # loaders invalidate
    assert tune.resolve_tn(plan, 4, "fwd_gather", batch=16) == 64
    hit = tune.lookup(plan, 4, "fwd_gather", batch=16)
    assert hit.source == "loaded"
    tune.clear_cache()


def test_lowering_sees_freshly_loaded_winner(tmp_path):
    """End-to-end: a JSON-shipped winner must flow through lookup() into
    fresh Lowering records (the generation-keyed memo must not serve the
    pre-load record)."""
    tune.clear_cache()
    lowering.clear_lowering_cache()
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    spec = lowering.LaunchSpec(op="fwd", n=96, impl="pallas")
    before = lowering.lower(plan, spec)
    assert before.tn_source == "heuristic"
    tune._CACHE[tune.cache_key(plan, 96, "fwd")] = tune.TuneResult(
        tn=16, time_us=1.0, source="tuned")
    path = str(tmp_path / "cache.json")
    tune.save_cache(path)
    tune.clear_cache()
    tune.load_cache(path)
    after = lowering.lower(plan, spec)
    assert after.tn == 16 and after.tn_source == "loaded"
    tune.clear_cache()
