"""FlashSketch v2 (fused-κ single-write) kernel tests.

Covers the PR-1 acceptance set: bit-exactness of the fused Φ construction
against the ``dense_block`` oracle, v2-vs-v1 allclose on all three kernel
variants, differentiation through the bf16 streaming path, and autotuner
cache determinism.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import blockperm, wiring
from repro.core.blockperm import make_plan
from repro.kernels import flashsketch as fsk
from repro.kernels import ops, ref as kref, tune

SWEEP = [
    # (d, k, kappa, s, block_rows, n)
    (256, 64, 1, 1, 8, 16),
    (256, 64, 2, 2, 8, 33),
    (300, 96, 3, 2, 16, 37),
    (512, 128, 4, 4, 32, 64),
    (1000, 256, 4, 2, 32, 128),
]


# ---------------------------------------------------------------------------
# Fused Φ construction: bit-exact vs the dense_block / ref.py oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,k,kappa,s,br,n", SWEEP[:4])
def test_stacked_phi_bit_exact(d, k, kappa, s, br, n):
    plan = make_plan(d=d, k=k, kappa=kappa, s=s, block_rows=br, seed=d + n)
    pi = np.asarray(wiring.wiring_table(plan.seed, plan.M, plan.kappa))
    for g in range(min(plan.M, 4)):
        neighbors = pi[:, g]
        stacked = np.asarray(fsk.stacked_phi(plan, g, neighbors))
        assert stacked.shape == (plan.Br, plan.kappa * plan.Bc)
        for ell, h in enumerate(neighbors):
            want = np.asarray(blockperm.dense_block(plan, g, int(h)))
            got = stacked[:, ell * plan.Bc:(ell + 1) * plan.Bc]
            # entries are ±1/0 — must match *bitwise*, not just to tolerance
            assert np.array_equal(got, want), (g, ell, h)


def test_stacked_phi_bf16_lossless():
    """Casting Φ to bf16 (the mixed-precision scratch dtype) is exact."""
    plan = make_plan(512, 128, kappa=4, s=2, block_rows=32, seed=3)
    pi = np.asarray(wiring.wiring_table(plan.seed, plan.M, plan.kappa))
    stacked = fsk.stacked_phi(plan, 0, pi[:, 0])
    assert np.array_equal(
        np.asarray(stacked.astype(jnp.bfloat16).astype(jnp.float32)),
        np.asarray(stacked),
    )


# ---------------------------------------------------------------------------
# v2 vs v1 equivalence on all three kernel variants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,k,kappa,s,br,n", SWEEP)
def test_v2_matches_v1_fwd(d, k, kappa, s, br, n, rng):
    plan = make_plan(d=d, k=k, kappa=kappa, s=s, block_rows=br, seed=d + n)
    A = jnp.asarray(rng.normal(size=(d, n)), jnp.float32)
    Y1 = ops.sketch_apply(plan, A, impl="pallas_v1", tn=16)
    Y2 = ops.sketch_apply(plan, A, impl="pallas", tn=16)
    np.testing.assert_allclose(np.asarray(Y2), np.asarray(Y1),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("d,k,kappa,s,br,n", SWEEP)
def test_v2_matches_v1_transpose(d, k, kappa, s, br, n, rng):
    plan = make_plan(d=d, k=k, kappa=kappa, s=s, block_rows=br, seed=d + n)
    Y = jnp.asarray(rng.normal(size=(plan.k, n)), jnp.float32)
    X1 = ops.sketch_apply_t(plan, Y, impl="pallas_v1", tn=16)
    X2 = ops.sketch_apply_t(plan, Y, impl="pallas", tn=16)
    np.testing.assert_allclose(np.asarray(X2), np.asarray(X1),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("d,k,kappa,s,br,n", SWEEP)
def test_v2_matches_v1_blockrow(d, k, kappa, s, br, n, rng):
    plan = make_plan(d=d, k=k, kappa=kappa, s=s, block_rows=br, seed=d + n)
    A = jnp.asarray(rng.normal(size=(d, n)), jnp.float32)
    Y1 = ops.blockrow_apply(plan, A, impl="pallas_v1", tn=16)
    Y2 = ops.blockrow_apply(plan, A, impl="pallas", tn=16)
    np.testing.assert_allclose(np.asarray(Y2), np.asarray(Y1),
                               atol=1e-5, rtol=1e-5)


def test_v2_matches_ref_fwd(rng):
    plan = make_plan(1000, 256, kappa=4, s=2, block_rows=32, seed=9)
    A = jnp.asarray(rng.normal(size=(1000, 40)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.sketch_apply(plan, A, impl="pallas", tn=8)),
        np.asarray(kref.flashsketch_ref(plan, A)),
        atol=1e-5, rtol=1e-5,
    )


# ---------------------------------------------------------------------------
# Mixed-precision streaming path
# ---------------------------------------------------------------------------

def test_bf16_stream_matches_bf16_oracle(rng):
    """Pallas bf16 path == XLA oracle fed bf16-rounded input (fp32 accum)."""
    plan = make_plan(512, 128, kappa=4, s=2, block_rows=32, seed=7,
                     dtype="bfloat16")
    A = jnp.asarray(rng.normal(size=(512, 48)), jnp.float32)
    Yp = ops.sketch_apply(plan, A, impl="pallas", tn=16)
    Yx = ops.sketch_apply(plan, A, impl="xla")
    np.testing.assert_allclose(np.asarray(Yp), np.asarray(Yx),
                               atol=1e-5, rtol=1e-5)


def test_bf16_stream_close_to_fp32(rng):
    plan = make_plan(512, 128, kappa=4, s=2, block_rows=32, seed=7)
    A = jnp.asarray(rng.normal(size=(512, 48)), jnp.float32)
    Y32 = ops.sketch_apply(plan, A, impl="pallas", tn=16)
    Yb = ops.sketch_apply(plan, A, impl="pallas", tn=16, dtype="bfloat16")
    # bf16 has ~8 mantissa bits: inputs are O(1), κs=8 terms per output
    np.testing.assert_allclose(np.asarray(Yb), np.asarray(Y32),
                               atol=5e-2, rtol=5e-2)


def test_vjp_roundtrip_bf16(rng):
    """jax.grad through sketch_apply on the bf16 path ≈ the fp32 VJP = Sᵀ dY."""
    plan = make_plan(300, 96, kappa=3, s=2, block_rows=16, seed=5)
    A = jnp.asarray(rng.normal(size=(300, 24)), jnp.float32)
    W = jnp.asarray(rng.normal(size=(plan.k, 24)), jnp.float32)

    def loss(A_, impl, dtype):
        return jnp.sum(W * ops.sketch_apply(plan, A_, impl, 8, dtype))

    g_ref = jax.grad(lambda A_: loss(A_, "xla", None))(A)
    g_bf = jax.grad(lambda A_: loss(A_, "pallas", "bfloat16"))(A)
    # dL/dA = Sᵀ W exactly, so the bf16 kernel path must track it closely
    np.testing.assert_allclose(np.asarray(g_bf), np.asarray(g_ref),
                               atol=5e-2, rtol=5e-2)
    g_f32 = jax.grad(lambda A_: loss(A_, "pallas", None))(A)
    np.testing.assert_allclose(np.asarray(g_f32), np.asarray(g_ref),
                               atol=1e-5, rtol=1e-5)


def test_plan_dtype_knob():
    plan = make_plan(256, 64, kappa=2, s=2, dtype="bfloat16")
    assert plan.stream_dtype == jnp.bfloat16
    assert plan.stream_itemsize == 2
    back = plan.with_dtype("float32")
    assert back.stream_itemsize == 4
    # dtype does not perturb the sketch draw
    assert back == make_plan(256, 64, kappa=2, s=2)
    with pytest.raises(ValueError):
        make_plan(256, 64, dtype="float16")


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------

def test_tune_heuristic_deterministic():
    plan = make_plan(512, 128, kappa=4, s=2, block_rows=32, seed=1)
    t1 = tune.resolve_tn(plan, 200, "fwd")
    t2 = tune.resolve_tn(plan, 200, "fwd")
    assert t1 == t2
    assert t1 & (t1 - 1) == 0            # power of two
    # small-n problems must not be padded past their bucket
    assert tune.resolve_tn(plan, 4, "fwd") <= 8


def test_tune_cache_roundtrip(tmp_path):
    tune.clear_cache()
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=2)
    res = tune.autotune(plan, 32, "fwd", iters=1, warmup=0)
    assert res.source == "tuned"
    assert tune.resolve_tn(plan, 32, "fwd") == res.tn
    # re-tuning the same shape class is a cache hit (same object back)
    assert tune.autotune(plan, 32, "fwd", iters=1, warmup=0) == res

    path = tmp_path / "tune.json"
    n_saved = tune.save_cache(str(path))
    assert n_saved == tune.cache_size() >= 1
    tune.clear_cache()
    assert tune.resolve_tn(plan, 32, "fwd") == tune.heuristic_tn(plan, 32, "fwd")
    n_loaded = tune.load_cache(str(path))
    assert n_loaded == n_saved
    assert tune.resolve_tn(plan, 32, "fwd") == res.tn
    # loaded entries are authoritative: autotune won't re-time them
    assert tune.autotune(plan, 32, "fwd", iters=1, warmup=0).source == "loaded"
    tune.clear_cache()


def test_tune_key_separates_dtype_and_variant():
    plan = make_plan(512, 128, kappa=4, s=2, block_rows=32, seed=1)
    k_f32 = tune.cache_key(plan, 100, "fwd")
    k_b16 = tune.cache_key(plan.with_dtype("bfloat16"), 100, "fwd")
    k_tr = tune.cache_key(plan, 100, "transpose")
    assert len({k_f32, k_b16, k_tr}) == 3
    # n buckets to the next power of two
    assert tune.cache_key(plan, 100, "fwd") == tune.cache_key(plan, 128, "fwd")
    assert tune.cache_key(plan, 100, "fwd") != tune.cache_key(plan, 129, "fwd")


def test_default_plans_fit_fused_vmem():
    """make_plan trades Br for M so the v2 working set stays VMEM-resident
    across the paper's (d, k) grid."""
    for d in (16_384, 65_536, 131_072, 262_144):
        for k in (64, 1024, 4096):
            if k * 8 > d:
                continue
            plan = make_plan(d, k, kappa=4, s=2)
            assert plan.k_pad >= k          # padding contract unchanged
            for variant in ("fwd", "transpose", "blockrow"):
                assert tune.fused_fits_vmem(plan, 512, variant), \
                    (d, k, variant, plan.describe())


def test_oversized_pinned_plan_falls_back_to_v1(rng):
    """An explicit block_rows choice that blows the fused VMEM budget must
    dispatch to the v1 revisiting kernel — silently correct, not OOM."""
    plan = make_plan(65_536, 1024, kappa=4, s=2, block_rows=256)
    assert not tune.fused_fits_vmem(plan, 8, "fwd")
    A = jnp.zeros((plan.d_pad, 8), jnp.float32)
    A = A.at[:512].set(jnp.asarray(rng.normal(size=(512, 8)), jnp.float32))
    Yp = ops.sketch_apply(plan, A[: plan.d], impl="pallas", tn=8)
    Yr = kref.flashsketch_ref(plan, A[: plan.d])
    np.testing.assert_allclose(np.asarray(Yp), np.asarray(Yr),
                               atol=1e-5, rtol=1e-5)


def test_tune_key_includes_backend():
    plan = make_plan(512, 128, kappa=4, s=2, block_rows=32, seed=1)
    k_here = tune.cache_key(plan, 64, "fwd")
    k_interp = tune.cache_key(plan, 64, "fwd", interpret=True)
    k_compiled = tune.cache_key(plan, 64, "fwd", interpret=False)
    assert k_interp != k_compiled          # interpreter winners never leak
    assert k_here in (k_interp, k_compiled)


def test_variants_plan_with_dtype_override():
    from repro.core import variants
    base = make_plan(512, 128, kappa=2, s=2)
    sk = variants.BlockPermSketch(512, 128, plan=base, dtype="bfloat16")
    assert sk.plan.dtype == "bfloat16"
    # and the cost model reflects the halved input stream
    c16 = sk.cost_model(256).hbm_bytes
    c32 = variants.BlockPermSketch(512, 128, plan=base).cost_model(256).hbm_bytes
    assert c16 < c32


def test_autotune_plan_sweeps_block_rows():
    tune.clear_cache()
    plan, res = tune.autotune_plan(512, 128, 32, kappa=2, s=2, seed=4,
                                   iters=1, warmup=0, tns=(16, 32))
    assert res.block_rows == plan.Br
    assert res.tn in (16, 32)
    # the winning plan keeps the requested sketch semantics
    assert plan.k >= 128 and plan.kappa == 2 and plan.s == 2
    tune.clear_cache()
