"""Tests for the precision-policy registry and the streaming quantizer.

Three layers:

  * registry — canonical names, aliases, single-sourced itemsize /
    jnp-dtype / tolerance-band accessors, frozen-record semantics, and
    the plan integration (``BlockPermPlan.precision``).
  * stochastic rounding, the distributional property — over many seeds
    ``E[quantize(x)] ≈ x`` for values strictly between fp8 grid points
    (the property that makes SR the right rounding for iterative
    refinement: quantization error averages out instead of biasing the
    preconditioner).
  * stochastic rounding, the determinism properties — bit-identical
    output for a fixed seed regardless of array shape or element order
    (value-keyed draws), exact passthrough on representable values, and
    saturating clamp at the format edge (e4m3 overflow must never reach
    the nan encoding).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision
from repro.core.blockperm import make_plan


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_contents_and_aliases():
    assert set(precision.POLICIES) == {
        "float32", "bfloat16", "fp8_e4m3", "fp8_e5m2",
        "fp8_e4m3_sr", "fp8_e5m2_sr"}
    assert precision.canonical("fp32") == "float32"
    assert precision.canonical("bf16") == "bfloat16"
    for name in precision.names():
        p = precision.resolve(name)
        assert precision.resolve(p) is p          # records resolve to self
    with pytest.raises(ValueError, match="registered"):
        precision.resolve("float16")


def test_itemsize_and_dtypes_single_sourced():
    cases = {
        "float32": (4, jnp.float32, 4),
        "bfloat16": (2, jnp.bfloat16, 2),
        "fp8_e4m3": (1, jnp.float8_e4m3fn, 2),
        "fp8_e5m2": (1, jnp.float8_e5m2, 2),
        "fp8_e4m3_sr": (1, jnp.float8_e4m3fn, 2),
        "fp8_e5m2_sr": (1, jnp.float8_e5m2, 2),
    }
    for name, (itemsize, stream_dtype, compute_itemsize) in cases.items():
        p = precision.resolve(name)
        assert p.itemsize == itemsize
        assert p.stream_dtype == stream_dtype
        assert p.compute_itemsize == compute_itemsize
        assert p.accum_dtype == jnp.float32       # every policy: fp32 accum
        # fp8 upcasts to bf16 in-kernel; wider policies feed the MXU as-is
        assert p.compute_dtype == (jnp.bfloat16 if p.is_fp8
                                   else stream_dtype)


def test_fp8_bands_widened_not_hardcoded():
    fp32 = precision.resolve("float32")
    for name in ("fp8_e4m3", "fp8_e5m2", "fp8_e4m3_sr", "fp8_e5m2_sr"):
        p = precision.resolve(name)
        assert p.isometry_tol > fp32.isometry_tol
        assert p.isometry_fail > fp32.isometry_fail
        assert p.ose_min_healthy < fp32.ose_min_healthy
        assert p.ose_min_failed < fp32.ose_min_failed
        assert p.exactness_atol > fp32.exactness_atol
        assert set(p.isometry_band()) == {"tol", "fail"}
        assert set(p.ose_band()) == {"min_healthy", "min_failed"}


def test_guard_defaults_sourced_from_fp32_policy():
    from repro.health import guards
    fp32 = precision.resolve("float32")
    assert guards.ISOMETRY_TOL == fp32.isometry_tol
    assert guards.ISOMETRY_FAIL == fp32.isometry_fail
    assert guards.OSE_MIN_HEALTHY == fp32.ose_min_healthy
    assert guards.OSE_MIN_FAILED == fp32.ose_min_failed


def test_records_frozen_and_hashable():
    p = precision.resolve("fp8_e4m3_sr")
    with pytest.raises(dataclasses.FrozenInstanceError):
        p.stream = "float32"
    assert len({precision.resolve(n) for n in precision.names()}) == \
        len(precision.POLICIES)


def test_plan_carries_policy_and_validates():
    plan = make_plan(256, 64, kappa=2, s=2, dtype="fp8_e4m3_sr")
    assert plan.dtype == "fp8_e4m3_sr"            # canonicalized, stored
    assert plan.precision is precision.resolve("fp8_e4m3_sr")
    assert plan.stream_itemsize == 1
    assert plan.stream_dtype == jnp.float8_e4m3fn
    # aliases canonicalize at the plan boundary (cache keys stay stable)
    assert make_plan(256, 64, dtype="bf16").dtype == "bfloat16"
    assert plan.with_dtype("fp32").dtype == "float32"
    with pytest.raises(ValueError, match="registered"):
        make_plan(256, 64, dtype="float64")


def test_fp8_max_matches_format_spec():
    assert precision.fp8_max("fp8_e4m3") == 448.0
    assert precision.fp8_max("fp8_e5m2") == 57344.0
    with pytest.raises(ValueError):
        precision.fp8_max("bfloat16")


# ---------------------------------------------------------------------------
# stochastic rounding: distributional property
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["fp8_e4m3_sr", "fp8_e5m2_sr"])
def test_sr_unbiased_over_seeds(policy):
    """E[quantize(x)] ≈ x: averaging the quantizer over many seeds must
    land within a small fraction of the local grid spacing (ulp) of the
    true value — the defining property of stochastic rounding."""
    p = precision.resolve(policy)
    grid = np.asarray(precision._finite_grid(p.stream))
    # strictly interior points at several magnitudes, incl. negatives
    rng = np.random.default_rng(0)
    lo_idx = rng.integers(1, grid.size - 2, size=16)
    frac = rng.uniform(0.2, 0.8, size=16).astype(np.float32)
    x = grid[lo_idx] + frac * (grid[lo_idx + 1] - grid[lo_idx])
    ulp = grid[lo_idx + 1] - grid[lo_idx]

    n_seeds = 1024
    acc = np.zeros_like(x, dtype=np.float64)
    for seed in range(n_seeds):
        q = precision.quantize_stream(jnp.asarray(x), p, seed=seed)
        acc += np.asarray(q.astype(jnp.float32), dtype=np.float64)
    mean = acc / n_seeds
    # CLT: sd of the mean ≤ 0.5·ulp/√n ≈ 0.016·ulp; 0.1·ulp is > 6 sigma
    np.testing.assert_array_less(np.abs(mean - x), 0.1 * ulp)


@pytest.mark.parametrize("policy", ["fp8_e4m3_sr", "fp8_e5m2_sr"])
def test_sr_rounds_to_neighbors_only(policy):
    """Every SR output is one of the value's two bracketing grid points."""
    p = precision.resolve(policy)
    grid = np.asarray(precision._finite_grid(p.stream))
    rng = np.random.default_rng(1)
    x = rng.standard_normal(512).astype(np.float32)
    for seed in (0, 7):
        q = np.asarray(precision.quantize_stream(
            jnp.asarray(x), p, seed=seed).astype(jnp.float32))
        lo_idx = np.clip(np.searchsorted(grid, x, side="right") - 1,
                         0, grid.size - 2)
        ok = (q == grid[lo_idx]) | (q == grid[lo_idx + 1])
        assert ok.all()


# ---------------------------------------------------------------------------
# stochastic rounding: determinism properties
# ---------------------------------------------------------------------------

def test_sr_bit_deterministic_for_fixed_seed():
    rng = np.random.default_rng(2)
    x = rng.standard_normal((32, 24)).astype(np.float32)
    q1 = precision.quantize_stream(jnp.asarray(x), "fp8_e4m3_sr", seed=13)
    q2 = precision.quantize_stream(jnp.asarray(x), "fp8_e4m3_sr", seed=13)
    b1 = np.asarray(jnp.asarray(q1).view(jnp.uint8))
    b2 = np.asarray(jnp.asarray(q2).view(jnp.uint8))
    np.testing.assert_array_equal(b1, b2)
    # a different seed really does draw differently somewhere
    q3 = precision.quantize_stream(jnp.asarray(x), "fp8_e4m3_sr", seed=14)
    assert not np.array_equal(np.asarray(jnp.asarray(q3).view(jnp.uint8)),
                              b1)


def test_sr_value_keyed_shape_and_order_invariant():
    """The draw depends on the VALUE, not the position: reshaping or
    permuting the array must quantize each element identically — the
    property that keeps batched / loop / gather kernel organizations
    bit-exact against the oracle."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal(256).astype(np.float32)
    flat = np.asarray(precision.quantize_stream(
        jnp.asarray(x), "fp8_e4m3_sr", seed=5).astype(jnp.float32))
    as_mat = np.asarray(precision.quantize_stream(
        jnp.asarray(x.reshape(16, 16)), "fp8_e4m3_sr",
        seed=5).astype(jnp.float32)).ravel()
    perm = rng.permutation(256)
    shuffled = np.asarray(precision.quantize_stream(
        jnp.asarray(x[perm]), "fp8_e4m3_sr", seed=5).astype(jnp.float32))
    np.testing.assert_array_equal(flat, as_mat)
    np.testing.assert_array_equal(flat[perm], shuffled)


@pytest.mark.parametrize("policy", ["fp8_e4m3", "fp8_e4m3_sr",
                                    "fp8_e5m2", "fp8_e5m2_sr"])
def test_exact_passthrough_on_representable_values(policy):
    """Every finite fp8 value round-trips exactly — nearest AND
    stochastic (frac = 0 at a grid point: nothing to draw)."""
    p = precision.resolve(policy)
    grid = np.asarray(precision._finite_grid(p.stream))
    q = np.asarray(precision.quantize_stream(
        jnp.asarray(grid), p, seed=9).astype(jnp.float32))
    np.testing.assert_array_equal(q, grid)


@pytest.mark.parametrize("policy", ["fp8_e4m3", "fp8_e4m3_sr"])
def test_overflow_saturates_never_nan(policy):
    """e4m3 has no inf: a plain astype of an out-of-range value produces
    nan.  The streaming cast must clamp to ±448 instead."""
    x = jnp.asarray(np.array([1e6, -1e6, 448.0, -448.0, 1e38, -1e38],
                             dtype=np.float32))
    q = np.asarray(precision.quantize_stream(
        x, policy, seed=0).astype(jnp.float32))
    assert np.isfinite(q).all()
    np.testing.assert_array_equal(
        q, np.array([448.0, -448.0, 448.0, -448.0, 448.0, -448.0]))


def test_nearest_policies_ignore_seed():
    x = jnp.asarray(np.linspace(-3, 3, 64, dtype=np.float32))
    a = np.asarray(precision.quantize_stream(
        x, "fp8_e4m3", seed=0).astype(jnp.float32))
    b = np.asarray(precision.quantize_stream(
        x, "fp8_e4m3", seed=99).astype(jnp.float32))
    np.testing.assert_array_equal(a, b)


def test_emulate_stream_matches_quantize_and_fp32_identity():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal(128).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(precision.emulate_stream(x, "float32")), np.asarray(x))
    for policy in ("bfloat16", "fp8_e4m3_sr"):
        em = np.asarray(precision.emulate_stream(x, policy, seed=3))
        q = np.asarray(precision.quantize_stream(
            x, policy, seed=3).astype(jnp.float32))
        np.testing.assert_array_equal(em, q)
