"""Pallas (interpret=True) vs pure-jnp oracle: shape/dtype/param sweeps.

Per the assignment: for each Pallas kernel, sweep shapes/dtypes and
assert_allclose against the ref.py oracle.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.blockperm import make_plan
from repro.kernels import ops
from repro.kernels import ref as kref

SWEEP = [
    # (d, k, kappa, s, block_rows, n)
    (256, 64, 1, 1, 8, 16),
    (256, 64, 2, 2, 8, 33),
    (300, 96, 3, 2, 16, 37),
    (512, 128, 4, 4, 32, 64),
    (1000, 256, 4, 2, 32, 128),
    (128, 128, 2, 1, 16, 1),
    (2048, 512, 8, 2, 64, 20),
]


@pytest.mark.parametrize("d,k,kappa,s,br,n", SWEEP)
def test_flashsketch_fwd(d, k, kappa, s, br, n, rng):
    plan = make_plan(d=d, k=k, kappa=kappa, s=s, block_rows=br, seed=d + n)
    A = jnp.asarray(rng.normal(size=(d, n)), jnp.float32)
    Y_ref = kref.flashsketch_ref(plan, A)
    Y_pl = ops.sketch_apply(plan, A, impl="pallas", tn=16)
    np.testing.assert_allclose(np.asarray(Y_pl), np.asarray(Y_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("d,k,kappa,s,br,n", SWEEP[:5])
def test_flashsketch_transpose(d, k, kappa, s, br, n, rng):
    plan = make_plan(d=d, k=k, kappa=kappa, s=s, block_rows=br, seed=d + n)
    Y = jnp.asarray(rng.normal(size=(plan.k, n)), jnp.float32)
    X_ref = kref.flashsketch_transpose_ref(plan, Y)
    X_pl = ops.sketch_apply_t(plan, Y, impl="pallas", tn=16)
    np.testing.assert_allclose(np.asarray(X_pl), np.asarray(X_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("d,k,kappa,s,br,n", SWEEP[:5])
def test_blockrow(d, k, kappa, s, br, n, rng):
    plan = make_plan(d=d, k=k, kappa=kappa, s=s, block_rows=br, seed=d + n)
    A = jnp.asarray(rng.normal(size=(d, n)), jnp.float32)
    Y_ref = kref.blockrow_ref(plan, A)
    Y_pl = ops.blockrow_apply(plan, A, impl="pallas", tn=16)
    np.testing.assert_allclose(np.asarray(Y_pl), np.asarray(Y_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype, rng):
    """Kernel accepts bf16 inputs (accumulates f32, returns f32)."""
    plan = make_plan(d=256, k=64, kappa=2, s=2, block_rows=8, seed=1)
    A = jnp.asarray(rng.normal(size=(256, 24)), dtype)
    Y_ref = kref.flashsketch_ref(plan, A)
    Y_pl = ops.sketch_apply(plan, A, impl="pallas", tn=8)
    atol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(Y_pl, np.float32),
                               np.asarray(Y_ref, np.float32), atol=atol, rtol=1e-2)


@pytest.mark.parametrize("tn", [8, 16, 64, 128])
def test_tile_width_invariance(tn, rng):
    """Output must be independent of the column-tile width T_n."""
    plan = make_plan(d=256, k=64, kappa=2, s=2, block_rows=8, seed=1)
    A = jnp.asarray(rng.normal(size=(256, 24)), jnp.float32)
    Y_ref = kref.flashsketch_ref(plan, A)
    Y_pl = ops.sketch_apply(plan, A, impl="pallas", tn=tn)
    np.testing.assert_allclose(np.asarray(Y_pl), np.asarray(Y_ref), atol=1e-4)


def test_vector_api(rng):
    plan = make_plan(d=100, k=32, kappa=2, s=2, block_rows=8, seed=6)
    x = jnp.asarray(rng.normal(size=(4, 3, 100)), jnp.float32)
    y = ops.sketch_vectors(plan, x, impl="xla")
    assert y.shape == (4, 3, plan.k)
    # consistency with matrix API
    Y = ops.sketch_apply(plan, x.reshape(-1, 100).T, "xla")
    np.testing.assert_allclose(np.asarray(y.reshape(-1, plan.k).T),
                               np.asarray(Y), atol=1e-5)
