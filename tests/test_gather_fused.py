"""Gather-fused batched FlashSketch tests (PR-3 acceptance set).

Covers: bit-exactness of the fused ``S @ A[mask, :]`` kernel against
gather-then-``pallas`` on every gatherable variant and both streaming
dtypes, the XLA oracle equivalence, the scatter VJP, batched apply vs a
per-example loop, and the autotuner's new gather+batch cache-key dims.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.blockperm import GATHER_VARIANTS, make_plan
from repro.kernels import ops, ref as kref, tune

SWEEP = [
    # (d_src, d_keep, k, kappa, s, block_rows, n)
    (700, 256, 64, 1, 1, 8, 16),
    (800, 256, 64, 2, 2, 8, 33),
    (900, 300, 96, 3, 2, 16, 37),
    (2000, 512, 128, 4, 4, 32, 64),
]


def _mask(rng, d_src, d_keep):
    return jnp.asarray(np.sort(rng.choice(d_src, d_keep, replace=False)),
                       jnp.int32)


# ---------------------------------------------------------------------------
# Fused gather: bit-exact vs the unfused v2 kernel, on all variants/dtypes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [None, "bfloat16"])
@pytest.mark.parametrize("d_src,d_keep,k,kappa,s,br,n", SWEEP)
def test_fused_gather_bit_exact_fwd(d_src, d_keep, k, kappa, s, br, n,
                                    dtype, rng):
    plan = make_plan(d_keep, k, kappa=kappa, s=s, block_rows=br, seed=d_src)
    A = jnp.asarray(rng.normal(size=(d_src, n)), jnp.float32)
    idx = _mask(rng, d_src, d_keep)
    fused = ops.sketch_apply(plan, A, "pallas", 16, dtype, row_index=idx)
    ref = ops.sketch_apply(plan, A[idx], "pallas", 16, dtype)
    # same contraction, same operand values => bitwise equal, not just close
    assert np.array_equal(np.asarray(fused), np.asarray(ref))


@pytest.mark.parametrize("dtype", [None, "bfloat16"])
@pytest.mark.parametrize("d_src,d_keep,k,kappa,s,br,n", SWEEP[:3])
def test_fused_gather_bit_exact_blockrow(d_src, d_keep, k, kappa, s, br, n,
                                         dtype, rng):
    plan = make_plan(d_keep, k, kappa=kappa, s=s, block_rows=br, seed=d_src)
    A = jnp.asarray(rng.normal(size=(d_src, n)), jnp.float32)
    idx = _mask(rng, d_src, d_keep)
    fused = ops.blockrow_apply(plan, A, "pallas", 16, dtype, row_index=idx)
    ref = ops.blockrow_apply(plan, A[idx], "pallas", 16, dtype)
    assert np.array_equal(np.asarray(fused), np.asarray(ref))


def test_fused_gather_matches_xla_oracle(rng):
    plan = make_plan(300, 96, kappa=3, s=2, block_rows=16, seed=9)
    A = jnp.asarray(rng.normal(size=(1100, 40)), jnp.float32)
    idx = _mask(rng, 1100, 300)
    np.testing.assert_allclose(
        np.asarray(ops.sketch_apply(plan, A, "pallas", 8, row_index=idx)),
        np.asarray(kref.flashsketch_ref(plan, A[idx])),
        atol=1e-5, rtol=1e-5,
    )


def test_fused_gather_identity_mask_equals_plain(rng):
    """A full-range mask must reproduce the non-gather kernel exactly."""
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    A = jnp.asarray(rng.normal(size=(256, 24)), jnp.float32)
    idx = jnp.arange(256, dtype=jnp.int32)
    fused = ops.sketch_apply(plan, A, "pallas", 8, row_index=idx)
    plain = ops.sketch_apply(plan, A, "pallas", 8)
    assert np.array_equal(np.asarray(fused), np.asarray(plain))


def test_fused_gather_wrong_mask_len_raises(rng):
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=4)
    A = jnp.asarray(rng.normal(size=(500, 8)), jnp.float32)
    with pytest.raises(ValueError, match="plan.d"):
        ops.sketch_apply(plan, A, "pallas", 8,
                         row_index=jnp.arange(100, dtype=jnp.int32))


def test_fused_gather_v1_and_xla_fallbacks(rng):
    """pallas_v1 has no gather formulation: it must materialize and agree."""
    plan = make_plan(300, 96, kappa=3, s=2, block_rows=16, seed=2)
    A = jnp.asarray(rng.normal(size=(700, 16)), jnp.float32)
    idx = _mask(rng, 700, 300)
    v1 = ops.sketch_apply(plan, A, "pallas_v1", 8, row_index=idx)
    xla = ops.sketch_apply(plan, A, "xla", row_index=idx)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(xla),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Differentiation: VJP of the fused gather is the scattered un-sketch
# ---------------------------------------------------------------------------

def test_fused_gather_vjp_is_scattered_transpose(rng):
    plan = make_plan(300, 96, kappa=3, s=2, block_rows=16, seed=5)
    A = jnp.asarray(rng.normal(size=(900, 24)), jnp.float32)
    idx = _mask(rng, 900, 300)
    W = jnp.asarray(rng.normal(size=(plan.k, 24)), jnp.float32)

    g_fused = jax.grad(lambda A_: jnp.sum(
        W * ops.sketch_apply(plan, A_, "pallas", 8, row_index=idx)))(A)
    g_ref = jax.grad(lambda A_: jnp.sum(
        W * ops.sketch_apply(plan, A_[idx], "xla")))(A)
    np.testing.assert_allclose(np.asarray(g_fused), np.asarray(g_ref),
                               atol=1e-5, rtol=1e-5)
    # rows off the mask receive exactly zero cotangent
    off = np.setdiff1d(np.arange(900), np.asarray(idx))
    assert np.all(np.asarray(g_fused)[off] == 0.0)


def test_sketch_apply_t_scatter_dual(rng):
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=6)
    Y = jnp.asarray(rng.normal(size=(plan.k, 12)), jnp.float32)
    idx = _mask(rng, 600, 256)
    X = ops.sketch_apply_t(plan, Y, "xla", row_index=idx, d_src=600)
    Xc = ops.sketch_apply_t(plan, Y, "xla")
    assert X.shape == (600, 12)
    np.testing.assert_allclose(np.asarray(X[idx]), np.asarray(Xc),
                               atol=1e-6, rtol=1e-6)
    with pytest.raises(ValueError, match="d_src"):
        ops.sketch_apply_t(plan, Y, "xla", row_index=idx)


# ---------------------------------------------------------------------------
# Batched apply: one launch == per-example loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas", "pallas_v1"])
def test_batched_equals_per_example_loop(impl, rng):
    plan = make_plan(300, 96, kappa=3, s=2, block_rows=16, seed=7)
    G = jnp.asarray(rng.normal(size=(5, 900, 8)), jnp.float32)
    idx = _mask(rng, 900, 300)
    Yb = ops.sketch_apply_batched(plan, G, impl, row_index=idx)
    Yl = jnp.stack([
        ops.sketch_apply(plan, G[b], impl, row_index=idx)
        for b in range(G.shape[0])
    ])
    assert Yb.shape == (5, plan.k, 8)
    np.testing.assert_allclose(np.asarray(Yb), np.asarray(Yl),
                               atol=1e-5, rtol=1e-5)


def test_batched_without_gather_unchanged(rng):
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=8)
    G = jnp.asarray(rng.normal(size=(3, 256, 8)), jnp.float32)
    Yb = ops.sketch_apply_batched(plan, G, "pallas")
    Yl = jnp.stack([ops.sketch_apply(plan, G[b], "pallas")
                    for b in range(3)])
    np.testing.assert_allclose(np.asarray(Yb), np.asarray(Yl),
                               atol=1e-5, rtol=1e-5)


def test_sketch_vectors_gather(rng):
    plan = make_plan(300, 96, kappa=2, s=2, block_rows=16, seed=3)
    x = jnp.asarray(rng.normal(size=(6, 900)), jnp.float32)
    idx = _mask(rng, 900, 300)
    y = ops.sketch_vectors(plan, x, "xla", row_index=idx)
    want = ops.sketch_vectors(plan, x[:, np.asarray(idx)], "xla")
    assert y.shape == (6, plan.k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Autotuner: gather+batch cache-key dims
# ---------------------------------------------------------------------------

def test_cache_key_gains_gather_and_batch_dims():
    plan = make_plan(512, 128, kappa=4, s=2, block_rows=32, seed=1)
    k_plain = tune.cache_key(plan, 64, "fwd")
    k_gather = tune.cache_key(plan, 64, "fwd_gather")
    k_batched = tune.cache_key(plan, 64, "fwd", batch=32)
    assert len({k_plain, k_gather, k_batched}) == 3
    # the gather flag is an explicit key field, not just the variant name
    assert k_gather[-2] is True and k_plain[-2] is False
    # batch buckets like n: 32 and 33 round to different powers of two
    assert tune.cache_key(plan, 64, "fwd", batch=17) == \
        tune.cache_key(plan, 64, "fwd", batch=32)
    assert tune.cache_key(plan, 64, "fwd", batch=33) != \
        tune.cache_key(plan, 64, "fwd", batch=32)


def test_gather_variants_registered():
    for v in GATHER_VARIANTS:
        assert v in tune.VARIANTS
        assert v in tune._KERNELS


def test_tune_cache_roundtrips_gather_batch_fields(tmp_path):
    tune.clear_cache()
    plan = make_plan(256, 64, kappa=2, s=2, block_rows=8, seed=2)
    res = tune.autotune(plan, 4, "fwd_gather", batch=8, iters=1, warmup=0)
    assert res.source == "tuned"
    assert tune.resolve_tn(plan, 4, "fwd_gather", batch=8) == res.tn
    path = tmp_path / "tune_gather.json"
    n_saved = tune.save_cache(str(path))
    tune.clear_cache()
    assert tune.load_cache(str(path)) == n_saved
    # the loaded winner is served for the SAME (gather, batch) class only
    assert tune.resolve_tn(plan, 4, "fwd_gather", batch=8) == res.tn
    assert tune.autotune(plan, 4, "fwd_gather", batch=8,
                         iters=1, warmup=0).source == "loaded"
    tune.clear_cache()


def test_gather_heuristic_respects_vmem():
    plan = make_plan(4096, 1024, kappa=2, s=2)
    from repro.core.blockperm import VMEM_BUDGET_BYTES, fused_variant_bytes
    tn = tune.heuristic_tn(plan, 1, "fwd_gather", batch=256)
    assert fused_variant_bytes(plan.kappa, plan.Br, plan.Bc, tn,
                               plan.stream_itemsize,
                               "fwd_gather") <= VMEM_BUDGET_BYTES
