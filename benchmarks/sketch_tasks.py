"""RandNLA task benchmarks — one per paper table/figure:

  gram    — Fig. 1 / App. F.2   (Gram relative-F error vs time)
  ose     — App. F.3            (OSE spectral error vs time)
  ridge   — Fig. 3 / App. F.4   (sketch-and-ridge residual vs time)
  solve   — App. F.5            (sketch-and-solve LS residual vs time)

Each yields BenchRows across sketch families × k × datasets; the κ/s
ablations (App. F legends) come from ``ablation_rows``.
"""
from __future__ import annotations

from typing import Iterable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coherence
from benchmarks import common


def _quality(task: str, A: np.ndarray, SA: np.ndarray, seed: int) -> float:
    d, n = A.shape
    if task == "gram":
        return coherence.gram_rel_error(A, SA)
    if task == "ose":
        Q, _ = np.linalg.qr(A)                      # column-space variant
        return float("nan")                         # handled separately
    raise KeyError(task)


def gram_rows(d: int, n: int, k_values, families, datasets, seed: int = 0
              ) -> List[common.BenchRow]:
    rows = []
    for ds in datasets:
        A_np = common.make_dataset(ds, d, n, seed)
        A = jnp.asarray(A_np)
        for fam, kw in families:
            for k in k_values:
                sk = common.build_sketch(fam, d, k, seed, kw)
                f = common.jit_apply(sk)
                t = common.time_fn(f, A)
                SA = np.asarray(f(A))
                rows.append(common.BenchRow(
                    "gram", ds, fam, d, n, sk.k, str(kw),
                    1e6 * t, common.modeled_tpu_us(sk, n),
                    coherence.gram_rel_error(A_np, SA), "gram_rel_F"))
    return rows


def ose_rows(d: int, n: int, k_values, families, datasets, seed: int = 0,
             r: int = 32) -> List[common.BenchRow]:
    rows = []
    for ds in datasets:
        A_np = common.make_dataset(ds, d, max(n, r), seed)
        Q, _ = np.linalg.qr(A_np[:, :r])
        Qj = jnp.asarray(Q.astype(np.float32))
        for fam, kw in families:
            for k in k_values:
                sk = common.build_sketch(fam, d, k, seed, kw)
                f = common.jit_apply(sk)
                t = common.time_fn(f, Qj)
                SQ = np.asarray(f(Qj))
                rows.append(common.BenchRow(
                    "ose", ds, fam, d, n, sk.k, str(kw),
                    1e6 * t, common.modeled_tpu_us(sk, r),
                    coherence.ose_spectral_error(Q, SQ), "ose_spectral"))
    return rows


def _ridge_solve(A, b, S_apply, lam: float):
    """x = argmin ‖S A x − S b‖² + λ‖x‖²  then residual ‖Ax−b‖/‖b‖."""
    SA = S_apply(A)
    Sb = S_apply(b[:, None])[:, 0]
    n = SA.shape[1]
    G = SA.T @ SA + lam * jnp.eye(n)
    x = jnp.linalg.solve(G, SA.T @ Sb)
    res = jnp.linalg.norm(A @ x - b) / jnp.maximum(jnp.linalg.norm(b), 1e-12)
    return x, res


def ridge_rows(d: int, n: int, k_values, families, datasets, seed: int = 0,
               lam: float = 1e-2, task: str = "ridge") -> List[common.BenchRow]:
    rows = []
    eff_lam = lam if task == "ridge" else 0.0
    for ds in datasets:
        A_np = common.make_dataset(ds, d, n, seed)
        rng = np.random.default_rng(seed + 1)
        x_true = rng.normal(size=(n,)).astype(np.float32)
        b_np = A_np @ x_true + 0.01 * rng.normal(size=(d,)).astype(np.float32)
        A = jnp.asarray(A_np)
        b = jnp.asarray(b_np)
        for fam, kw in families:
            for k in k_values:
                sk = common.build_sketch(fam, d, k, seed, kw)

                def end_to_end(A_, b_):
                    return _ridge_solve(A_, b_, sk.apply, eff_lam)[1]

                f = jax.jit(end_to_end)
                t = common.time_fn(f, A, b)
                res = float(f(A, b))
                rows.append(common.BenchRow(
                    task, ds, fam, d, n, sk.k, str(kw),
                    1e6 * t, common.modeled_tpu_us(sk, n + 1),
                    res, "rel_residual"))
    return rows


def ablation_rows(d: int, n: int, k: int, seed: int = 0,
                  datasets=("gaussian", "llm_weights")) -> List[common.BenchRow]:
    """κ/s ablation grid (App. F legend: blockperm(κ,s) settings)."""
    rows = []
    for ds in datasets:
        A_np = common.make_dataset(ds, d, n, seed)
        A = jnp.asarray(A_np)
        for kappa in (1, 2, 4, 8):
            for s in (1, 2, 4):
                sk = common.build_sketch(
                    "blockperm", d, k, seed, {"kappa": kappa, "s": s})
                f = common.jit_apply(sk)
                t = common.time_fn(f, A)
                SA = np.asarray(f(A))
                rows.append(common.BenchRow(
                    "gram_ablation", ds, "blockperm", d, n, sk.k,
                    f"kappa={kappa},s={s}",
                    1e6 * t, common.modeled_tpu_us(sk, n),
                    coherence.gram_rel_error(A_np, SA), "gram_rel_F"))
    return rows
