"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS

DRYRUN_DIR = os.environ.get("DRYRUN_OUT", "experiments/dryrun")

HEADER = (
    "| arch | shape | mesh | compute ms | memory ms | collective ms | "
    "bottleneck | useful(6ND/HLO) | roofline-frac | mem/dev |\n"
    "|---|---|---|---|---|---|---|---|---|---|")


def load_records() -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r: Dict) -> str:
    if r.get("status") == "skip":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"{r['reason']} | — | — | — |")
    if r.get("status") == "fail":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"FAIL: {r.get('error','?')[:60]} | — | — | — |")
    ideal = r["model_flops"] / (r["chips"] * 197e12)
    step = max(r["compute_s"], r["memory_s"], r["collective_s"])
    frac = ideal / step if step > 0 else 0.0
    mem = (r["arg_bytes_per_device"] + r["temp_bytes_per_device"]) / 2**30
    useful = r["model_flops"] / max(r["device_flops"] * r["chips"], 1.0)
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']*1e3:.0f} | {r['memory_s']*1e3:.0f} | "
            f"{r['collective_s']*1e3:.0f} | {r['bottleneck']} | "
            f"{useful:.2f} | {frac*100:.0f}% | {mem:.1f} GiB |")


def table_markdown(mesh_filter: str = None) -> str:
    recs = load_records()
    if mesh_filter:
        recs = [r for r in recs if r.get("mesh") == mesh_filter]
    order = {a: i for i, a in enumerate(ARCHS)}
    shape_order = {s.name: i for i, s in enumerate(SHAPES)}
    recs.sort(key=lambda r: (order.get(r["arch"], 99),
                             shape_order.get(r["shape"], 9), r.get("mesh", "")))
    return HEADER + "\n" + "\n".join(fmt_row(r) for r in recs)


def csv_rows() -> List[str]:
    rows = []
    for r in load_records():
        if r.get("status") != "ok":
            rows.append(f"dryrun,{r['arch']},{r['shape']},{r.get('mesh','')},"
                        f",,,,{r.get('status')}:{r.get('reason', r.get('error',''))[:40]}")
            continue
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append(
            f"dryrun,{r['arch']},{r['shape']},{r['mesh']},"
            f"{r['compute_s']*1e6:.0f},{r['memory_s']*1e6:.0f},"
            f"{r['collective_s']*1e6:.0f},{step*1e6:.0f},{r['bottleneck']}")
    return rows


if __name__ == "__main__":
    print(table_markdown())
