"""GraSS sparsify→sketch benchmark: gather-fused batched FlashSketch vs the
seed pipeline (materialized gather + per-example sketch launches).

    PYTHONPATH=src python -m benchmarks.grass_bench               # paper grid
    PYTHONPATH=src python -m benchmarks.grass_bench --tiny        # CI smoke

Writes ``BENCH_grass.json``.  Each row covers one (B, sparse_dim, κ) cell:

  * measured_* — interpret-mode wall-clock on THIS host.  Real, and the
    per-example column shows the launch-count pathology directly, but the
    DMA emulation overhead makes interpret-mode gather *kernels* slow —
    not TPU time.
  * modeled_*  — TPU-v5e numbers from ``roofline.sketch_model.
    grass_sketch_cost`` (transaction-granular gather reads + per-launch
    overhead); the trustworthy number off-TPU and the one the acceptance
    geomean is computed from.

The run FAILS (non-zero exit) if the fused kernel is not bit-exact against
gather-then-``pallas`` on any variant/dtype, or if the modeled geomean
speedup of fused-batched over gather-then-sketch-per-example drops below
1.5× — CI runs ``--tiny`` as a regression gate.

``grass_rows`` (the Fig.-4 LDS-vs-time rows used by ``benchmarks.run``) is
kept unchanged at the bottom.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import geomean, time_fn
from repro import engine
from repro.attribution.grass import sparsify_mask
from repro.core.blockperm import make_plan
from repro.kernels import ops
from repro.roofline import sketch_model

DTYPES = (None, "bfloat16")          # None = fp32 (the plan default)


def _bit_exact(plan, G, mask, tn, dtype) -> Dict[str, bool]:
    """Fused S·G[mask] vs gather-then-pallas on every gatherable variant."""
    Gm = G[mask]
    out = {}
    for variant in ("fwd", "blockrow"):
        if variant == "fwd":
            fused = ops.sketch_apply(plan, G, "pallas", tn, dtype,
                                     row_index=mask)
            ref = ops.sketch_apply(plan, Gm, "pallas", tn, dtype)
        else:
            fused = ops.blockrow_apply(plan, G, "pallas", tn, dtype,
                                       row_index=mask)
            ref = ops.blockrow_apply(plan, Gm, "pallas", tn, dtype)
        out[f"{variant}_{dtype or 'float32'}"] = bool(
            np.array_equal(np.asarray(fused), np.asarray(ref)))
    return out


def bench_grid(B_values, sparse_dims, kappas, *, k, d_total_of, s=2, seed=0,
               iters=3, max_measured_examples=8) -> List[Dict]:
    rows: List[Dict] = []
    rng = np.random.default_rng(seed)
    for sparse_dim in sparse_dims:
        d_total = d_total_of(sparse_dim)
        mask = sparsify_mask(d_total, sparse_dim, seed)
        for kappa in kappas:
            plan = make_plan(sparse_dim, k, kappa=kappa, s=s, seed=seed)
            for B in B_values:
                # B per-example gradient vectors as columns of one (D, B)
                G = jnp.asarray(
                    rng.normal(size=(d_total, B)).astype(np.float32))
                # each kernel shape class gets its own VMEM-fitting tile —
                # the fused gather scratch is smaller than the fwd kernel's
                # double-buffered pipeline, so their budgets differ; the
                # bit-exact check runs both at the common (smaller) width.
                # Tiles come from the lowering records of the two launches
                # being compared (the engine is the single decision layer).
                lw_fused = engine.lower(plan, engine.LaunchSpec(
                    op="fwd", n=1, impl="pallas", gather=True, batch=B))
                lw_ref = engine.lower(plan, engine.LaunchSpec(
                    op="fwd", n=B, impl="pallas"))
                tn = lw_fused.tn
                tn_ref = lw_ref.tn
                tn_check = min(tn, tn_ref)

                # -------- bit-exactness gate (all variants × dtypes)
                exact = {}
                for dtype in DTYPES:
                    exact.update(_bit_exact(plan, G, mask, tn_check, dtype))

                # -------- measured (interpret mode off-TPU)
                fused = jax.jit(lambda X: ops.sketch_apply(
                    plan, X, "pallas", tn, None, row_index=mask))
                fused_us = 1e6 * time_fn(fused, G, iters=iters)

                unf_batched = jax.jit(
                    lambda X: ops.sketch_apply(plan, X[mask], "pallas",
                                               tn_ref))
                unf_batched_us = 1e6 * time_fn(unf_batched, G, iters=iters)

                # per-example: B materializing-gather + skinny-sketch passes
                # (the gather happens INSIDE the timed fn, as in the seed
                # pipeline) — measure a capped number of examples and
                # extrapolate (the passes are identical; interpret-mode
                # python overhead is per-launch)
                n_meas = min(B, max_measured_examples)
                one = jax.jit(lambda g_col: ops.sketch_apply(
                    plan, g_col[mask], "pallas", min(8, tn_ref)))
                cols = [G[:, b:b + 1] for b in range(n_meas)]

                def per_example_pass(cols=cols):
                    outs = [one(c) for c in cols]
                    return outs[-1]

                per_meas_us = 1e6 * time_fn(per_example_pass, iters=iters)
                per_example_us = per_meas_us * (B / n_meas)

                # -------- modeled (TPU v5e)
                m = {
                    kind: sketch_model.grass_sketch_cost(
                        plan, B, fused=f, batched=b)
                    for kind, (f, b) in {
                        "fused_batched": (True, True),
                        "fused_per_example": (True, False),
                        "unfused_batched": (False, True),
                        "unfused_per_example": (False, False),
                    }.items()
                }
                row = dict(
                    B=B, d_total=d_total, sparse_dim=sparse_dim, k=plan.k_pad,
                    kappa=kappa, s=s, tn=tn, tn_ref=tn_ref,
                    M=plan.M, Br=plan.Br, Bc=plan.Bc,
                    lowering_fused=lw_fused.describe(),
                    bit_exact=exact,
                    measured_fused_batched_us=fused_us,
                    measured_unfused_batched_us=unf_batched_us,
                    measured_unfused_per_example_us=per_example_us,
                    measured_examples=n_meas,
                    measured_speedup=per_example_us / fused_us,
                    modeled_fused_batched_us=m["fused_batched"],
                    modeled_fused_per_example_us=m["fused_per_example"],
                    modeled_unfused_batched_us=m["unfused_batched"],
                    modeled_unfused_per_example_us=m["unfused_per_example"],
                    modeled_speedup=(m["unfused_per_example"]
                                     / m["fused_batched"]),
                    modeled_speedup_vs_unfused_batched=(
                        m["unfused_batched"] / m["fused_batched"]),
                )
                rows.append(row)
                ok = all(exact.values())
                print(f"B={B:>4} d_keep={sparse_dim:>6} kappa={kappa} "
                      f"tn={tn:<4} bit_exact={'OK' if ok else 'FAIL'} "
                      f"measured x{row['measured_speedup']:.2f} "
                      f"modeled x{row['modeled_speedup']:.1f} "
                      f"(vs unfused-batched x"
                      f"{row['modeled_speedup_vs_unfused_batched']:.2f})")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke grid (seconds, still gates bit-exactness)")
    ap.add_argument("--out", default="BENCH_grass.json")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)

    if args.tiny:
        B_values, sparse_dims, kappas = (8,), (512,), (1,)
        k, d_total_of = 128, lambda d: 4 * d
    else:
        B_values, sparse_dims, kappas = (32, 256), (4096, 16_384), (1, 2)
        k, d_total_of = 1024, lambda d: 4 * d

    rows = bench_grid(B_values, sparse_dims, kappas, k=k,
                      d_total_of=d_total_of, iters=args.iters)

    all_exact = all(all(r["bit_exact"].values()) for r in rows)
    geo_modeled = geomean([r["modeled_speedup"] for r in rows])
    geo_measured = geomean([r["measured_speedup"] for r in rows])
    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "interpret": jax.default_backend() != "tpu",
            "tiny": args.tiny,
            "grid": {"B": list(B_values), "sparse_dim": list(sparse_dims),
                     "kappa": list(kappas), "k": k},
            "note": ("fused-gather-batched vs gather-then-sketch; "
                     "measured_* is interpret-mode wall-clock off-TPU "
                     "(per-example column extrapolated from "
                     "measured_examples launches); modeled_* is "
                     "roofline.sketch_model.grass_sketch_cost on TPU v5e"),
        },
        "rows": rows,
        "all_bit_exact": all_exact,
        "geomean_modeled_speedup": geo_modeled,
        "geomean_measured_speedup": geo_measured,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\nwrote {args.out}: modeled geomean x{geo_modeled:.1f}, "
          f"measured geomean x{geo_measured:.2f}, "
          f"bit_exact={'OK' if all_exact else 'FAIL'}")

    if not all_exact:
        print("FAIL: fused path lost bit-exactness vs the unfused reference",
              file=sys.stderr)
        return 1
    if geo_modeled < 1.5:
        print(f"FAIL: modeled geomean {geo_modeled:.2f}x < 1.5x",
              file=sys.stderr)
        return 1
    return 0


# ---------------------------------------------------------------------------
# Fig. 4 analogue: end-to-end GraSS — LDS vs per-sample sketch time,
# across sketch families × k (paper App. E: MLP, sketch 4k -> k).
# Used by ``benchmarks.run --only grass``.
# ---------------------------------------------------------------------------

def grass_rows(scale: str = "smoke") -> List[str]:
    from repro.attribution.grass import GrassPipelineConfig, run_grass_lds
    from repro.attribution.mlp import MLPConfig

    if scale == "full":
        mcfg = MLPConfig(d_in=784, hidden=(256, 256), steps=120)
        n_train, n_test, m = 1024, 32, 50
        sparse, ks = 4096, (1024, 2048)
    else:
        mcfg = MLPConfig(d_in=128, hidden=(32, 32), steps=80)
        n_train, n_test, m = 256, 24, 24
        sparse, ks = 1024, (256,)
    rows = []
    for fam in ("blockperm", "dense_gaussian", "sjlt", "srht", "blockrow"):
        for k in ks:
            res = run_grass_lds(
                GrassPipelineConfig(sparse_dim=sparse, sketch_dim=k,
                                    sketch_family=fam),
                mcfg, n_train=n_train, n_test=n_test, m_subsets=m)
            rows.append(
                f"grass,{fam},k={k},,,,{res['lds']:.4f},"
                f"{res['per_sample_us']:.1f},lds_vs_us_per_sample")
    return rows


if __name__ == "__main__":
    sys.exit(main())
