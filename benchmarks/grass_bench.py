"""Fig. 4 analogue: end-to-end GraSS — LDS vs per-sample sketch time,
across sketch families × k (paper App. E: MLP, sketch 4k -> k)."""
from __future__ import annotations

from typing import List

from repro.attribution.grass import GrassPipelineConfig, run_grass_lds
from repro.attribution.mlp import MLPConfig


def grass_rows(scale: str = "smoke") -> List[str]:
    if scale == "full":
        mcfg = MLPConfig(d_in=784, hidden=(256, 256), steps=120)
        n_train, n_test, m = 1024, 32, 50
        sparse, ks = 4096, (1024, 2048)
    else:
        mcfg = MLPConfig(d_in=128, hidden=(32, 32), steps=80)
        n_train, n_test, m = 256, 24, 24
        sparse, ks = 1024, (256,)
    rows = []
    for fam in ("blockperm", "dense_gaussian", "sjlt", "srht", "blockrow"):
        for k in ks:
            res = run_grass_lds(
                GrassPipelineConfig(sparse_dim=sparse, sketch_dim=k,
                                    sketch_family=fam),
                mcfg, n_train=n_train, n_test=n_test, m_subsets=m)
            rows.append(
                f"grass,{fam},k={k},,,,{res['lds']:.4f},"
                f"{res['per_sample_us']:.1f},lds_vs_us_per_sample")
    return rows
