"""Serving bench: latency/throughput under Poisson load + faults-under-load.

    PYTHONPATH=src python -m benchmarks.serve_bench                # full
    PYTHONPATH=src python -m benchmarks.serve_bench --tiny         # CI smoke
    PYTHONPATH=src python -m benchmarks.serve_bench --tiny --inject

Writes ``BENCH_serve.json`` and exits non-zero if a gate fails.

The harness is EVENT-DRIVEN VIRTUAL TIME: the server runs on a
``ManualClock``; the Poisson arrival schedule is pre-drawn and replayed
by advancing the clock to each arrival, while every real kernel launch
and guard pass feeds its MEASURED wall duration back into the clock
(``SketchServer._timed`` / ``_guard_slice``).  Queueing dynamics are
therefore exactly reproducible — the guarded and unguarded runs see the
IDENTICAL arrival schedule — while service times stay real.

Two sections, two gates:

  * ``healthy`` — the same Poisson workload served with ``guard=True``
    and ``guard=False``.  GATE: guarded p99 latency overhead ≤ 25%
    (``--max-p99-overhead``) — detection must be cheap enough to leave
    on in production.
  * ``inject`` (``--inject``) — the same load with faults woven in:
    NaN-poisoned operands, adversarial annihilating inputs (κ=1/s=1
    plan class), and a corrupted tuner cache loaded mid-run.  GATE:
    ZERO SILENT FAILURES — every fault-touched request either serves a
    flagged (non-healthy-report) response or is rejected with an
    explicit shed/deadline status, and every ``ok`` response in the
    whole run holds a finite result.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.health import report as health_report
from repro.health.inject import (adversarial_input, corrupt_cache_file,
                                 inject_nan)
from repro.kernels import tune
from repro.serving import ManualClock, SketchRequest, SketchServer

PARAMS = dict(kappa=2, s=2, seed=7)
ADV_PARAMS = dict(kappa=1, s=1, seed=7)     # injectable plan class


def _arrivals(rps: float, count: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rps, size=count))


def _percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def warmup(*, d: int, n: int, k: int, max_batch: int = 8) -> None:
    """Compile every shape the timed runs can hit: each coalesced batch
    size is a distinct jit specialization, and a first-call compile in a
    timed run would dominate the tail."""
    clock = ManualClock()
    srv = SketchServer(clock=clock, guard=True, max_batch=max_batch,
                       batch_wait_s=0.001, max_queue=4 * max_batch)
    rng = np.random.default_rng(0)
    for b in range(1, max_batch + 1):
        for _ in range(b):
            srv.submit(SketchRequest(
                tenant="warm", kind="sketch",
                operand=rng.standard_normal((d, n)).astype(np.float32),
                plan_params=dict(PARAMS, d=d, k=k)))
        srv.run_pending(force=True)
    srv.drain()


def run_load(*, d: int, n: int, k: int, rps: float, count: int,
             guard: bool, seed: int, deadline_s: float,
             inject: bool = False, corrupt_path: Optional[str] = None,
             max_batch: int = 8, batch_wait_s: float = 0.002) -> Dict:
    """Replay one Poisson schedule through a fresh virtual-time server."""
    clock = ManualClock()
    srv = SketchServer(clock=clock, guard=guard, max_batch=max_batch,
                       batch_wait_s=batch_wait_s, max_queue=4 * max_batch)
    rng = np.random.default_rng(seed + 1)
    params = dict(PARAMS, d=d, k=k)
    adv_params = dict(ADV_PARAMS, d=d, k=k)
    adv_plan = srv.plans.resolve("bench", adv_params)
    arrivals = _arrivals(rps, count, seed)

    faulty: Dict[int, str] = {}
    tickets = []
    for i, t_arr in enumerate(arrivals):
        clock.advance(max(0.0, float(t_arr) - clock.now()))
        A = rng.standard_normal((d, n)).astype(np.float32)
        p = params
        if inject and i % 11 == 4:
            A = np.asarray(inject_nan(A, count=2, seed=i))
            faulty[i] = "nan"
        elif inject and i % 11 == 8:
            A = np.asarray(adversarial_input(adv_plan, n, seed=i))
            p = adv_params
            faulty[i] = "adversarial"
        if inject and corrupt_path is not None and i == count // 2:
            # corrupted tuner cache lands MID-RUN: load must warn + fall
            # back to the heuristic, and the generation bump must flush
            # the lowering memo without breaking in-flight groups
            corrupt_cache_file(corrupt_path, mode="garbage")
            tune.load_cache(corrupt_path)
        tickets.append(srv.submit(SketchRequest(
            tenant=f"t{i % 2}", kind="sketch", operand=A,
            plan_params=dict(p), deadline_s=deadline_s)))
        srv.run_pending()

    guard_steps = 0
    while srv.batcher.depth() and guard_steps < 10_000:
        clock.advance(2 * batch_wait_s)
        srv.run_pending()
        guard_steps += 1
    srv.run_pending(force=True)

    responses = [t if not isinstance(t, int) else srv.poll(t)
                 for t in tickets]
    assert all(r is not None for r in responses), "lost responses"

    lat = [r.latency_s for r in responses if r.served]
    statuses: Dict[str, int] = {}
    for r in responses:
        statuses[r.status] = statuses.get(r.status, 0) + 1
    silent = [i for i, r in enumerate(responses)
              if r.status == "ok" and (
                  r.result is None or not np.all(np.isfinite(r.result)))]
    unflagged_faults = [i for i in faulty
                        if responses[i].served and not responses[i].flagged]
    return {
        "guard": guard,
        "requests": count,
        "statuses": statuses,
        "served": sum(1 for r in responses if r.served),
        "p50_ms": _percentile(lat, 50) * 1e3,
        "p99_ms": _percentile(lat, 99) * 1e3,
        "throughput_rps": (sum(1 for r in responses if r.served)
                           / max(clock.now(), 1e-9)),
        "virtual_makespan_s": clock.now(),
        "injected": {kind: sum(1 for v in faulty.values() if v == kind)
                     for kind in set(faulty.values())},
        "silent_ok_nonfinite": silent,
        "unflagged_fault_responses": unflagged_faults,
        "stats": srv.stats(),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true", help="CI smoke sizes")
    ap.add_argument("--inject", action="store_true",
                    help="run the fault-injection-under-load section")
    ap.add_argument("--d", type=int, default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--rps", type=float, default=None)
    ap.add_argument("--count", type=int, default=None)
    ap.add_argument("--deadline-s", type=float, default=1.0)
    ap.add_argument("--max-p99-overhead", type=float, default=0.25,
                    help="healthy-workload gate: guarded p99 may exceed "
                         "unguarded p99 by at most this fraction")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if args.tiny:
        d, n, k, rps, count = 256, 16, 64, 400.0, 80
    else:
        d, n, k, rps, count = 2048, 64, 256, 200.0, 400
    d = args.d or d
    n = args.n or n
    k = args.k or k
    rps = args.rps or rps
    count = args.count or count

    cfg = dict(d=d, n=n, k=k, rps=rps, count=count,
               deadline_s=args.deadline_s, tiny=args.tiny, seed=args.seed)
    print(f"[serve_bench] config: {cfg}")

    # warm the jit caches so neither timed run pays first-call compiles
    warmup(d=d, n=n, k=k)
    health_report.reset_counters()

    out: Dict = {"config": cfg}
    ok = True

    # -- healthy workload: guarded vs unguarded, identical schedule -------
    healthy = {}
    for guard in (False, True):
        r = run_load(d=d, n=n, k=k, rps=rps, count=count, guard=guard,
                     seed=args.seed, deadline_s=args.deadline_s)
        healthy["guarded" if guard else "unguarded"] = r
        print(f"[serve_bench] guard={guard}: p50={r['p50_ms']:.3f}ms "
              f"p99={r['p99_ms']:.3f}ms served={r['served']}/{count} "
              f"thru={r['throughput_rps']:.0f} rps {r['statuses']}")
    p99_u = healthy["unguarded"]["p99_ms"]
    p99_g = healthy["guarded"]["p99_ms"]
    overhead = (p99_g - p99_u) / p99_u if p99_u > 0 else float("inf")
    gate_latency = bool(overhead <= args.max_p99_overhead)
    healthy["p99_overhead_frac"] = overhead
    healthy["gate_p99_overhead_ok"] = gate_latency
    print(f"[serve_bench] guarded p99 overhead: {overhead * 100:+.1f}% "
          f"(gate ≤ {args.max_p99_overhead * 100:.0f}%) "
          f"{'ok' if gate_latency else 'FAIL'}")
    if not gate_latency:
        ok = False
    out["healthy"] = healthy

    # -- faults under load ------------------------------------------------
    if args.inject:
        import tempfile
        health_report.reset_counters()
        with tempfile.NamedTemporaryFile(suffix=".json",
                                         delete=False) as f:
            corrupt_path = f.name
        r = run_load(d=d, n=n, k=k, rps=rps, count=count, guard=True,
                     seed=args.seed + 1, deadline_s=args.deadline_s,
                     inject=True, corrupt_path=corrupt_path)
        counters = health_report.counters()
        silent = (len(r["silent_ok_nonfinite"])
                  + len(r["unflagged_fault_responses"]))
        gate_silent = silent == 0
        cache_seen = counters.get("tune.cache_corrupt", 0) > 0
        r["counters"] = counters
        r["gate_no_silent_failures"] = gate_silent
        r["cache_corruption_detected"] = cache_seen
        print(f"[serve_bench] inject: {r['injected']} faults over "
              f"{count} requests; statuses {r['statuses']}; "
              f"silent failures: {silent} "
              f"{'ok' if gate_silent else 'FAIL'}")
        print(f"[serve_bench] counters: "
              f"{health_report.summarize_counters(12)}")
        if not (gate_silent and cache_seen):
            ok = False
        out["inject"] = r

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"[serve_bench] wrote {args.out}; "
          f"{'all gates ok' if ok else 'GATE FAILURE'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
