"""Pareto tournament: every registered sketch family, quality × speed.

    PYTHONPATH=src python -m benchmarks.pareto_bench             # full grid
    PYTHONPATH=src python -m benchmarks.pareto_bench --tiny      # CI smoke

The paper's headline claim is positional: BlockPerm-SJLT sits ON the
quality-vs-speed Pareto frontier of sparse sketching — faster than anything
of equal quality, better than anything of equal speed.  That claim is only
falsifiable against strong competitors, so this bench scores EVERY family
in ``repro.core.variants.SKETCH_FAMILIES`` (including the fused CountSketch
of Higgins & Boman arXiv:2508.14209 and the sparse-graph sketch of Hu et
al. arXiv:2102.05758, both lowered through the same engine) on four axes,
all lower-is-better:

  quality:
    * ``ose_err``    — OSE distortion ‖UᵀSᵀSU − I‖₂ on U = orth(A)
                       (the PR 6 ``ose_probe`` statistic, family-generic),
                       averaged over ``--trials`` independent draws so the
                       axis measures the FAMILY, not one lucky seed;
    * ``lsqr_iters`` — preconditioned-LSQR iterations to tol on a
                       controlled-cond consistent system (the
                       ``randnla_bench`` solver protocol).
  speed:
    * ``modeled_us`` — idealized TPU time from the family's ``cost_model``
                       (for engine families: the roofline of the Lowering
                       record that would actually launch);
    * ``measured_us``— wall-clock of the jitted apply on THIS host
                       (interpret/XLA off-TPU — real, but a CPU number).

Per regime (a (d, n, k, dataset) point) the bench reports the 4-axis
Pareto front.  The TOURNAMENT GATE is narrower and deliberately robust:
it replays the paper's own figure axes — mean OSE distortion × modeled
TPU time — and fails (non-zero exit) iff some non-kin family strictly
dominates ``blockperm`` there with a ≥``MARGIN`` relative win on the
strict axis, in a regime the paper claims (``claimed: true``).  Claimed
regimes use k large enough that a global family's plan has M ≥ κ row
blocks — the paper's setting, where CountSketch-style sketches pay M
full streams of A against BlockPerm's κ.  The CPU ``measured_us`` axis
and the (noisy, integer-quantized) iteration axis stay out of the gate:
they are evidence, not the claim.  BlockPerm's own ablations
(``blockperm_bf16``, ``localized``) are kin, not competitors — they
never count as dominators.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

import jax

jax.config.update("jax_enable_x64", True)   # solver iterations in f64

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import make_dataset, time_fn, modeled_tpu_us  # noqa: E402
from benchmarks.randnla_bench import make_ls_problem  # noqa: E402
from repro.core import coherence  # noqa: E402
from repro.core.variants import SKETCH_FAMILIES, make_sketch  # noqa: E402
from repro.kernels import ops as kops  # noqa: E402
from repro.solvers import lsqr  # noqa: E402

TOL = 1e-6

# A >= 5% relative win on the strict axis is required to call a family
# DOMINATED in the gate — differences inside the band are draw noise, not
# a Pareto ordering.
MARGIN = 0.05

# One entry per REGISTERED family — adding a family to SKETCH_FAMILIES and
# not here is a hard error (the tournament must stay exhaustive).
FAMILY_KWARGS = {
    "dense_gaussian": {},
    "dense_rademacher": {},
    "sjlt": {"s": 8},
    "srht": {},
    "blockperm": {"kappa": 4, "s": 2},
    "blockperm_bf16": {"kappa": 4, "s": 2},
    "blockperm_fp8": {"kappa": 4, "s": 2},
    "localized": {"s": 2},
    "blockrow": {"kappa": 4, "s": 2},
    "countsketch": {},
    "graph": {},
}

# BlockPerm's own ablation/precision variants — never counted as dominators
# of "blockperm" (beating yourself is not losing the tournament).
BLOCKPERM_KIN = ("blockperm", "blockperm_bf16", "blockperm_fp8",
                 "localized")

# The four reported axes (ALL lower-is-better) and the subset the gate
# replays (the paper's figure axes).
AXES = ("ose_err", "lsqr_iters", "modeled_us", "measured_us")
GATE_AXES = ("ose_err", "modeled_us")


def regimes(tiny: bool) -> List[Dict]:
    """(d, n, k, dataset) grid; ``claimed`` marks the regimes the paper's
    Pareto figure covers — tall operands with k large enough that global
    families split into M >= κ row blocks (k >= κ·256 under the default
    block cap).  The small-k and sparse regimes are reported but
    unclaimed: at M < κ a global sketch genuinely streams A fewer times
    than BlockPerm, and a near-empty operand rewards scan baselines."""
    if tiny:
        return [
            dict(name="tiny_claimed", d=2048, n=64, k=1024,
                 dataset="gaussian", cond=1e3, claimed=True),
            dict(name="tiny_smallk", d=1024, n=32, k=128,
                 dataset="gaussian", cond=1e3, claimed=False),
        ]
    return [
        dict(name="tall_gaussian", d=4096, n=64, k=1024,
             dataset="gaussian", cond=1e4, claimed=True),
        dict(name="tall_lowrank", d=4096, n=96, k=1024,
             dataset="lowrank_noise", cond=1e4, claimed=True),
        dict(name="llm_weights", d=8192, n=128, k=1024,
             dataset="llm_weights", cond=1e4, claimed=True),
        dict(name="smallk_gaussian", d=4096, n=64, k=256,
             dataset="gaussian", cond=1e4, claimed=False),
        dict(name="sparse", d=4096, n=64, k=1024,
             dataset="sparse", cond=1e4, claimed=False),
    ]


def score_family(name: str, kwargs: Dict, reg: Dict, *, seed: int,
                 trials: int, timing_iters: int, max_iters: int) -> Dict:
    """One family × one regime -> the 4-axis score row."""
    d, n, k = reg["d"], reg["n"], reg["k"]
    # independent draws: one sketch per trial seed (trial 0 also serves the
    # solver and timing axes — those are far less draw-sensitive).
    sketches = [make_sketch(name, d, k, seed=seed + 1000 * t, **kwargs)
                for t in range(trials)]
    sk = sketches[0]

    # quality axis 1: mean OSE distortion on U = orth(dataset operand).
    A_data = make_dataset(reg["dataset"], d, n, seed=seed)
    U, _ = np.linalg.qr(A_data)
    Uj = jnp.asarray(U, jnp.float32)
    ose_draws = [coherence.ose_spectral_error(
        U, np.asarray(s.apply(Uj), np.float64)) for s in sketches]
    ose_err = float(np.mean(ose_draws))

    # quality axis 2: preconditioned-LSQR iterations on a controlled-cond
    # CONSISTENT system (randnla_bench protocol, family-parametric R).
    A_np, b_np, _ = make_ls_problem(d, n, reg["cond"], seed=seed)
    A, b = jnp.asarray(A_np), jnp.asarray(b_np)
    SA = sk.apply(A.astype(jnp.float32))
    R = kops.triangular_factor(SA.astype(jnp.float32), "qr")
    res = lsqr(A, b, R=R.astype(b.dtype), tol=TOL, max_iters=max_iters)
    lsqr_iters = res.iterations if res.converged else max_iters

    # speed axes: modeled TPU roofline + measured host wall-clock.
    modeled_us = modeled_tpu_us(sk, n)
    Aj = jnp.asarray(A_data)
    apply_jit = jax.jit(lambda X: sk.apply(X))
    measured_us = 1e6 * time_fn(apply_jit, Aj, iters=timing_iters)

    return dict(
        family=name, params=json.dumps(kwargs, sort_keys=True),
        regime=reg["name"], d=d, n=n, k=sk.k,
        ose_err=ose_err, ose_draws=[float(x) for x in ose_draws],
        lsqr_iters=int(lsqr_iters),
        lsqr_converged=bool(res.converged), lsqr_relres=float(res.relres),
        modeled_us=float(modeled_us), measured_us=float(measured_us),
    )


def dominates(x: Dict, y: Dict, axes=AXES, margin: float = 0.0) -> bool:
    """x beats-or-ties y on every axis AND strictly beats it on >= 1
    (by a relative ``margin`` on the strict axis when given)."""
    return (all(x[a] <= y[a] for a in axes)
            and any(x[a] < (1.0 - margin) * y[a] for a in axes))


def pareto_front(rows: List[Dict], axes=AXES) -> List[str]:
    """Families not dominated by ANY other row of the regime."""
    return sorted(r["family"] for r in rows
                  if not any(dominates(o, r, axes) for o in rows
                             if o is not r))


def gate_dominators(target: str, rows: List[Dict]) -> List[str]:
    """Non-kin families that strictly dominate ``target`` on the GATE
    axes with the robustness margin."""
    tgt = next(r for r in rows if r["family"] == target)
    return sorted(r["family"] for r in rows
                  if r["family"] not in BLOCKPERM_KIN
                  and dominates(r, tgt, GATE_AXES, MARGIN))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke grid (small shapes, 1 timing rep)")
    ap.add_argument("--out", default="BENCH_pareto.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trials", type=int, default=None,
                    help="independent OSE draws per row (default 3 tiny/5)")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing repetitions per row (default 1 tiny / 3)")
    args = ap.parse_args(argv)

    missing = sorted(set(SKETCH_FAMILIES) - set(FAMILY_KWARGS))
    if missing:
        raise SystemExit(
            f"pareto_bench: families registered but not scored: {missing} "
            f"— add them to FAMILY_KWARGS (the tournament is exhaustive "
            f"by contract)")

    trials = args.trials or (3 if args.tiny else 5)
    timing_iters = args.iters or (1 if args.tiny else 3)
    max_iters = 100 if args.tiny else 200
    regs = regimes(args.tiny)

    all_rows: List[Dict] = []
    fronts: Dict[str, Dict[str, List[str]]] = {}
    gate_failures: List[Dict] = []
    for reg in regs:
        rows = []
        for fam, kw in sorted(FAMILY_KWARGS.items()):
            row = score_family(fam, kw, reg, seed=args.seed, trials=trials,
                               timing_iters=timing_iters,
                               max_iters=max_iters)
            rows.append(row)
            print(f"[{reg['name']}] {fam:>16}: ose={row['ose_err']:.3f} "
                  f"iters={row['lsqr_iters']:>3} "
                  f"modeled={row['modeled_us']:8.2f}us "
                  f"measured={row['measured_us']:10.1f}us")
        fronts[reg["name"]] = {
            "all_axes": pareto_front(rows, AXES),
            "gate_axes": pareto_front(rows, GATE_AXES),
        }
        doms = gate_dominators("blockperm", rows)
        print(f"[{reg['name']}] front(4-axis): "
              f"{fronts[reg['name']]['all_axes']}")
        print(f"[{reg['name']}] front(gate):   "
              f"{fronts[reg['name']]['gate_axes']}")
        if doms and reg["claimed"]:
            gate_failures.append(dict(regime=reg["name"], dominators=doms))
            print(f"[{reg['name']}] GATE FAIL: blockperm strictly "
                  f"dominated by {doms}")
        elif doms:
            print(f"[{reg['name']}] (unclaimed regime) blockperm "
                  f"dominated by {doms}")
        all_rows.extend(rows)

    gate_pass = not gate_failures
    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "interpret": jax.default_backend() != "tpu",
            "tiny": args.tiny,
            "seed": args.seed,
            "trials": trials,
            "tol": TOL,
            "axes": list(AXES),
            "gate_axes": list(GATE_AXES),
            "margin": MARGIN,
            "families": {f: json.dumps(kw, sort_keys=True)
                         for f, kw in sorted(FAMILY_KWARGS.items())},
            "blockperm_kin": list(BLOCKPERM_KIN),
            "note": ("all axes lower-is-better; modeled_us is the TPU-v5e "
                     "roofline of the launch the family would issue, "
                     "measured_us is host wall-clock (interpret off-TPU); "
                     "the gate replays the paper's figure axes "
                     "(mean-OSE x modeled) with a strict-win margin"),
        },
        "regimes": regs,
        "rows": all_rows,
        "pareto_fronts": fronts,
        "gate": {
            "pass": gate_pass,
            "rule": (f"fail iff blockperm is dominated on {GATE_AXES} "
                     f"(<= on both, < by a {MARGIN:.0%} relative margin "
                     f"on one) by a non-kin family in a claimed regime"),
            "failures": gate_failures,
        },
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\nwrote {args.out}: {len(all_rows)} rows over "
          f"{len(regs)} regimes; gate {'PASS' if gate_pass else 'FAIL'}")
    return 0 if gate_pass else 1


if __name__ == "__main__":
    sys.exit(main())
