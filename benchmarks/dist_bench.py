"""Multi-device FlashSketch benchmark: shard-mapped sketching on a forced
8-host-device mesh (the ``test_sharding_multidevice`` trick), plus the
distributed sketch-and-precondition solver.

    PYTHONPATH=src python -m benchmarks.dist_bench               # paper grid
    PYTHONPATH=src python -m benchmarks.dist_bench --tiny        # CI smoke

Writes ``BENCH_dist.json``.  Each row covers one (d, n, k, κ, dtype) cell:

  * ``exact_*``   — ``array_equal`` gates: row-sharded (the psum'd-partials
    path), column-sharded and batch-sharded applies against the
    single-device ``ops`` entry points.  These must hold BITWISE — the
    per-ℓ psum protocol guarantees it (see ``repro.distributed``).
  * ``measured_*`` — wall-clock on THIS host.  8 emulated host devices
    share the same cores, so sharded wall-clock says nothing about real
    scaling; it is a smoke signal only.
  * ``modeled_*`` — TPU-v5e numbers priced from the LOWERING RECORDS of
    the two organizations (``engine.cost_of``): the row-sharded partial
    (1/P HBM slab + ring-psum at ``hw.ICI_BW``) against the single-chip
    launch the dispatch engine would actually make.  For plans whose
    fused v2 scratch cannot fit VMEM, that single-chip baseline is the
    v1 revisiting kernel — what ``ops.sketch_apply`` really runs — not a
    hypothetical v2 launch that could never fit (the PR-4 class of
    model-vs-kernel contradiction).

The run FAILS (non-zero exit) if any exactness gate is lost, if the
modeled multi-chip scaling geomean drops below 1.5× at 8 devices, or if
the distributed solver fails to converge — CI runs ``--tiny`` as a
regression gate.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse                                              # noqa: E402
import json                                                  # noqa: E402
import sys                                                   # noqa: E402
from typing import Dict, List                                # noqa: E402

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402

from benchmarks.common import geomean, time_fn               # noqa: E402
from repro import engine                                     # noqa: E402
from repro.distributed import (dist_sketch_precondition_lstsq,  # noqa: E402
                               plan_for_mesh,
                               sketch_apply_batched_sharded,
                               sketch_apply_colsharded,
                               sketch_apply_sharded)
from repro.kernels import ops                                # noqa: E402
from repro.launch import mesh as mesh_lib                    # noqa: E402

DEVICES = 8
DTYPES = (None, "bfloat16")          # None = fp32 (the plan default)


def bench_grid(cells, *, mesh, axis, iters=3, batch=DEVICES) -> List[Dict]:
    rows: List[Dict] = []
    rng = np.random.default_rng(0)
    for d, n, k, kappa in cells:
        for dtype in DTYPES:
            plan = plan_for_mesh(d, k, DEVICES, kappa=kappa, s=2, seed=0,
                                 dtype=dtype or "float32")
            A = jnp.asarray(rng.normal(size=(d, n)).astype(np.float32))
            G = jnp.asarray(
                rng.normal(size=(batch, d, max(1, n // batch)))
                .astype(np.float32))

            ref = ops.sketch_apply(plan, A)
            sharded = sketch_apply_sharded(plan, A, mesh, axis)
            exact_row = bool(np.array_equal(np.asarray(sharded),
                                            np.asarray(ref)))
            exact_col = bool(np.array_equal(
                np.asarray(sketch_apply_colsharded(plan, A, mesh, axis)),
                np.asarray(ref)))
            exact_batch = bool(np.array_equal(
                np.asarray(sketch_apply_batched_sharded(plan, G, mesh, axis)),
                np.asarray(ops.sketch_apply_batched(plan, G))))

            single_fn = jax.jit(lambda X: ops.sketch_apply(plan, X))
            shard_fn = jax.jit(
                lambda X: sketch_apply_sharded(plan, X, mesh, axis))
            measured_single_us = 1e6 * time_fn(single_fn, A, iters=iters)
            measured_sharded_us = 1e6 * time_fn(shard_fn, A, iters=iters)

            # modeled from the lowering records of the two organizations
            # being compared: the single-chip launch as dispatch would
            # actually make it (v2, or the v1 downgrade when the fused
            # scratch cannot fit VMEM) and the row-sharded partial (the
            # same engine path sharded_apply lowers through)
            lw1 = engine.lower(plan, engine.LaunchSpec(
                op="fwd", n=n, impl="pallas", tn=128))
            lwP = engine.lower(plan, engine.LaunchSpec(
                op="fwd", n=n, impl="pallas", tn=128, shard="row",
                devices=DEVICES))
            c1 = engine.cost_of(lw1)
            cP = engine.cost_of(lwP)
            row = dict(
                d=d, n=n, k=plan.k_pad, kappa=kappa,
                dtype=dtype or "float32",
                M=plan.M, Br=plan.Br, Bc=plan.Bc, devices=DEVICES,
                exact_row_sharded=exact_row,
                exact_col_sharded=exact_col,
                exact_batch_sharded=exact_batch,
                measured_single_us=measured_single_us,
                measured_sharded_us=measured_sharded_us,
                modeled_single_chip_us=c1.modeled_us,
                modeled_per_chip_us=cP.modeled_us,
                modeled_ici_us=1e6 * cP.ici_s,
                modeled_bottleneck=cP.bottleneck,
                modeled_speedup=c1.modeled_us / cP.modeled_us,
                lowering_sharded=lwP.describe(),
            )
            rows.append(row)
            ok = exact_row and exact_col and exact_batch
            print(f"d={d:>8} n={n:>4} k={plan.k_pad:>5} kappa={kappa} "
                  f"dtype={row['dtype']:<8} exact={'OK' if ok else 'FAIL'} "
                  f"modeled x{row['modeled_speedup']:.2f} "
                  f"({row['modeled_bottleneck']})")
    return rows


def bench_solver(d, n, *, mesh, axis, tol=1e-5) -> Dict:
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.normal(size=(d, n)).astype(np.float32))
    x_true = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    b = A @ x_true
    res = dist_sketch_precondition_lstsq(A, b, mesh, axis, tol=tol)
    print(f"dist solver d={d} n={n}: iters={res.iterations} "
          f"relres={res.relres:.2e} converged={res.converged}")
    return dict(d=d, n=n, iterations=res.iterations,
                relres=float(res.relres), converged=bool(res.converged),
                tol=tol)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke grid (seconds, still gates exactness)")
    ap.add_argument("--out", default="BENCH_dist.json")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)

    if jax.device_count() < DEVICES:
        print(f"FAIL: need {DEVICES} devices (set XLA_FLAGS="
              f"--xla_force_host_platform_device_count={DEVICES} before "
              f"importing jax), got {jax.device_count()}", file=sys.stderr)
        return 1
    mesh, axis = mesh_lib.make_mesh((DEVICES,), ("shard",)), "shard"

    if args.tiny:
        # d/k ≈ 512: deep enough in the paper's d >> k regime that the
        # modeled 1/P HBM saving clears the psum cost (the gate's subject)
        cells = [(65_536, 16, 128, 1), (65_536, 16, 128, 2)]
        solver_dims = (4096, 24)
    else:
        cells = [(65_536, 64, 512, 1), (65_536, 64, 512, 2),
                 (262_144, 128, 1024, 2)]
        solver_dims = (65_536, 64)

    rows = bench_grid(cells, mesh=mesh, axis=axis, iters=args.iters)
    solver = bench_solver(*solver_dims, mesh=mesh, axis=axis)

    all_exact = all(r["exact_row_sharded"] and r["exact_col_sharded"]
                    and r["exact_batch_sharded"] for r in rows)
    geo_modeled = geomean([r["modeled_speedup"] for r in rows])
    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "devices": DEVICES,
            "tiny": args.tiny,
            "note": ("row/col/batch-sharded FlashSketch vs single device on "
                     f"{DEVICES} forced host devices; exact_* are "
                     "array_equal gates (psum'd per-kappa partials); "
                     "measured_* is host wall-clock (emulated devices share "
                     "cores — smoke only); modeled_* is engine.cost_of of "
                     "the two lowering records on TPU v5e: the row-sharded "
                     "partial (1/P HBM slab + ring psum at hw.ICI_BW) vs "
                     "the single-chip launch dispatch would actually make "
                     "(v1 when the fused v2 scratch cannot fit VMEM)"),
        },
        "rows": rows,
        "solver": solver,
        "all_exact": all_exact,
        "geomean_modeled_speedup": geo_modeled,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\nwrote {args.out}: modeled geomean x{geo_modeled:.2f} at "
          f"{DEVICES} devices, exact={'OK' if all_exact else 'FAIL'}, "
          f"solver={'OK' if solver['converged'] else 'FAIL'}")

    if not all_exact:
        print("FAIL: sharded apply lost bit-exactness vs single device",
              file=sys.stderr)
        return 1
    if not (geo_modeled >= 1.5):
        print(f"FAIL: modeled multi-chip scaling {geo_modeled:.2f}x < 1.5x "
              f"at {DEVICES} devices", file=sys.stderr)
        return 1
    if not solver["converged"]:
        print("FAIL: distributed sketch-and-precondition did not converge",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
