"""Shared benchmark utilities: datasets (paper §7.3), timing, cost models.

Two time axes are reported for every sketch:
  * measured_us — wall-clock of the jitted apply on THIS host (CPU XLA);
    real, comparable *between families*, but not TPU time;
  * modeled_us  — idealized TPU v5e time from the family's cost model
    (max of compute/memory terms), the number the roofline section uses.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.variants import SketchBase, make_sketch
from repro.roofline import hw


# ---------------------------------------------------------------------------
# datasets (paper §7.3: gaussian, low-rank+noise, sparse, LLM weights)
# ---------------------------------------------------------------------------

def make_dataset(name: str, d: int, n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if name == "gaussian":
        return rng.normal(size=(d, n)).astype(np.float32)
    if name == "lowrank_noise":
        r = max(4, n // 16)
        U = rng.normal(size=(d, r)).astype(np.float32)
        V = rng.normal(size=(r, n)).astype(np.float32)
        return (U @ V / np.sqrt(r) + 0.1 * rng.normal(size=(d, n))).astype(np.float32)
    if name == "sparse":
        # SuiteSparse spal_004-like: ~1.4% density
        A = rng.normal(size=(d, n)).astype(np.float32)
        mask = rng.random(size=(d, n)) < 0.014
        return (A * mask).astype(np.float32)
    if name == "llm_weights":
        # stacked-transformer-weight-like: block-wise scale variation +
        # mild low-rank structure (GPT2/Qwen2 stacked weights in the paper)
        blocks = []
        b = max(1, d // 16)
        for i in range(0, d, b):
            scale = 0.5 + 1.5 * rng.random()
            r = max(2, n // 8)
            U = rng.normal(size=(min(b, d - i), r)).astype(np.float32)
            V = rng.normal(size=(r, n)).astype(np.float32)
            W = scale * (0.7 * U @ V / np.sqrt(r)
                         + 0.3 * rng.normal(size=(min(b, d - i), n)))
            blocks.append(W.astype(np.float32))
        return np.concatenate(blocks, axis=0)
    raise KeyError(name)


DATASETS = ("gaussian", "lowrank_noise", "sparse", "llm_weights")


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------

def geomean(xs) -> float:
    """Geometric mean over positive finite entries (speedup ratios compose
    multiplicatively — see docs/benchmarks.md#geomean-methodology); NaN for
    an empty/filtered-out input.  The single aggregation rule every
    bench gate uses."""
    xs = [x for x in xs if x > 0 and np.isfinite(x)]
    return float(np.exp(np.mean(np.log(xs)))) if xs else float("nan")


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time (seconds) of a jitted fn."""
    for _ in range(warmup):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def modeled_tpu_us(sk: SketchBase, n: int) -> float:
    cm = sk.cost_model(n)
    t_compute = cm.flops / hw.PEAK_FLOPS_BF16
    t_memory = cm.hbm_bytes / hw.HBM_BW
    return 1e6 * max(t_compute, t_memory)


@dataclasses.dataclass
class BenchRow:
    task: str
    dataset: str
    family: str
    d: int
    n: int
    k: int
    params: str
    measured_us: float
    modeled_us: float
    quality: float
    quality_metric: str

    def csv(self) -> str:
        return (f"{self.task},{self.dataset},{self.family},{self.d},{self.n},"
                f"{self.k},{self.params},{self.measured_us:.1f},"
                f"{self.modeled_us:.2f},{self.quality:.6g},{self.quality_metric}")


CSV_HEADER = ("task,dataset,family,d,n,k,params,measured_us,modeled_us,"
              "quality,quality_metric")


# Table-1 baseline set (paper §7.1): dense Gaussian (cuBLAS), SJLT
# (cuSPARSE/GraSS-kernel semantics), subsampled FHT.  localized (κ=1) and
# FLASHBLOCKROW are appendix variants — plotted, but not Table-1 baselines.
PAPER_BASELINES = ("dense_gaussian", "sjlt", "srht")


def default_families(seed: int = 0):
    """The paper's comparison set (§7.1) + ours (κ tuned on the Pareto
    frontier, as the paper does) + appendix variants."""
    return [
        ("dense_gaussian", {}),
        ("sjlt", {"s": 8}),
        ("srht", {}),
        ("blockperm", {"kappa": 4, "s": 2}),
        ("blockperm", {"kappa": 2, "s": 2}),
        # mixed-precision entry is its own family so Table-1 aggregation
        # (ours == "blockperm") never compares bf16-ours vs fp32 baselines
        ("blockperm_bf16", {"kappa": 4, "s": 2}),
        ("localized", {"s": 2}),
        ("blockrow", {"kappa": 4, "s": 2}),
    ]


def build_sketch(family: str, d: int, k: int, seed: int, kwargs: Dict):
    return make_sketch(family, d, k, seed=seed, **kwargs)


def jit_apply(sk: SketchBase):
    return jax.jit(lambda A: sk.apply(A))
