"""RandNLA solver benchmark: sketch-and-precondition / sketch-and-solve.

    PYTHONPATH=src python -m benchmarks.randnla_bench            # smoke grid
    PYTHONPATH=src python -m benchmarks.randnla_bench --full     # larger grid

Exercises FlashSketch end-to-end the way the paper's evaluation does —
overdetermined least squares and low-rank approximation driven by the
sketch — and writes ``BENCH_randnla.json``.  For every (d, n) problem size
× κ ∈ {1, 2, 4} × streaming-precision policy — fp32/bf16 at the
ill-conditioned regime, the four fp8 policies (e4m3/e5m2 ×
nearest/stochastic) plus a matched bf16 reference at the
quantizer-reachable conditioning (see ``FP8_COND``):

  * unpreconditioned LSQR iterations to tol (the baseline every RandNLA
    paper compares against — blows up with cond(A));
  * sketch-and-precondition LSQR: iterations, final relative residual,
    measured wall time (sketch + factor + iterations);
  * one-shot sketch-and-solve relative residual;
  * modeled TPU-v5e time for the sketch step (roofline.sketch_model) plus
    flop-derived factor/iteration terms — the number to read off-TPU.

The solver iterations run in float64 (x64 enabled below) while the sketch
and factorization run in the plan's streaming precision — the standard
sketch-and-precondition split (low-precision preconditioner, full-precision
refinement; cf. Chen et al. arXiv:2506.03070).  The κ/dtype sweep makes the
paper's quality-vs-speed knob visible as iteration counts.
"""
from __future__ import annotations

import argparse
import json
import math
from typing import Dict, List

import jax

jax.config.update("jax_enable_x64", True)   # solver iterations in f64

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import time_fn  # noqa: E402
from repro.core.blockperm import make_plan  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.roofline import hw, sketch_model  # noqa: E402
from repro.solvers import (  # noqa: E402
    lsqr,
    multisketch_lstsq,
    sketch_and_solve_lstsq,
    sketched_svd,
    sketch_precondition_lstsq,
)

KAPPAS = (1, 2, 4)
# the precision-policy sweep: fp32/bf16 at the ill-conditioned regime,
# plus the four fp8 streaming policies (e4m3/e5m2 × nearest/stochastic)
# from ``core.precision``
DTYPES = ("float32", "bfloat16")
FP8_DTYPES = ("fp8_e4m3", "fp8_e4m3_sr", "fp8_e5m2", "fp8_e5m2_sr")
TOL = 1e-6
# The fp8 preconditioner's quality floor is the quantization noise
# (e4m3 rounds at ~6% relative, e5m2 at ~12%), so its reach is bounded:
# noise × cond(A) must stay O(10) for the preconditioned iteration to
# converge like a preconditioned iteration.  The fp8 rows therefore run
# at cond = min(--cond, FP8_COND) — the regime the 1-byte stream is FOR
# — alongside a matched bf16 reference row at the same cond; at the
# fp32/bf16 regime's cond=1e4 an fp8 preconditioner saturates near
# relres ~ 1e-3 (measured), which is the documented cliff, not a bug.
FP8_COND = 1e2
# CI gate: every fp8 row must converge, with LSQR iteration inflation vs
# the same-(d, n, κ, cond) bf16 row bounded by this factor (+ absolute
# slack for tiny iteration counts).  fp8 quantizes the PRECONDITIONER
# only — iterations absorb the quality loss; the refinement runs f64.
# Measured worst case on the smoke grid is 3.58x (e4m3+SR at 8192x128);
# 4x + slack is the regression band, not a target.
FP8_ITER_INFLATION = 4.0
FP8_ITER_SLACK = 10


def make_ls_problem(d: int, n: int, cond: float, seed: int = 0):
    """Tall (d, n) least-squares problem with controlled cond(A) and a
    CONSISTENT rhs (b = A x*), so the optimal residual is 0 and relative
    residual is a clean convergence meter."""
    rng = np.random.default_rng(seed)
    U, _ = np.linalg.qr(rng.normal(size=(d, n)))
    V, _ = np.linalg.qr(rng.normal(size=(n, n)))
    svals = np.logspace(0.0, -math.log10(cond), n)
    A = (U * svals) @ V.T
    x_true = rng.normal(size=n)
    return A, A @ x_true, x_true


def modeled_sketch_lowering(plan, n: int):
    """The record of the sketch launch the solver issues, pinned to the v2
    kernel (the modeled hardware is a TPU even when the host traces the
    xla oracle) — modeled columns are priced from THIS record."""
    from repro import engine
    return engine.lower(plan, engine.LaunchSpec(op="fwd", n=n,
                                                impl="pallas"))


def modeled_solver_us(plan, n: int, iters: int, d: int) -> float:
    """Modeled TPU time: sketch kernel (roofline of the lowering record) +
    QR of the (k, n) sketch + per-iteration 2 matvecs (4 d n flops) +
    triangular solves."""
    sketch_us = sketch_model.cost_of(modeled_sketch_lowering(plan, n)).modeled_us
    qr_flops = 2.0 * plan.k * n * n
    iter_flops = iters * (4.0 * d * n + 2.0 * n * n)
    dense_us = 1e6 * (qr_flops + iter_flops) / hw.PEAK_FLOPS_FP32
    # matvecs are memory-bound on a (d, n) operand: charge the streams too
    iter_mem_us = 1e6 * iters * (2.0 * 4 * d * n) / hw.HBM_BW
    return sketch_us + dense_us + iter_mem_us


def bench_lstsq(problems, *, cond: float, seed: int, unprecond_cap: int,
                iters: int) -> List[Dict]:
    rows: List[Dict] = []
    # two condition regimes: the ill-conditioned fp32/bf16 sweep, and the
    # fp8 sweep (with a matched bf16 reference for the inflation gate) at
    # the quantizer-reachable conditioning — see FP8_COND above
    regimes = [(cond, DTYPES)]
    regimes.append((min(cond, FP8_COND), ("bfloat16",) + FP8_DTYPES))
    for (d, n) in problems:
        for prob_cond, dtypes in regimes:
            A_np, b_np, _ = make_ls_problem(d, n, prob_cond, seed)
            A, b = jnp.asarray(A_np), jnp.asarray(b_np)
            base = lsqr(A, b, tol=TOL, max_iters=unprecond_cap)
            print(f"[{d}x{n}] cond={prob_cond:.0e} unpreconditioned: "
                  f"it={base.iterations} relres={base.relres:.2e} "
                  f"converged={base.converged}")
            for kappa in KAPPAS:
                for dtype in dtypes:
                    k = max(4 * n, n + 8)
                    plan = make_plan(d, k, kappa=kappa, s=2, seed=seed,
                                     dtype=dtype)

                    def solve():
                        return sketch_precondition_lstsq(
                            A, b, plan=plan, tol=TOL, max_iters=200)

                    res = solve()
                    t_us = 1e6 * time_fn(lambda: solve().x, iters=iters)
                    x_ss = sketch_and_solve_lstsq(plan, A, b)
                    ss_relres = float(jnp.linalg.norm(A @ x_ss - b)
                                      / jnp.linalg.norm(b))
                    row = dict(
                        task="lstsq", d=d, n=n, k=plan.k, kappa=kappa, s=2,
                        dtype=dtype, cond=prob_cond,
                        iters_precond=res.iterations,
                        relres_precond=res.relres,
                        converged_precond=res.converged,
                        iters_unprecond=base.iterations,
                        relres_unprecond=base.relres,
                        converged_unprecond=base.converged,
                        relres_sketch_solve=ss_relres,
                        measured_precond_us=t_us,
                        modeled_precond_us=modeled_solver_us(
                            plan, n, res.iterations, d),
                        modeled_sketch_us=sketch_model.cost_of(
                            modeled_sketch_lowering(plan, n)).modeled_us,
                        lowering_sketch=modeled_sketch_lowering(
                            plan, n).describe(),
                    )
                    rows.append(row)
                    print(f"[{d}x{n}] cond={prob_cond:.0e} kappa={kappa} "
                          f"{dtype:>11}: it={res.iterations:>3} "
                          f"relres={res.relres:.2e} "
                          f"sketch&solve={ss_relres:.2e} "
                          f"measured={t_us/1e3:.1f}ms")
    return rows


def bench_multisketch(problems, *, cond: float, seed: int) -> List[Dict]:
    rows = []
    for (d, n) in problems:
        A_np, b_np, _ = make_ls_problem(d, n, cond, seed)
        A, b = jnp.asarray(A_np), jnp.asarray(b_np)
        res = multisketch_lstsq(A, b, seed=seed, tol=TOL)
        rows.append(dict(
            task="multisketch", d=d, n=n, t=2,
            iterations=res.iterations, restarts=res.restarts,
            relres=res.relres, converged=res.converged,
            seeds=[list(s) for s in res.seeds],
        ))
        print(f"[{d}x{n}] multisketch: it={res.iterations} "
              f"restarts={res.restarts} relres={res.relres:.2e}")
    return rows


def bench_guarded(problems, *, cond: float, seed: int,
                  iters: int) -> List[Dict]:
    """Guarded-solve rows: what the health layer costs on HEALTHY inputs.

    Runs ``sketch_precondition_lstsq`` with and without ``guard=True`` on
    the same problem and reports the overhead percentage plus the
    HealthReport counters — on a well-posed problem the ladder must accept
    draw #1 (attempts == 1) and the guards cost two Frobenius norms and a
    diagonal scan.
    """
    rows: List[Dict] = []
    for (d, n) in problems:
        A_np, b_np, _ = make_ls_problem(d, n, cond, seed)
        A, b = jnp.asarray(A_np), jnp.asarray(b_np)

        def solve(guard):
            return sketch_precondition_lstsq(A, b, seed=seed, tol=TOL,
                                             max_iters=200, guard=guard)

        t_plain = 1e6 * time_fn(lambda: solve(False).x, iters=iters)
        t_guard = 1e6 * time_fn(lambda: solve(True).x, iters=iters)
        res = solve(True)
        rows.append(dict(
            task="guarded_lstsq", d=d, n=n,
            health_status=res.health.status,
            attempts=res.health.attempts,
            converged=res.converged, relres=res.relres,
            guard_overhead_pct=100.0 * (t_guard - t_plain)
            / max(t_plain, 1e-12),
            health_counters=res.health.counters(),
        ))
        print(f"[{d}x{n}] guarded: status={res.health.status} "
              f"attempts={res.health.attempts} "
              f"overhead={(t_guard - t_plain) / 1e3:+.1f}ms")
    return rows


def bench_lowrank(problems, *, rank: int, seed: int) -> List[Dict]:
    """Sketched low-rank SVD vs. numpy's truncated SVD (quality + time)."""
    rows = []
    for (d, n) in problems:
        rng = np.random.default_rng(seed)
        # rapidly decaying spectrum: rank-r signal + small tail
        L = (rng.normal(size=(d, rank)) @ rng.normal(size=(rank, n))
             / math.sqrt(rank)
             + 0.01 * rng.normal(size=(d, n)))
        Lj = jnp.asarray(L.astype(np.float32))
        plan = make_plan(d, max(4 * rank, 64), kappa=4, s=2, seed=seed)
        U, svals, Vt = sketched_svd(plan, Lj, rank=rank)
        err = float(np.linalg.norm(
            np.asarray(U) @ np.diag(np.asarray(svals)) @ np.asarray(Vt) - L)
            / np.linalg.norm(L))
        U0, s0, Vt0 = np.linalg.svd(L, full_matrices=False)
        opt = float(np.linalg.norm(
            (U0[:, :rank] * s0[:rank]) @ Vt0[:rank] - L) / np.linalg.norm(L))
        rows.append(dict(task="lowrank_svd", d=d, n=n, rank=rank,
                         rel_err=err, optimal_rel_err=opt,
                         suboptimality=err / max(opt, 1e-30)))
        print(f"[{d}x{n}] sketched svd rank={rank}: err={err:.4f} "
              f"(optimal {opt:.4f})")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger (d, n) grid")
    ap.add_argument("--out", default="BENCH_randnla.json")
    ap.add_argument("--cond", type=float, default=1e4,
                    help="condition number of the test matrices")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--iters", type=int, default=1,
                    help="timing repetitions per row")
    args = ap.parse_args(argv)

    if args.full:
        problems = [(8192, 64), (16384, 128), (32768, 256)]
        unprecond_cap = 2000
    else:
        problems = [(4096, 64), (8192, 128)]
        unprecond_cap = 1000

    rows = bench_lstsq(problems, cond=args.cond, seed=args.seed,
                       unprecond_cap=unprecond_cap, iters=args.iters)
    ms_rows = bench_multisketch(problems, cond=args.cond, seed=args.seed)
    g_rows = bench_guarded(problems, cond=args.cond, seed=args.seed,
                           iters=args.iters)
    lr_rows = bench_lowrank(problems, rank=16, seed=args.seed)

    fp32 = [r for r in rows if r["dtype"] == "float32"]
    ok = all(r["relres_precond"] <= TOL
             and r["iters_precond"] < r["iters_unprecond"] for r in fp32)
    # fp8 gate: every fp8 row converged, iteration inflation vs the
    # matching bf16 row bounded (the "robustness surfaces as iteration
    # count" acceptance check for the precision refactor)
    bf16_iters = {(r["d"], r["n"], r["kappa"], r["cond"]):
                  r["iters_precond"]
                  for r in rows if r["dtype"] == "bfloat16"}

    def _ref(r):
        return bf16_iters[(r["d"], r["n"], r["kappa"], r["cond"])]

    fp8 = [r for r in rows if r["dtype"].startswith("fp8")]
    inflations = [r["iters_precond"] / max(_ref(r), 1) for r in fp8]
    fp8_ok = bool(fp8) and all(
        r["converged_precond"]
        and r["iters_precond"] <= (FP8_ITER_INFLATION * _ref(r)
                                   + FP8_ITER_SLACK)
        for r in fp8)
    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "interpret": jax.default_backend() != "tpu",
            "tol": TOL,
            "cond": args.cond,
            "problems": [list(p) for p in problems],
            "kappas": list(KAPPAS),
            "dtypes": list(DTYPES),
            "note": ("solver iterations in f64, sketch+factor in the "
                     "plan's streaming dtype; measured_* is CPU wall-clock "
                     "off-TPU, modeled_* is the TPU-v5e roofline"),
            "fp32_rows_all_converged_faster_than_unpreconditioned": ok,
            "fp8_rows_all_converged_with_bounded_inflation": fp8_ok,
            "fp8_dtypes": list(FP8_DTYPES),
            "fp8_cond": min(args.cond, FP8_COND),
            "fp8_iter_inflation_bound": FP8_ITER_INFLATION,
            "fp8_iter_slack": FP8_ITER_SLACK,
            "fp8_max_iter_inflation_vs_bf16": max(inflations, default=None),
        },
        "rows": rows,
        "multisketch": ms_rows,
        "guarded": g_rows,
        "lowrank": lr_rows,
    }
    from repro.health import report as health_report
    payload["meta"]["health_counters"] = health_report.counters()
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\nwrote {args.out}: {len(rows)} lstsq rows, "
          f"fp32 precond-beats-unprecond on all rows: {ok}, "
          f"fp8 converged within {FP8_ITER_INFLATION}x bf16 iterations: "
          f"{fp8_ok} (max inflation "
          f"{max(inflations, default=float('nan')):.2f}x)")
    if not (ok and fp8_ok):
        # CI gate: the JSON above is already on disk as the debugging
        # artifact for exactly the failing rows
        raise SystemExit(1)


if __name__ == "__main__":
    main()
