"""Numerical validation of the paper's §6/App. A theory claims:

  * Lemma A.9 sandwich:  μ_blk/κ ≤ μ_nbr ≤ μ_blk           (every draw)
  * Prop A.11 smoothing: μ_nbr ≤ 1 + C(√(μ_blk L/κ) + μ_blk L/κ)
  * Thm 6.2 scaling:     OSE error ~ √(μ_nbr t / k)
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core import coherence, wiring
from repro.core.blockperm import make_plan
from repro.kernels import ref as kref


def coherence_rows(M: int = 64, block: int = 8, r: int = 4,
                   seeds=(0, 1, 2, 3, 4)) -> List[str]:
    rng = np.random.default_rng(0)
    # spiky subspace (worst case for localized sketching)
    U = np.zeros((M * block, r), np.float32)
    U[:block * 2, :] = np.linalg.qr(rng.normal(size=(block * 2, r)))[0]
    mu_blk = coherence.block_coherence(U, M)
    rows = [f"theory,coherence,mu_blk,{M},{r},,{mu_blk:.4f},,"]
    for kappa in (1, 2, 4, 8, 16):
        vals = [coherence.neighborhood_coherence(
            U, wiring.wiring_table(s, M, kappa)) for s in seeds]
        mu = float(np.mean(vals))
        bound = coherence.smoothing_bound(mu_blk, kappa, M, r, C=2.0)
        sandwich_ok = all(
            mu_blk / kappa - 1e-9 <= v <= mu_blk + 1e-9 for v in vals)
        rows.append(
            f"theory,coherence,mu_nbr(kappa={kappa}),{M},{r},,"
            f"{mu:.4f},{bound:.4f},sandwich_ok={sandwich_ok}")
    return rows


def ose_scaling_rows(d: int = 4096, r: int = 8,
                     k_values=(128, 256, 512, 1024, 2048)) -> List[str]:
    rng = np.random.default_rng(1)
    U, _ = np.linalg.qr(rng.normal(size=(d, r)))
    Uj = jnp.asarray(U, jnp.float32)
    rows = []
    for k in k_values:
        errs = []
        for seed in range(4):
            plan = make_plan(d, k, kappa=4, s=2, seed=seed)
            SU = np.asarray(kref.flashsketch_ref(plan, Uj))
            errs.append(coherence.ose_spectral_error(U, SU))
        pred = np.sqrt(r / k)       # Thm 6.2 scaling (μ_nbr≈1, t≈r)
        rows.append(f"theory,ose_scaling,k={k},{d},{r},,"
                    f"{np.mean(errs):.4f},{pred:.4f},ratio="
                    f"{np.mean(errs)/pred:.2f}")
    return rows


def all_rows() -> List[str]:
    return coherence_rows() + ose_scaling_rows()
