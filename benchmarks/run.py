"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # smoke scale
    PYTHONPATH=src python -m benchmarks.run --full     # paper scale
    PYTHONPATH=src python -m benchmarks.run --only gram,table1

Prints ``name,...,derived`` CSV rows (assignment format) and writes
experiments/bench_results.csv + the Table-1 speedup summary.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from benchmarks import common, grass_bench, roofline_table, sketch_tasks, speedup_table
from benchmarks import theory_validation


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale shapes")
    ap.add_argument("--only", default=None,
                    help="comma list: gram,ose,ridge,solve,ablation,table1,"
                         "grass,theory,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    # Shapes follow the paper's regime d >> k (§7: d = 16384..262144,
    # k <= 4096); out-of-regime d/k ~ 4 makes any sparse sketch pointless.
    if args.full:
        d, n = 65_536, 512
        k_values = (256, 1024, 4096)
        datasets = common.DATASETS
    else:
        d, n = 16_384, 128
        k_values = (256, 2048)
        datasets = ("gaussian", "llm_weights")
    families = common.default_families()

    all_rows = []
    print(common.CSV_HEADER)

    def emit(rows):
        for r in rows:
            line = r.csv() if hasattr(r, "csv") else str(r)
            print(line)
            all_rows.append(r)

    t0 = time.time()
    if want("gram"):
        emit(sketch_tasks.gram_rows(d, n, k_values, families, datasets))
    if want("ose"):
        emit(sketch_tasks.ose_rows(d, n, k_values, families, datasets))
    if want("ridge"):
        emit(sketch_tasks.ridge_rows(d, n, k_values, families, datasets,
                                     task="ridge"))
    if want("solve"):
        emit(sketch_tasks.ridge_rows(d, n, k_values, families, datasets,
                                     task="solve"))
    if want("ablation"):
        emit(sketch_tasks.ablation_rows(d, n, k_values[0]))

    bench_rows = [r for r in all_rows if isinstance(r, common.BenchRow)]
    if want("table1") and bench_rows:
        table = speedup_table.speedup_table(bench_rows)
        headline = speedup_table.global_geomean_vs_next_best(table)
        print()
        print("## Table 1 — geomean speedups of FlashSketch(blockperm) "
              "vs baselines (measured-CPU× / modeled-TPU×)")
        print(speedup_table.format_markdown(table, headline))
        print()

    if want("theory"):
        for line in theory_validation.all_rows():
            print(line)
    if want("grass"):
        for line in grass_bench.grass_rows("full" if args.full else "smoke"):
            print(line)
    if want("roofline"):
        for line in roofline_table.csv_rows():
            print(line)

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_results.csv", "w") as f:
        f.write(common.CSV_HEADER + "\n")
        for r in bench_rows:
            f.write(r.csv() + "\n")
    print(f"# done in {time.time()-t0:.1f}s; "
          f"{len(bench_rows)} rows -> experiments/bench_results.csv")


if __name__ == "__main__":
    main()
