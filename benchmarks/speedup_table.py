"""Table 1 analogue: geomean speedups of FlashSketch vs each baseline,
aggregated over shapes × datasets × configs per task, on both time axes
(measured CPU wall time; modeled TPU v5e time)."""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Iterable, List, Tuple

import numpy as np

from benchmarks import common


def geomean(xs: Iterable[float]) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return float("nan")
    return float(np.exp(np.mean(np.log(xs))))


def speedup_table(rows: List[common.BenchRow],
                  ours: str = "blockperm",
                  baselines=common.PAPER_BASELINES) -> Dict[str, Dict[str, Dict[str, float]]]:
    """table[task][baseline] = {measured: gx, modeled: gx} vs ours, matched
    on (dataset, d, n, k-request bucket).  When several of our configs exist
    at a cell (κ tuning), the fastest-modeled one is used — the paper tunes
    κ on the Pareto frontier the same way."""
    ours_rows = defaultdict(dict)
    for r in rows:
        if r.family == ours:
            key = (r.task, r.dataset, r.d, r.n)
            prev = ours_rows[key].get(r.k)
            if prev is None or r.modeled_us < prev.modeled_us:
                ours_rows[key][r.k] = r

    def nearest(task_key, k):
        cand = ours_rows.get(task_key)
        if not cand:
            return None
        kk = min(cand, key=lambda x: abs(x - k))
        return cand[kk]

    table: Dict[str, Dict[str, Dict[str, List[float]]]] = defaultdict(
        lambda: defaultdict(lambda: {"measured": [], "modeled": []}))
    for r in rows:
        if r.family == ours or (baselines and r.family not in baselines):
            continue
        mine = nearest((r.task, r.dataset, r.d, r.n), r.k)
        if mine is None:
            continue
        if mine.measured_us > 0:
            table[r.task][r.family]["measured"].append(
                r.measured_us / mine.measured_us)
        if mine.modeled_us > 0:
            table[r.task][r.family]["modeled"].append(
                r.modeled_us / mine.modeled_us)
    out: Dict[str, Dict[str, Dict[str, float]]] = {}
    for task, fams in table.items():
        out[task] = {}
        for fam, axes in fams.items():
            out[task][fam] = {ax: geomean(v) for ax, v in axes.items()}
    return out


def global_geomean_vs_next_best(table) -> Dict[str, float]:
    """Paper headline: global geomean vs the NEXT-BEST baseline per cell."""
    per_axis = {"measured": [], "modeled": []}
    for task, fams in table.items():
        for ax in per_axis:
            best = min((v[ax] for v in fams.values() if np.isfinite(v[ax])),
                       default=float("nan"))
            if np.isfinite(best):
                per_axis[ax].append(best)
    return {ax: geomean(v) for ax, v in per_axis.items()}


def format_markdown(table, headline) -> str:
    fams = sorted({f for t in table.values() for f in t})
    lines = ["| Task | " + " | ".join(fams) + " |",
             "|---" * (len(fams) + 1) + "|"]
    for task, row in sorted(table.items()):
        cells = []
        for f in fams:
            v = row.get(f)
            cells.append(f"{v['measured']:.2f}×/{v['modeled']:.2f}×" if v else "—")
        lines.append(f"| {task} | " + " | ".join(cells) + " |")
    lines.append("")
    lines.append(f"Global geomean vs next-best baseline: "
                 f"measured {headline['measured']:.2f}×, "
                 f"modeled-TPU {headline['modeled']:.2f}× "
                 f"(paper: 1.73×).")
    return "\n".join(lines)
