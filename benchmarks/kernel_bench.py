"""Kernel-level microbenchmark: FlashSketch v1 vs v2.

    PYTHONPATH=src python -m benchmarks.kernel_bench            # smoke grid
    PYTHONPATH=src python -m benchmarks.kernel_bench --full     # paper grid
    PYTHONPATH=src python -m benchmarks.kernel_bench --autotune # tn sweep first

Times the Pallas kernels (interpret mode off-TPU) for fwd / transpose /
blockrow, fp32 and bf16, across a (d, k) grid, and writes a machine-readable
``BENCH_kernel.json`` so future PRs have a perf trajectory to regress
against.  Each row carries both:

  * measured v1/v2 wall-times on THIS host (interpret-mode python overhead
    scales with grid steps, so the κ-fused v2 launch shows up directly);
  * modeled TPU-v5e times from ``roofline.sketch_model`` (single-write +
    bf16-streaming HBM terms) — the trustworthy number off-TPU.

v1 is fp32-only; bf16 rows therefore compare v2-bf16 against the fp32 v1
baseline, which is exactly the upgrade a user of the old kernel gets.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import geomean, time_fn
from repro import engine
from repro.core.blockperm import SKETCH_VARIANTS as VARIANTS
from repro.core.blockperm import make_plan
from repro.kernels import ops, tune

DTYPES = ("float32", "bfloat16")


def _apply_fn(variant: str, impl: str, plan, tn, dtype):
    if variant == "fwd":
        return jax.jit(lambda X: ops.sketch_apply(plan, X, impl, tn, dtype))
    if variant == "transpose":
        return jax.jit(lambda X: ops.sketch_apply_t(plan, X, impl, tn, dtype))
    return jax.jit(lambda X: ops.blockrow_apply(plan, X, impl, tn, dtype))


def _operand(variant: str, plan, n: int, rng) -> np.ndarray:
    rows = plan.k_pad if variant == "transpose" else plan.d
    return rng.normal(size=(rows, n)).astype(np.float32)


def bench_grid(d_values, k_values, n_for, *, kappa=4, s=2, seed=0,
               tn=64, iters=3, autotune_first=False,
               check_allclose=True) -> List[Dict]:
    rows: List[Dict] = []
    rng = np.random.default_rng(seed)
    for d in d_values:
        for k in k_values:
            if k * 8 > d:        # stay in the paper's d >> k regime
                continue
            n = n_for(d)
            for dtype in DTYPES:
                plan = make_plan(d, k, kappa=kappa, s=s, seed=seed, dtype=dtype)
                for variant in VARIANTS:
                    use_tn = v1_tn = tn
                    if autotune_first:
                        # each generation gets its own best tile — timing v1
                        # at v2's winner would bias the speedup toward v2
                        use_tn = tune.autotune(plan, n, variant, iters=1).tn
                        v1_tn = tune.v1_default_tn(plan, n)
                    X = _operand(variant, plan, n, rng)
                    v2 = _apply_fn(variant, "pallas", plan, use_tn, dtype)
                    v1 = _apply_fn(variant, "pallas_v1", plan, v1_tn, dtype)
                    if check_allclose and dtype == "float32":
                        np.testing.assert_allclose(
                            np.asarray(v2(X)), np.asarray(v1(X)),
                            atol=1e-5, rtol=1e-5,
                        )
                    v2_us = 1e6 * time_fn(v2, X, iters=iters)
                    v1_us = 1e6 * time_fn(v1, X, iters=iters)
                    # modeled costs come from the SAME lowering records the
                    # timed entry points resolve — not re-derived knobs
                    lw2 = engine.lower(plan, engine.LaunchSpec(
                        op=variant, n=n, impl="pallas", tn=use_tn))
                    lw1 = engine.lower(plan, engine.LaunchSpec(
                        op=variant, n=n, impl="pallas_v1", tn=use_tn))
                    m2 = engine.cost_of(lw2)
                    m1 = engine.cost_of(lw1)
                    row = dict(
                        d=d, k=plan.k_pad, n=n, kappa=kappa, s=s,
                        variant=variant, dtype=dtype, tn=use_tn, v1_tn=v1_tn,
                        M=plan.M, Br=plan.Br, Bc=plan.Bc,
                        v1_us=v1_us, v2_us=v2_us,
                        speedup=v1_us / v2_us,
                        modeled_v1_us=m1.modeled_us, modeled_v2_us=m2.modeled_us,
                        modeled_speedup=m1.modeled_us / m2.modeled_us,
                        modeled_bottleneck_v2=m2.bottleneck,
                        lowering_v2=lw2.describe(),
                        lowering_v1=lw1.describe(),
                    )
                    rows.append(row)
                    print(f"{d:>7} {plan.k_pad:>5} {variant:>9} {dtype:>8} "
                          f"tn={use_tn:<4} v1={v1_us:9.1f}us v2={v2_us:9.1f}us "
                          f"x{row['speedup']:.2f}  modeled x{row['modeled_speedup']:.2f}")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale (d, k) grid")
    ap.add_argument("--out", default="BENCH_kernel.json")
    ap.add_argument("--tn", type=int, default=64)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--autotune", action="store_true",
                    help="sweep tn per shape before timing")
    ap.add_argument("--tune-cache", default=None,
                    help="path to persist the autotuner cache")
    args = ap.parse_args(argv)

    if args.full:
        d_values = (16_384, 65_536, 131_072)
        k_values = (256, 1024, 4096)
        n_for = lambda d: 1024 if d <= 65_536 else 512
    else:
        d_values = (4096, 16_384)
        k_values = (256, 1024)
        n_for = lambda d: 256

    rows = bench_grid(d_values, k_values, n_for, tn=args.tn, iters=args.iters,
                      autotune_first=args.autotune)

    measured = geomean([r["speedup"] for r in rows])
    modeled = geomean([r["modeled_speedup"] for r in rows])
    modeled_bf16 = geomean(
        [r["modeled_speedup"] for r in rows if r["dtype"] == "bfloat16"])
    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "interpret": jax.default_backend() != "tpu",
            "grid": {"d": list(d_values), "k": list(k_values)},
            "note": ("measured_* is interpret-mode wall-clock off-TPU; "
                     "modeled_* is the roofline sketch_model on TPU v5e"),
        },
        "rows": rows,
        "geomean_measured_speedup": measured,
        "geomean_modeled_speedup": modeled,
        "geomean_modeled_speedup_bf16": modeled_bf16,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    if args.tune_cache:
        tune.save_cache(args.tune_cache)
    print(f"\nwrote {args.out}: geomean measured x{measured:.2f}, "
          f"modeled x{modeled:.2f} (bf16 rows x{modeled_bf16:.2f})")


if __name__ == "__main__":
    main()
