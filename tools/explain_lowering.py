#!/usr/bin/env python
"""Print the sketch lowering decision trace for one launch shape.

    PYTHONPATH=src python tools/explain_lowering.py --d 65536 --k 1024 --n 512
    PYTHONPATH=src python tools/explain_lowering.py --d 65536 --k 1024 \
        --n 512 --dtype bfloat16 --impl pallas --block-rows 256
    PYTHONPATH=src python tools/explain_lowering.py --d 4096 --k 1024 \
        --n 64 --shard row --devices 8

Shows exactly what ``repro.kernels.ops`` would launch for these knobs —
the resolved impl (with any downgrade and its reason), the tile width and
where it came from, the VMEM footprint, the padding plan — plus the
modeled TPU-v5e roofline of that same record (``engine.cost_of``).  CI
runs this as a smoke step so the engine's public surface cannot rot.

``--check-health`` additionally runs a tiny GUARDED solve on this shape
(``sketch_precondition_lstsq(guard=True)``) and prints its HealthReport
and the process-wide guard counters — exits non-zero if the guarded solve
fails outright.  CI runs this too, so the guard layer's public surface is
smoke-tested alongside the lowering trace.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    from repro.core import precision

    ap = argparse.ArgumentParser(
        description="FlashSketch lowering decision trace")
    ap.add_argument("--d", type=int, required=True, help="input dim (rows)")
    ap.add_argument("--k", type=int, required=True, help="sketch dim")
    ap.add_argument("--n", type=int, required=True, help="operand columns")
    ap.add_argument("--kappa", type=int, default=4)
    ap.add_argument("--s", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--block-rows", type=int, default=None,
                    help="pin B_r (make_plan block_rows=)")
    ap.add_argument("--dtype", choices=list(precision.names()), default=None,
                    help="streaming-precision policy override (any "
                         "registered core.precision policy or alias)")
    ap.add_argument("--op", choices=["fwd", "transpose", "blockrow"],
                    default="fwd")
    ap.add_argument("--impl",
                    choices=["auto", "pallas", "pallas_v1", "xla"],
                    default="pallas",
                    help="requested impl (default 'pallas': show the TPU "
                         "decision regardless of host backend)")
    ap.add_argument("--tn", type=int, default=None,
                    help="explicit tile width (default: tuner/heuristic)")
    ap.add_argument("--gather", action="store_true",
                    help="gather-fused row_index= launch")
    ap.add_argument("--batch", type=int, default=1,
                    help="batched-apply fold factor")
    ap.add_argument("--shard", choices=["none", "row", "col", "batch"],
                    default="none")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--tune-cache", default=None,
                    help="JSON tuner cache to load first (tuned winners "
                         "then show up as the resolved tile)")
    ap.add_argument("--check-health", action="store_true",
                    help="also run a tiny guarded solve on this shape and "
                         "print its HealthReport + the guard counters "
                         "(nonzero exit if the guarded solve fails)")
    args = ap.parse_args(argv)

    from repro import engine
    from repro.core.blockperm import make_plan
    from repro.kernels import tune

    if args.tune_cache:
        n_loaded = tune.load_cache(args.tune_cache)
        print(f"loaded {n_loaded} tuned winners from {args.tune_cache}\n")

    plan = make_plan(args.d, args.k, kappa=args.kappa, s=args.s,
                     seed=args.seed, block_rows=args.block_rows)
    spec = engine.LaunchSpec(
        op=args.op, n=args.n, impl=args.impl, tn=args.tn, dtype=args.dtype,
        gather=args.gather, batch=args.batch, shard=args.shard,
        devices=args.devices)
    print(engine.explain(plan, spec))

    lw = engine.lower(plan, spec)
    try:
        kc = engine.cost_of(lw)
    except ValueError as e:           # e.g. row-sharded blockrow
        print(f"\nmodeled cost: n/a ({e})")
        return 0
    print(f"\nmodeled TPU-v5e roofline of this record "
          f"(repro.engine.cost_of):")
    print(f"  mxu={1e6 * kc.compute_s:8.2f} us   "
          f"vpu={1e6 * kc.vpu_s:8.2f} us   "
          f"hbm={1e6 * kc.memory_s:8.2f} us   "
          f"ici={1e6 * kc.ici_s:8.2f} us")
    print(f"  modeled {kc.modeled_us:.2f} us, bottleneck: {kc.bottleneck}")

    if args.check_health:
        return _check_health(args)
    return 0


def _check_health(args) -> int:
    """Tiny guarded solve with this launch's κ/s/seed knobs; prints the
    HealthReport and the process guard counters.  The problem shape is
    capped (the point is exercising the guard surface, not the launch
    size)."""
    import numpy as np

    from repro.health import report
    from repro.solvers.sketch_precondition import sketch_precondition_lstsq

    d = min(args.d, 8192)
    n = min(args.n, 32)
    rng = np.random.default_rng(args.seed)
    A = rng.standard_normal((d, n)).astype(np.float32)
    b = (A @ np.ones(n, np.float32)).astype(np.float32)
    res = sketch_precondition_lstsq(
        A, b, kappa=args.kappa, s=args.s, seed=args.seed,
        impl="auto", guard=True, probe=True)
    print(f"\nguarded solve on a capped ({d}, {n}) problem:")
    print(res.health.describe())
    print(f"converged={res.converged} relres={res.relres:.3g} "
          f"iterations={res.iterations}")
    print("guard counters: " + report.summarize_counters(max_items=100))
    if res.health.status == "failed" or not res.converged:
        print("health check FAILED", file=sys.stderr)
        return 1
    print("health check ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
