#!/usr/bin/env python
"""Check internal markdown links in docs/*.md and README.md.

Verifies that every relative link target exists, and that heading-anchor
fragments (``file.md#some-heading``) resolve to a heading in the target
file (GitHub slug rules: lowercase, punctuation stripped, spaces->dashes).
External (http/https/mailto) links are ignored.

    python tools/check_doc_links.py          # from the repo root
Exit status 1 with a report if any link is broken.
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown/punctuation, lowercase,
    spaces to dashes."""
    text = re.sub(r"[`*_]", "", heading).strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: pathlib.Path) -> set:
    return {github_slug(h) for h in HEADING_RE.findall(path.read_text())}


def check_file(md: pathlib.Path, root: pathlib.Path) -> list:
    errors = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, fragment = target.partition("#")
        if path_part:
            resolved = (md.parent / path_part).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(root)}: broken link "
                              f"-> {target} (no such file)")
                continue
        else:
            resolved = md
        if fragment:
            if resolved.suffix != ".md":
                continue
            if fragment not in anchors_of(resolved):
                errors.append(f"{md.relative_to(root)}: broken anchor "
                              f"-> {target} (no heading "
                              f"'#{fragment}' in {resolved.name})")
    return errors


def main() -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    files = sorted((root / "docs").glob("*.md")) + [root / "README.md"]
    errors = []
    checked = 0
    for md in files:
        if md.exists():
            checked += 1
            errors.extend(check_file(md, root))
    if errors:
        print("\n".join(errors))
        print(f"\n{len(errors)} broken link(s) across {checked} file(s)")
        return 1
    print(f"ok: {checked} markdown file(s), all internal links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
