"""End-to-end GraSS data attribution with FlashSketch (paper §7.4, Fig. 4):
train an MLP, build a sketched gradient feature cache, compute attributions,
and evaluate with the linear datamodeling score (LDS).

    PYTHONPATH=src python examples/grass_attribution.py
    PYTHONPATH=src python examples/grass_attribution.py --full
"""
import argparse

from repro.attribution.grass import GrassPipelineConfig, run_grass_lds
from repro.attribution.mlp import MLPConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale MLP (109k params) + m=50 subsets")
    ap.add_argument("--k", type=int, default=None)
    args = ap.parse_args(argv)

    if args.full:
        mcfg = MLPConfig(d_in=784, hidden=(256, 256), steps=120)
        n_train, n_test, m, sparse = 1024, 32, 50, 4096
        k = args.k or 1024
    else:
        mcfg = MLPConfig(d_in=128, hidden=(32, 32), steps=80)
        n_train, n_test, m, sparse = 256, 24, 24, 1024
        k = args.k or 256

    print(f"[grass] MLP{mcfg.hidden} n_train={n_train} m={m} k={k}")
    for fam in ("blockperm", "dense_gaussian", "sjlt", "blockrow"):
        res = run_grass_lds(
            GrassPipelineConfig(sparse_dim=sparse, sketch_dim=k,
                                sketch_family=fam),
            mcfg, n_train=n_train, n_test=n_test, m_subsets=m)
        print(f"[grass] {fam:16s} LDS={res['lds']:+.3f} "
              f"featurize={res['per_sample_us']:.0f}us/sample")


if __name__ == "__main__":
    main()
