"""Least squares with FlashSketch, three ways (CI smoke-tests this).

    PYTHONPATH=src python examples/least_squares.py

Solves an ill-conditioned overdetermined system min ||Ax - b|| with:
  1. sketch-and-precondition LSQR  — machine precision, O(1) iterations;
  2. one-shot sketch-and-solve     — (1+eps)-optimal, zero iterations;
  3. adaptive multisketch          — cheap independent draws + restarts.
and prints iteration counts so the sketch-quality knobs (kappa, streaming
dtype) are visible: a cheaper sketch preconditions slightly worse and pays
in iterations, never in final accuracy.
"""
import numpy as np
import jax.numpy as jnp

from repro.solvers import lsqr, sketch_precondition_lstsq, solve_preset


def make_problem(d=4096, n=64, cond=1e4, seed=0):
    rng = np.random.default_rng(seed)
    U, _ = np.linalg.qr(rng.normal(size=(d, n)))
    V, _ = np.linalg.qr(rng.normal(size=(n, n)))
    svals = np.logspace(0.0, -np.log10(cond), n)
    A = ((U * svals) @ V.T).astype(np.float32)
    x_true = rng.normal(size=n).astype(np.float32)
    return jnp.asarray(A), jnp.asarray(A @ x_true)


def main():
    A, b = make_problem()
    d, n = A.shape
    print(f"problem: A ({d}, {n}), cond 1e4, consistent rhs; tol 1e-5\n")

    base = lsqr(A, b, tol=1e-5, max_iters=500)
    print(f"unpreconditioned LSQR : {base.iterations:>4} iters, "
          f"relres {base.relres:.1e}  (ill-conditioning hurts)")

    # the kappa / streaming-dtype quality-vs-speed knob, explicitly:
    for kappa, dtype in ((4, "float32"), (4, "bfloat16"), (1, "float32")):
        res = sketch_precondition_lstsq(
            A, b, kappa=kappa, dtype=dtype, tol=1e-5, max_iters=200)
        print(f"precond kappa={kappa} {dtype:>8}: {res.iterations:>4} iters, "
              f"relres {res.relres:.1e}")
        assert res.converged, "sketch-preconditioned LSQR must converge"
        assert res.iterations < base.iterations

    # the named operating points (configs.flashsketch_paper.SOLVER_PRESETS):
    print()
    for name in ("default", "fast", "direct", "multisketch"):
        res = solve_preset(A, b, name)
        extra = (f", restarts {res.restarts}" if hasattr(res, "restarts")
                 else "")
        print(f"preset {name:>11}       : {res.iterations:>4} iters, "
              f"relres {res.relres:.1e}{extra}")
        if name == "direct":
            assert res.relres < 1e-2, "sketch-and-solve is (1+eps)-optimal"
        else:
            assert res.converged, f"preset {name} must converge"

    print("\nok")


if __name__ == "__main__":
    main()
