"""RandNLA sketch-and-solve walkthrough (paper §7.3): least squares and
ridge regression with every sketch family, on the paper's dataset types.

    PYTHONPATH=src python examples/randnla_tasks.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.variants import make_sketch


def main():
    d, n, k = 8192, 128, 1024
    for ds in ("gaussian", "lowrank_noise", "llm_weights"):
        A_np = common.make_dataset(ds, d, n, seed=0)
        rng = np.random.default_rng(1)
        x_true = rng.normal(size=(n,)).astype(np.float32)
        b_np = A_np @ x_true + 0.01 * rng.normal(size=(d,)).astype(np.float32)
        A, b = jnp.asarray(A_np), jnp.asarray(b_np)
        # direct solution residual for reference
        x_dir, *_ = np.linalg.lstsq(A_np, b_np, rcond=None)
        res_dir = np.linalg.norm(A_np @ x_dir - b_np) / np.linalg.norm(b_np)
        print(f"--- {ds}: direct residual {res_dir:.5f}")
        for fam in ("blockperm", "dense_gaussian", "srht", "sjlt"):
            sk = make_sketch(fam, d, k, seed=0)

            @jax.jit
            def solve(A_, b_):
                SA = sk.apply(A_)
                Sb = sk.apply(b_[:, None])[:, 0]
                x = jnp.linalg.lstsq(SA, Sb)[0]
                return jnp.linalg.norm(A_ @ x - b_) / jnp.linalg.norm(b_)

            print(f"    {fam:16s} sketch-and-solve residual "
                  f"{float(solve(A, b)):.5f}")


if __name__ == "__main__":
    main()
