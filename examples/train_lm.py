"""End-to-end driver: train an LM for a few hundred steps on the synthetic
bigram stream and watch the loss drop.

Default is CPU-sized; ``--preset 100m`` builds a ~100M-param qwen3-family
model (the assignment's end-to-end scale — expect ~20-40 min on one CPU
core; it is the default on real accelerators).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import dataclasses

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.data import pipeline as dp
from repro.optim import adamw
from repro.optim import grad_compress as gc
from repro.train.trainer import Trainer, TrainerConfig


def build_cfg(preset: str):
    base = get_arch("qwen3-0.6b")
    if preset == "tiny":
        return dataclasses.replace(
            smoke_config(base), n_layers=4, d_model=128, d_ff=512,
            vocab_size=2048)
    if preset == "100m":
        # ~100M params: 12L, d=768, ffn 2048, vocab 32k (tied embeddings)
        return dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32_000,
            param_dtype="float32", remat=False)
    raise KeyError(preset)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=("tiny", "100m"))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--grad-compress", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    cfg = build_cfg(args.preset)
    print(f"[train_lm] {cfg.name} preset={args.preset} "
          f"params~{cfg.param_count()/1e6:.1f}M steps={args.steps}")
    opt = adamw.AdamWConfig(lr=3e-3 if args.preset == "tiny" else 6e-4,
                            warmup_steps=max(10, args.steps // 20),
                            total_steps=args.steps, weight_decay=0.01)
    data_cfg = dp.DataConfig(vocab_size=cfg.vocab_size,
                             global_batch=args.batch, seq_len=args.seq)
    comp = (gc.CompressConfig(ratio=args.grad_compress)
            if args.grad_compress else None)
    tcfg = TrainerConfig(total_steps=args.steps,
                         ckpt_every=max(50, args.steps // 4),
                         ckpt_dir=args.ckpt_dir,
                         log_every=max(1, args.steps // 25))
    out = Trainer(cfg, opt, tcfg, data_cfg, compress=comp).fit()
    l0 = sum(out["losses"][:10]) / 10
    l1 = sum(out["losses"][-10:]) / 10
    print(f"[train_lm] loss {l0:.4f} -> {l1:.4f} over {out['steps']} steps "
          f"({out['wall_s']:.1f}s) — structure learned: {l1 < l0 - 0.5}")


if __name__ == "__main__":
    main()
