"""Quickstart: sketch a matrix with BLOCKPERM-SJLT / FlashSketch.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.blockperm import make_plan
from repro.core import coherence
from repro.core.variants import make_sketch
from repro.kernels import ops


def main():
    d, n, k = 8192, 256, 1024
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(d, n)), jnp.float32)

    # --- low-level API: plan + kernel apply -------------------------------
    plan = make_plan(d, k, kappa=4, s=2, seed=0)
    print("plan:", plan.describe())
    Y = ops.sketch_apply(plan, A)           # Pallas on TPU, XLA elsewhere
    print("Y = SA:", Y.shape)
    print("Gram rel-error:", coherence.gram_rel_error(np.asarray(A), np.asarray(Y)))

    # --- transpose apply (the VJP / decompression operator) ---------------
    X = ops.sketch_apply_t(plan, Y)
    print("SᵀY:", X.shape)

    # --- high-level API: sketch families for benchmarking -----------------
    for fam in ("blockperm", "dense_gaussian", "srht", "blockrow"):
        sk = make_sketch(fam, d, k, seed=1)
        err = coherence.gram_rel_error(np.asarray(A), np.asarray(sk.apply(A)))
        cm = sk.cost_model(n)
        print(f"{fam:16s} gram_rel={err:.4f} "
              f"modeled_tpu_us={1e6*max(cm.flops/197e12, cm.hbm_bytes/819e9):.1f}")


if __name__ == "__main__":
    main()
